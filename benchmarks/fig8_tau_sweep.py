"""Fig. 8 analogue: dynamic-threshold ablation — accuracy and tokens/step
as tau sweeps 0.5..0.99 for the post-trained model."""

from __future__ import annotations


def run(quick: bool = True) -> list[str]:
    from .common import bench_config, quick_sft
    from .table1_eval import evaluate
    taus = [0.5, 0.9] if quick else [0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99]
    model, params, tok, _ = quick_sft(bench_config(),
                                      steps=200 if quick else 400, level=0)
    rows = ["tau,acc,tokens_per_step"]
    for tau in taus:
        m = evaluate(model, params, tok, n_problems=32 if quick else 64,
                     mode="dynamic", tau=tau, level=0)
        rows.append(f"{tau},{m['acc']:.3f},{m['tokens_per_step']:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
