"""Fig. 8 analogue: dynamic-threshold ablation — accuracy and tokens/step
as tau sweeps 0.5..0.99 for the post-trained model.

Rebuilt on the per-request ``SamplingParams`` API: the whole sweep is
ONE mixed-configuration batch — every (tau, problem) pair is a request
with its own params, all submitted to a single slot pool and drained in
one pass (one model build, one jit warmup, one drain), instead of the
old one-engine-rebuild-per-τ loop.  With the prefix cache on, the N
problems' prompt pages are shared across all τ variants — sampling
params never touch prompt KV — so the sweep pays each prompt's prefill
once, not once per τ.  The pool's advance is traced exactly once for
the entire mixed sweep (asserted below).
"""

from __future__ import annotations

import jax
import numpy as np


def run(quick: bool = True) -> list[str]:
    from .common import bench_config, quick_sft
    from repro.data.math_tasks import check_answer
    from repro.data.pipeline import MathTaskDataset
    from repro.serving.api import SamplingParams
    from repro.serving.scheduler import SlotScheduler

    taus = [0.5, 0.9] if quick else [0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99]
    model, params, tok, _ = quick_sft(bench_config(),
                                      steps=200 if quick else 400, level=0)
    n = 32 if quick else 64
    max_len, s_max = 96, 8
    bsz = model.cfg.block_size
    ds = MathTaskDataset(tok, bsz, seq_len=max_len, seed=123, level=0)
    pb = next(ds.prompt_batches(n))
    prompts = np.asarray(pb.prompt_tokens)
    pblocks = np.asarray(pb.prompt_blocks)

    # one pool serves the full τ × problems cross product
    sched = SlotScheduler(model, n_slots=8, max_len=max_len, s_max=s_max,
                          temperature=0.0, eos_id=tok.eos_id,
                          cache="paged", prefix_cache=True)
    keys = jax.random.split(jax.random.PRNGKey(123), n)
    meta = {}
    for tau in taus:
        sp = SamplingParams(tau=tau, mode="dynamic", temperature=0.0,
                            eos_id=tok.eos_id)
        for i in range(n):
            uid = sched.submit(prompts[i], int(pblocks[i]), keys[i],
                               params=sp)
            meta[uid] = (tau, i)
    comps = {c.uid: c for c in sched.run(params)}      # single drain
    assert len(comps) == len(meta)
    # the mixed sweep must not retrace per τ: params are traced data
    assert sched.n_advance_traces == 1, sched.n_advance_traces

    acc = {t: [] for t in taus}
    tps = {t: [] for t in taus}
    for uid, (tau, i) in meta.items():
        c = comps[uid]
        lo, hi = c.prompt_blocks * bsz, \
            (c.prompt_blocks + c.gen_blocks) * bsz
        text = tok.decode(c.tokens[lo:hi])
        acc[tau].append(float(check_answer(text, int(pb.answers[i]))))
        tps[tau].append((hi - lo) / max(c.denoise_steps, 1))
    rows = ["tau,acc,tokens_per_step"]
    for tau in taus:
        rows.append(f"{tau},{np.mean(acc[tau]):.3f},"
                    f"{np.mean(tps[tau]):.2f}")
    s = sched.stats
    rows.append(f"# one pool, one drain: {len(meta)} mixed requests, "
                f"{sched.n_advance_traces} advance trace, prefix hit "
                f"{s.prefix_hit_rate:.0%} ({s.prefix_hit_blocks} of "
                f"{s.prefix_hit_blocks + s.prefix_miss_blocks} prompt "
                f"blocks shared across tau variants)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
