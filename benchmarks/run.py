"""Benchmark harness — one entry per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--suite NAME]``

Prints CSV blocks per benchmark.  --full widens sweeps (slower).
``--suite paged_attn`` (or any registered name, with or without the
``_bench`` suffix) runs a single suite; ``--smoke`` shrinks it to tiny
shapes and *validates the emitted JSON artifact* against the shared
schema (``common.validate_bench_json``), exiting nonzero on any error —
the CI bench-smoke job's contract.

The roofline/dry-run artifacts (deliverables e/g) are produced separately
by ``python -m repro.launch.dryrun --all`` and summarised by
``python -m repro.launch.rooflines``; this harness reports their status.
"""

from __future__ import annotations

import argparse
import glob
import inspect
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None,
                    help="run a single registered suite by exact name")
    ap.add_argument("--suite", type=str, default=None,
                    help="run a single suite by short name "
                         "(e.g. paged_attn)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; validate emitted JSON artifacts "
                         "and exit nonzero on any failure")
    args = ap.parse_args()
    quick = not args.full

    from . import (analysis_bench, async_rl_bench, fig6_breakdown,
                   fig7_sizes, fig8_tau_sweep, kernel_bench,
                   paged_attn_bench, serve_bench, table1_eval)
    from .common import validate_bench_json

    benches = {
        "analysis_bench": analysis_bench.run,
        "kernel_bench": kernel_bench.run,
        "paged_attn_bench": paged_attn_bench.run,
        "fig7_sizes": fig7_sizes.run,
        "fig6_breakdown": fig6_breakdown.run,
        "table1_eval": table1_eval.run,
        "fig8_tau_sweep": fig8_tau_sweep.run,
        "serve_bench": serve_bench.run,
        "async_rl_bench": async_rl_bench.run,
    }
    # suites that track a cross-PR trajectory artifact: suite short name
    # -> per-entry required keys, checked by --smoke after the run
    json_suites = {
        "kernel_bench": ("block_diff_attn", kernel_bench.ENTRY_KEYS),
        "paged_attn_bench": ("paged_attn", paged_attn_bench.ENTRY_KEYS),
        "async_rl_bench": ("async_rl", async_rl_bench.ENTRY_KEYS),
    }

    only = args.only
    if args.suite:
        only = args.suite if args.suite in benches \
            else f"{args.suite}_bench"
        if only not in benches:
            sys.exit(f"unknown suite {args.suite!r}; registered: "
                     f"{', '.join(sorted(benches))}")

    failed = False
    for name, fn in benches.items():
        if only and only != name:
            continue
        t0 = time.time()
        print(f"\n=== {name} ===")
        try:
            kwargs = {"quick": quick}
            if args.smoke and \
                    "smoke" in inspect.signature(fn).parameters:
                kwargs["smoke"] = True
            for row in fn(**kwargs):
                print(row)
            if args.smoke and name in json_suites:
                suite, keys = json_suites[name]
                print(f"# schema ok: {validate_bench_json(suite, keys)}")
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            failed = True
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)

    if only is None:
        # dry-run / roofline status summary
        print("\n=== dryrun_status ===")
        root = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "dryrun")
        recs = [json.load(open(p))
                for p in glob.glob(os.path.join(root, "*.json"))]
        ok = sum(1 for r in recs if r.get("ok"))
        print(f"combos,{len(recs)},ok,{ok}")
        from collections import Counter
        doms = Counter(r["dominant"] for r in recs if r.get("ok"))
        for k, v in sorted(doms.items()):
            print(f"dominant_{k},{v}")

    if args.smoke and failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
