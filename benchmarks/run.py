"""Benchmark harness — one entry per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full]``

Prints CSV blocks per benchmark.  --full widens sweeps (slower).
The roofline/dry-run artifacts (deliverables e/g) are produced separately
by ``python -m repro.launch.dryrun --all`` and summarised by
``python -m repro.launch.rooflines``; this harness reports their status.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    quick = not args.full

    from . import (fig6_breakdown, fig7_sizes, fig8_tau_sweep,
                   kernel_bench, paged_attn_bench, serve_bench,
                   table1_eval)

    benches = {
        "kernel_bench": kernel_bench.run,
        "paged_attn_bench": paged_attn_bench.run,
        "fig7_sizes": fig7_sizes.run,
        "fig6_breakdown": fig6_breakdown.run,
        "table1_eval": table1_eval.run,
        "fig8_tau_sweep": fig8_tau_sweep.run,
        "serve_bench": serve_bench.run,
    }
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"\n=== {name} ===")
        try:
            for row in fn(quick=quick):
                print(row)
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)

    # dry-run / roofline status summary
    print("\n=== dryrun_status ===")
    root = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    recs = [json.load(open(p)) for p in glob.glob(os.path.join(root,
                                                               "*.json"))]
    ok = sum(1 for r in recs if r.get("ok"))
    print(f"combos,{len(recs)},ok,{ok}")
    from collections import Counter
    doms = Counter(r["dominant"] for r in recs if r.get("ok"))
    for k, v in sorted(doms.items()):
        print(f"dominant_{k},{v}")


if __name__ == "__main__":
    main()
