"""dirlint smoke: time the full contract-checking pass.

The analyzer is part of CI's lint gate, so its own latency is a
contract: the full pass (trace hygiene + donation safety + kernel
capture over the whole plan matrix) must stay interactive.  Emits one
CSV row per pass plus the total, and raises if the full run exceeds
the budget.
"""

from __future__ import annotations

import time

_BUDGET_S = 30.0


def run(quick: bool = True, smoke: bool = False):
    from repro.analysis import run_all
    from repro.analysis.astutils import Project
    from repro.analysis import donation, kernel_contracts, trace_lint

    yield "pass,findings,suppressed,seconds"

    project = Project.__new__(Project)          # built below, timed
    t0 = time.perf_counter()
    project.__init__(_src_root())
    t_parse = time.perf_counter() - t0
    yield f"parse,{len(project.modules)},0,{t_parse:.2f}"

    rows = []
    for name, fn in (("trace_lint", trace_lint.run),
                     ("donation", donation.run)):
        t0 = time.perf_counter()
        found = fn(project)
        rows.append((name, found, time.perf_counter() - t0))
    t0 = time.perf_counter()
    rows.append(("kernel_contracts", kernel_contracts.run(project),
                 time.perf_counter() - t0))
    for name, found, dt in rows:
        yield f"{name},{len(found)},0,{dt:.2f}"

    t0 = time.perf_counter()
    findings = run_all()
    t_all = time.perf_counter() - t0
    loud = [f for f in findings if not f.suppressed]
    yield (f"run_all,{len(loud)},"
           f"{len(findings) - len(loud)},{t_all:.2f}")

    total = t_parse + sum(dt for _, _, dt in rows) + t_all
    if total > _BUDGET_S:
        raise RuntimeError(
            f"dirlint pass took {total:.1f}s > {_BUDGET_S:.0f}s budget")
    if loud:
        raise RuntimeError(
            f"dirlint found {len(loud)} unsuppressed finding(s): "
            + "; ".join(f.format() for f in loud[:5]))
    yield f"total,,,{total:.2f}"


def _src_root():
    # repro is a namespace package (no __file__); anchor on a real module
    import repro.analysis as a
    from pathlib import Path
    return Path(a.__file__).resolve().parents[1]
