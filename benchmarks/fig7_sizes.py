"""Fig. 7 analogue: SFT train-step latency across model sizes, for the
DiRL fused mask vs the TraceRL-style layout vs the no-fusion replay
baseline (per-block sequential logit computation)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.block_diffusion import sft_loss, token_cross_entropy
from repro.core.masks import plain_layout, sample_sft_noise
from repro.models.model import BlockDiffLM


def _replay_sft_loss(model, params, batch, rng):
    """No-fused-mask baseline: per-block sequential recomputation (the
    cost structure TraceRL §4.1 improves on)."""
    cfg = model.cfg
    tokens = batch["tokens"]
    B, L = tokens.shape
    bsz = cfg.block_size
    K = L // bsz
    steps, weight, _ = sample_sft_noise(rng, tokens, batch["prompt_mask"],
                                        batch["valid"],
                                        block_size=cfg.block_size)
    meta = plain_layout(tokens, batch["valid"], block_size=bsz)
    caches = model.make_caches(B, L)
    _, out = model.forward_masked(params, tokens, meta, caches=caches,
                                  want_boundaries=bool(cfg.ssm_kind))
    caches = out["caches"]
    MASK = cfg.resolved_mask_token

    def blk_loss(k):
        ids = jnp.where(
            jax.lax.dynamic_slice_in_dim(steps, k * bsz, bsz, 1) > 0, MASK,
            jax.lax.dynamic_slice_in_dim(tokens, k * bsz, bsz, 1))
        pos = k * bsz + jnp.arange(bsz, dtype=jnp.int32)[None, :]
        pos = jnp.broadcast_to(pos, (B, bsz))
        lg, _ = model.decode_step(params, ids, pos, caches,
                                  cache_limit=k * bsz)
        ce = token_cross_entropy(
            lg, jax.lax.dynamic_slice_in_dim(tokens, k * bsz, bsz, 1))
        w = jax.lax.dynamic_slice_in_dim(weight, k * bsz, bsz, 1)
        return jnp.sum(ce * w)

    tot = jnp.sum(jax.lax.map(blk_loss, jnp.arange(K)))
    denom = jnp.maximum(jnp.sum(batch["valid"] & ~batch["prompt_mask"]), 1)
    return tot / denom, {}


def run(quick: bool = True) -> list[str]:
    from .common import SEQ_LEN, bench_config, timed
    from repro.data.pipeline import MathTaskDataset
    from repro.data.tokenizer import ByteTokenizer

    sizes = [(128, 2), (256, 2)] if quick else [(128, 2), (256, 4),
                                                (384, 6), (512, 8)]
    rows = ["d_model,n_layers,variant,ms_per_train_step"]
    tok = ByteTokenizer()
    for d, nl in sizes:
        for variant in ["dirl", "tracer", "replay"]:
            cfg = bench_config(d_model=d, n_layers=nl)
            model = BlockDiffLM(cfg)
            params = model.init(jax.random.PRNGKey(0))
            ds = MathTaskDataset(tok, cfg.block_size, seq_len=SEQ_LEN,
                                 seed=0)
            b = next(ds.sft_batches(8)).asdict()
            b = {k: jnp.asarray(v) for k, v in b.items()}
            if variant == "tracer":
                # TraceRL layout needs a static prompt length: use the
                # common block-aligned minimum
                plen = int(b["prompt_mask"].sum(1).min())
                plen -= plen % cfg.block_size
                b["prompt_len_static"] = plen
                loss_fn = functools.partial(sft_loss, model,
                                            layout="tracer")
            elif variant == "dirl":
                loss_fn = functools.partial(sft_loss, model)
            else:
                loss_fn = functools.partial(_replay_sft_loss, model)

            @jax.jit
            def step(p, rng):
                (l, _), g = jax.value_and_grad(
                    lambda q: loss_fn(q, b, rng), has_aux=True)(p)
                return l, g

            t = timed(lambda: step(params, jax.random.PRNGKey(1)),
                      warmup=1, iters=2)
            rows.append(f"{d},{nl},{variant},{t * 1e3:.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
