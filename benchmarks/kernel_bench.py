"""Kernel-level benchmark: block-diffusion attention implementations.

Wall-clock on CPU is NOT the deliverable (interpret-mode Pallas is a
correctness harness); the structurally meaningful numbers are the tile
visit fractions — the FLOP savings the TPU kernel realises via its
FlexAttention-style block-sparse map — reported per layout/shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.masks import dirl_layout, packed_layout, sample_sft_noise
from repro.kernels import ops


def run(quick: bool = True) -> list[str]:
    from .common import timed
    rows = ["layout,L,block,impl,us_per_call,tile_visit_fraction"]
    Ls = [256] if quick else [256, 512, 1024]
    for L in Ls:
        for bsz in [16, 32]:
            key = jax.random.PRNGKey(0)
            B, H, Hkv, Dh = 2, 4, 2, 32
            tokens = jax.random.randint(key, (B, L), 4, 100)
            valid = jnp.ones((B, L), bool)
            pm = jnp.arange(L)[None] < bsz
            steps, _, _ = sample_sft_noise(key, tokens, pm, valid,
                                           block_size=bsz)
            ids, meta, _ = dirl_layout(tokens, steps, valid,
                                       block_size=bsz, mask_token=101,
                                       noised=True)
            T = meta.length
            ks = jax.random.split(key, 3)
            q = jax.random.normal(ks[0], (B, T, H, Dh))
            k = jax.random.normal(ks[1], (B, T, Hkv, Dh))
            v = jax.random.normal(ks[2], (B, T, Hkv, Dh))
            qm = ops.pack_meta(meta)
            tm = ops.build_tile_map(qm, qm, 128, 128)
            frac = ops.tile_map_stats(tm)["visit_fraction"]
            for impl, kw in [("ref", {}),
                             ("chunked", {}),
                             ("structured",
                              dict(dup_len=L, block_size=bsz))]:
                fn = jax.jit(lambda a, b, c: ops.attention(
                    a, b, c, meta, meta, impl=impl, **kw))
                t = timed(lambda: fn(q, k, v), warmup=1, iters=3)
                rows.append(f"sft_dup,{L},{bsz},{impl},{t * 1e6:.0f},"
                            f"{frac:.3f}")
            # packed RL layout visit fraction
            steps_rl = jax.random.randint(key, (B, L), 0, 4)
            _, meta_p, _, _ = packed_layout(tokens, steps_rl, valid,
                                            block_size=bsz,
                                            mask_token=101, s_max=4)
            qmp = ops.pack_meta(meta_p)
            tmp = ops.build_tile_map(qmp, qmp, 128, 128)
            fr = ops.tile_map_stats(tmp)["visit_fraction"]
            rows.append(f"rl_packed,{L},{bsz},tile_map,0,{fr:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
