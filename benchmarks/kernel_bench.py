"""Kernel-level benchmark: block-diffusion training attention.

Measures the three training-attention impls — ``ref`` (dense oracle),
``structured`` (pure-jnp dup-layout fast path) and ``pallas`` (the
tile-map-sparse flash kernel with its custom-VJP backward) — on the SFT
duplicated layout, forward and forward+backward.  The pallas rows are
the tentpole deliverable: the compacted visited-tile grid does work
only where the block-diffusion mask (and the sliding window the
long-context model family trains with, cf. ``configs/*`` with
``sliding_window``) is non-empty, while the jnp paths pay the dense
(2L)^2 matmul and its quadratic autodiff residents.  The headline
long-context shape is where that separation shows up even in CPU
interpret mode (the ``mode`` column says which execution path ran;
on TPU the compiled kernels win at every shape).

Per-row ``grad_max_dev`` is the max |d(impl) - d(structured autodiff)|
over dq/dk/dv — the numerical contract (0.0 for structured itself;
pallas documented tolerance ``GRAD_TOL``).

Emits ``benchmarks/BENCH_block_diff_attn.json`` through the shared
schema (``common.write_bench_json``); CI bench-smoke replays it on a
tiny shape and validates the artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import dirl_layout, packed_layout, sample_sft_noise
from repro.kernels import ops

SUITE = "block_diff_attn"
GRAD_TOL = 5e-4  # f32 max deviation vs structured autodiff
ENTRY_KEYS = ("layout", "L", "block_size", "window", "tile", "impl",
              "mode", "fwd_us", "fwd_bwd_us", "fwd_tok_s",
              "fwd_bwd_tok_s", "tile_visit_fraction", "grad_max_dev",
              "grad_tol")

_IMPLS = ("structured", "ref", "pallas")  # structured first: dev baseline

# (L, block_size, window, tile): the headline row is the long-context
# sliding-window SFT shape — the assert below pins the pallas win there
_HEADLINE = (4096, 32, 256, 512)


def _impl_kwargs(impl: str, L: int, bsz: int, window, tile) -> dict:
    kw = {} if window is None else {"window": window}
    if impl == "structured":
        kw.update(dup_len=L, block_size=bsz)
    elif impl == "pallas":
        kw.update(tq=tile, tk=tile)
    return kw


def _sft_inputs(L: int, bsz: int, *, B=1, H=4, Hkv=2, D=64, Dv=64,
                seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, L), 4, 100)
    valid = jnp.ones((B, L), bool)
    pm = jnp.broadcast_to(jnp.arange(L)[None] < bsz, (B, L))
    steps, _, _ = sample_sft_noise(key, tokens, pm, valid,
                                   block_size=bsz)
    _, meta, _ = dirl_layout(tokens, steps, valid, block_size=bsz,
                             mask_token=101, noised=True)
    T = meta.length
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, Dv))
    return q, k, v, meta


def _max_dev(a, b) -> float:
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(a, b))


def run(quick: bool = True, smoke: bool = False) -> list[str]:
    from .common import timed, write_bench_json
    from repro.kernels.ops import train_exec_plan

    rows = [",".join(ENTRY_KEYS)]
    entries: list[dict] = []
    if smoke:
        shapes = [(256, 32, 64, 64)]
    elif quick:
        shapes = [_HEADLINE]
    else:
        shapes = [(2048, 32, 256, 256), _HEADLINE]
    for L, bsz, window, tile in shapes:
        q, k, v, meta = _sft_inputs(L, bsz)
        B, T = q.shape[0], q.shape[1]
        qm = ops.pack_meta(meta)
        tm = ops.build_tile_map(qm, qm, min(tile, T), min(tile, T),
                                window=window)
        frac = ops.tile_map_stats(tm)["visit_fraction"]
        grads = {}
        for impl in _IMPLS:
            kw = _impl_kwargs(impl, L, bsz, window, tile)
            fwd = jax.jit(lambda a, b, c, kw=kw, impl=impl: ops.attention(
                a, b, c, meta, meta, impl=impl, **kw))
            t_fwd = timed(fwd, q, k, v, warmup=1, iters=3)

            def fb(a, b, c, kw=kw, impl=impl):
                def f(a, b, c):
                    o = ops.attention(a, b, c, meta, meta, impl=impl,
                                      **kw)
                    return jnp.sum(o * o)
                return jax.value_and_grad(f, argnums=(0, 1, 2))(a, b, c)
            fb_j = jax.jit(fb)
            t_fb = timed(fb_j, q, k, v, warmup=1, iters=3)
            grads[impl] = jax.tree.map(np.asarray, fb_j(q, k, v)[1])
            dev = 0.0 if impl == "structured" else _max_dev(
                grads[impl], grads["structured"])
            assert dev <= GRAD_TOL, \
                f"{impl} grad deviation {dev:.2e} > tol {GRAD_TOL}"
            plan = train_exec_plan(impl if impl != "pallas" else "pallas")
            entry = {
                "layout": "sft_dup", "L": L, "block_size": bsz,
                "window": window, "tile": tile, "impl": impl,
                "mode": plan.mode,
                "fwd_us": round(t_fwd * 1e6, 1),
                "fwd_bwd_us": round(t_fb * 1e6, 1),
                "fwd_tok_s": round(B * T / t_fwd, 1),
                "fwd_bwd_tok_s": round(B * T / t_fb, 1),
                "tile_visit_fraction": round(frac, 4),
                "grad_max_dev": float(f"{dev:.2e}"),
                "grad_tol": GRAD_TOL,
            }
            entries.append(entry)
            rows.append(",".join(str(entry[k]) for k in ENTRY_KEYS))
        # packed RL layout sparsity (context row, not timed: the same
        # kernels run it via trajectory_logprobs' packed scheme)
        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, (1, L), 4, 100)
        steps_rl = jax.random.randint(key, (1, L), 0, 4)
        _, meta_p, _, _ = packed_layout(tokens, steps_rl,
                                        jnp.ones((1, L), bool),
                                        block_size=bsz, mask_token=101,
                                        s_max=4)
        st = ops.layout_tile_stats(meta_p, tq=min(tile, meta_p.length),
                                   tk=min(tile, meta_p.length))
        rows.append(f"# rl_packed L={L} bsz={bsz} "
                    f"visit_fraction={st['visit_fraction']:.3f}")
    write_bench_json(SUITE, entries)
    # the tentpole claim, enforced on the headline shape: the
    # tile-map-sparse fwd+bwd beats structured (and ref more widely)
    by = {e["impl"]: e for e in entries
          if (e["L"], e["block_size"], e["window"], e["tile"])
          == _HEADLINE}
    if by:
        assert by["pallas"]["fwd_bwd_us"] < by["structured"]["fwd_bwd_us"] \
            < by["ref"]["fwd_bwd_us"], \
            f"pallas fwd+bwd must win at the headline shape: {by}"
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
