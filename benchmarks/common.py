"""Shared benchmark helpers: a small CPU-trainable model, quick SFT,
and the one JSON-emitting results path every suite shares.

Suites that track a perf trajectory across PRs write
``benchmarks/BENCH_<suite>.json`` via ``write_bench_json`` (one schema:
``{"suite", "schema_version", "entries": [...]}``) and CI's bench-smoke
job replays them on tiny shapes, validating the emitted schema with
``validate_bench_json`` — so a suite that silently stops emitting (or
changes shape) fails the push, not the next reader.

Observability artifacts (PR 8): ``write_trace_artifact`` /
``write_metrics_artifact`` drop Chrome-trace / metrics-JSON files into
``benchmarks/artifacts/`` (gitignored) through ``repro.obs.export``,
then immediately re-read them through the matching ``validate_*`` —
every artifact a bench emits is schema-checked at the moment it is
written, and CI's bench-smoke job uploads the directory."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.optim.adamw import AdamWConfig
from repro.sft.trainer import SFTTrainer

SEQ_LEN = 96


def bench_config(d_model=128, n_layers=2, block_size=16,
                 attn_impl="structured") -> ModelConfig:
    return ModelConfig(
        name=f"bench-{d_model}x{n_layers}", n_layers=n_layers,
        d_model=d_model, n_heads=4, n_kv_heads=2,
        head_dim=d_model // 4, d_ff=2 * d_model, vocab_size=384,
        block_size=block_size, attn_impl=attn_impl)


def quick_sft(cfg: ModelConfig, steps: int = 80, batch: int = 16,
              lr: float = 3e-3, seed: int = 0, level: int = 1):
    tok = ByteTokenizer()
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(tok, cfg.block_size, seq_len=SEQ_LEN, seed=seed,
                         level=level)
    tr = SFTTrainer(model, AdamWConfig(lr=lr, clip_norm=1.0), params)
    tr.run(ds.sft_batches(batch), steps, jax.random.PRNGKey(seed + 1),
           verbose=False)
    return model, tr.params, tok, ds


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ------------------------------------------------------- JSON results
BENCH_SCHEMA_VERSION = 1


def bench_json_path(suite: str) -> str:
    """Canonical trajectory artifact for ``suite`` (committed to git)."""
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        f"BENCH_{suite}.json")


def write_bench_json(suite: str, entries: list[dict]) -> str:
    """Write a suite's result entries through the shared schema.

    Every entry is one measured configuration (a flat dict of scalars);
    the envelope carries the suite name and schema version so the CI
    smoke job — and cross-PR trajectory diffs — can parse any suite's
    artifact the same way.  Returns the written path.
    """
    path = bench_json_path(suite)
    payload = {"suite": suite,
               "schema_version": BENCH_SCHEMA_VERSION,
               "entries": entries}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


# ------------------------------------------------- obs artifacts (PR 8)

def artifacts_dir() -> str:
    """``benchmarks/artifacts/`` — per-run trace/metrics artifacts
    (gitignored; uploaded by CI's bench-smoke job)."""
    d = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "artifacts")
    os.makedirs(d, exist_ok=True)
    return d


def write_trace_artifact(name: str, spans, metadata: dict | None = None
                         ) -> str:
    """Write + validate ``artifacts/<name>.trace.json`` (Chrome trace).

    Validation happens on the re-read file, so a schema regression in
    the exporter fails the bench run itself, not a later Perfetto
    session.  Returns the written path."""
    from repro.obs import export
    path = os.path.join(artifacts_dir(), f"{name}.trace.json")
    export.write_chrome_trace(path, spans,
                              metadata={"bench": name, **(metadata or {})})
    export.validate_chrome_trace(path)
    return path


def write_metrics_artifact(name: str, *registries) -> str:
    """Write + validate ``artifacts/<name>.metrics.json``."""
    from repro.obs import export
    path = os.path.join(artifacts_dir(), f"{name}.metrics.json")
    export.write_metrics_json(path, *registries)
    export.validate_metrics_json(path)
    return path


def validate_bench_json(suite: str, required_keys: tuple[str, ...]
                        ) -> str:
    """Assert the suite's artifact exists and matches the shared schema
    (envelope fields + ``required_keys`` present in every entry).
    Raises AssertionError with a pointed message otherwise; returns the
    validated path."""
    path = bench_json_path(suite)
    assert os.path.exists(path), f"{path} was not emitted"
    with open(path) as f:
        data = json.load(f)
    assert data.get("suite") == suite, \
        f"{path}: suite={data.get('suite')!r} != {suite!r}"
    assert data.get("schema_version") == BENCH_SCHEMA_VERSION, \
        f"{path}: schema_version {data.get('schema_version')!r}"
    entries = data.get("entries")
    assert isinstance(entries, list) and entries, \
        f"{path}: entries must be a non-empty list"
    for i, e in enumerate(entries):
        missing = [k for k in required_keys if k not in e]
        assert not missing, f"{path}: entry {i} missing {missing}"
    return path
