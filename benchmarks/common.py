"""Shared benchmark helpers: a small CPU-trainable model + quick SFT."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.optim.adamw import AdamWConfig
from repro.sft.trainer import SFTTrainer

SEQ_LEN = 96


def bench_config(d_model=128, n_layers=2, block_size=16,
                 attn_impl="structured") -> ModelConfig:
    return ModelConfig(
        name=f"bench-{d_model}x{n_layers}", n_layers=n_layers,
        d_model=d_model, n_heads=4, n_kv_heads=2,
        head_dim=d_model // 4, d_ff=2 * d_model, vocab_size=384,
        block_size=block_size, attn_impl=attn_impl)


def quick_sft(cfg: ModelConfig, steps: int = 80, batch: int = 16,
              lr: float = 3e-3, seed: int = 0, level: int = 1):
    tok = ByteTokenizer()
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(tok, cfg.block_size, seq_len=SEQ_LEN, seed=seed,
                         level=level)
    tr = SFTTrainer(model, AdamWConfig(lr=lr, clip_norm=1.0), params)
    tr.run(ds.sft_batches(batch), steps, jax.random.PRNGKey(seed + 1),
           verbose=False)
    return model, tr.params, tok, ds


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
