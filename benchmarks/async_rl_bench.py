"""Sync vs async DiPO post-training: wall-clock per update at equal
sample count (the paper's §4.2/Fig. 5b overlap claim, measured).

Both modes run the *same* fused update step (``rl.trainer
.make_dipo_step`` — one jaxpr), the same prompt stream, the same
P×G group shape and the same number of updates, so seconds-per-update
is an apples-to-apples comparison.  The synchronous ``DiPOTrainer``
alternates rollout↔update: every update waits for its batch's slowest
straggler while the freed slots sit idle (the drain tail — visible as
``idle_frac = 1 - utilization``).  The async ``rl.pipeline`` loop
admits up to K prompt batches ahead, so the pool backfills freed slots
with future batches while the current one finishes, and weight pushes
land at block boundaries without draining the pool.

The workload makes the structural difference visible on CPU: EOS-driven
ragged generation lengths (post-SFT weights, temperature 1.0) on a
single-wave pool (``n_slots = P*G``) maximise the sync drain tail, and
the ``fused_approx`` log-prob scheme keeps the update step from
drowning the rollout phase the overlap optimises.  Expected shape of
the result: K=1 recovers most of the drain tail, K=2 nearly all of it
(deeper admission window -> higher pool utilisation); the committed
trajectory point shows 1.39x (K=1) and 1.51x (K=2) per update at
equal sample count, idle fraction 0.26 -> 0.11 -> 0.05.  Numbers on a
loaded machine compress toward 1x — the idle-fraction columns are the
load-independent witness.  Off-policy
correctness rides along at zero measured cost: behaviour log-probs are
sealed only onto groups that cross a version boundary while queued
(``groups_sealed`` — 0 at steady state), and ``step_traces`` stays 1
across mixed-version batches.

Entries land in ``benchmarks/BENCH_async_rl.json`` via the shared
``common.write_bench_json`` path (CI bench-smoke validates the schema);
the async run also drops Perfetto trace + metrics artifacts into
``benchmarks/artifacts/`` — the producer/consumer lanes interleaved
with serving ticks are the picture of the overlap this suite measures.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig
from repro.rl.pipeline import AsyncDiPOTrainer
from repro.rl.trainer import DiPOConfig, DiPOTrainer
from repro.serving.engine import (EngineStats, GenerationConfig,
                                  RolloutEngine)
from repro.serving.server import ModelServer

from .common import (SEQ_LEN, bench_config, quick_sft,
                     write_bench_json, write_metrics_artifact,
                     write_trace_artifact)

ENTRY_KEYS = ("mode", "staleness_k", "updates", "prompts", "group_size",
              "samples", "wall_per_update_s", "speedup_vs_sync",
              "idle_frac", "staleness_p50", "staleness_max",
              "groups_sealed", "step_traces")


def _measure(model, params, tok, ds, *, mode, staleness_k, s_max,
             n_slots, P, G, updates, trace=False):
    """One timed trainer run: 1 warmup update (compiles), stats reset,
    ``updates`` timed updates, blocked on the final params."""
    server = ModelServer(jax.tree.map(jnp.copy, params))
    eng = RolloutEngine(model, server, GenerationConfig(
        max_len=SEQ_LEN, s_max=s_max, mode="dynamic", tau=0.7,
        temperature=1.0, cache="paged", n_slots=n_slots, trace=trace),
        tokenizer=tok)
    rl = DiPOConfig(group_size=G, logprob_scheme="fused_approx")
    opt = AdamWConfig(lr=1e-4)
    p0 = jax.tree.map(jnp.copy, params)
    if mode == "sync":
        tr = DiPOTrainer(model, eng, opt, rl, p0)
    else:
        tr = AsyncDiPOTrainer(model, eng, opt, rl, p0,
                              staleness_k=staleness_k)
    batches = ds.prompt_batches(P)
    tr.run(batches, 1, jax.random.PRNGKey(42), verbose=False)
    # the timed window runs untraced (tracing is <5% overhead, but this
    # suite reports a ratio of two close wall-clocks); the artifact is
    # captured from one extra post-timing update below
    eng.tracer.enabled = False
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    tr.run(batches, updates, jax.random.PRNGKey(43), verbose=False)
    jax.block_until_ready(jax.tree_util.tree_leaves(tr.params)[0])
    wall = time.perf_counter() - t0
    idle = 1.0 - eng.stats.utilization
    if trace:
        eng.tracer.enabled = True
        tr.run(batches, 1, jax.random.PRNGKey(44), verbose=False)

    entry = {"mode": mode, "staleness_k": staleness_k,
             "updates": updates, "prompts": P, "group_size": G,
             "samples": updates * P * G,
             "wall_per_update_s": round(wall / updates, 4),
             "idle_frac": round(idle, 4),
             "step_traces": tr._step.n_traces}
    if mode == "sync":
        entry.update(staleness_p50=0, staleness_max=0, groups_sealed=0)
    else:
        stale = tr.metrics.get("staleness")
        entry.update(
            staleness_p50=int(stale.percentile(50)),
            staleness_max=int(max(stale)) if stale.count else 0,
            groups_sealed=int(tr.metrics.get("groups_sealed").value))
    return entry, eng, tr


def run(quick: bool = True, smoke: bool = False) -> list[str]:
    if smoke:
        cfg = bench_config(d_model=64)
        sft_steps, s_max, P, G, updates = 4, 4, 2, 2, 2
    else:
        cfg = bench_config()
        sft_steps, s_max, P, G, updates = 40, 12, 4, 4, 10
    n_slots = P * G                       # single wave: max drain tail
    model, params, tok, ds = quick_sft(cfg, steps=sft_steps, batch=16)

    entries = []
    sync, _, _ = _measure(model, params, tok, ds, mode="sync",
                          staleness_k=0, s_max=s_max, n_slots=n_slots,
                          P=P, G=G, updates=updates)
    sync["speedup_vs_sync"] = 1.0
    entries.append(sync)
    for k in ((1,) if smoke else (1, 2)):
        e, eng, tr = _measure(model, params, tok, ds, mode="async",
                              staleness_k=k, s_max=s_max,
                              n_slots=n_slots, P=P, G=G,
                              updates=updates, trace=(k == 1))
        e["speedup_vs_sync"] = round(
            sync["wall_per_update_s"] / e["wall_per_update_s"], 3)
        entries.append(e)
        if k == 1:
            trace_path = write_trace_artifact(
                "async_rl", eng.tracer.snapshot(),
                metadata={"staleness_k": k, "updates": updates})
            metrics_path = write_metrics_artifact(
                "async_rl", tr.metrics, eng.stats.registry)

    path = write_bench_json("async_rl", entries)
    rows = [",".join(ENTRY_KEYS)]
    rows += [",".join(str(e[k]) for k in ENTRY_KEYS) for e in entries]
    rows.append(f"# json -> {path}")
    rows.append(f"# trace artifact -> {trace_path}")
    rows.append(f"# metrics artifact -> {metrics_path}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
