"""Serving throughput: static lock-step batching vs the slot-based
continuous batcher on a ragged mixed-length workload.

The static path (the pre-refactor engine) pads every request to the
batch width and runs the full jitted block loop to cache capacity —
sequences that hit EOS early keep re-committing frozen blocks until the
trip count drains.  The continuous path serves the same requests through
a small decode-slot pool that refills freed slots at block boundaries.
Outputs are token-identical between the two (see tests/test_scheduler),
so tokens/sec is an apples-to-apples comparison; ``utilization`` is the
fraction of paid slot-steps that advanced a live request.
"""

from __future__ import annotations

import random

import jax
import numpy as np

from repro.data.math_tasks import sample_problem
from repro.data.pipeline import pad_to_block
from repro.serving.engine import (EngineStats, GenerationConfig,
                                  RolloutEngine)
from repro.serving.server import ModelServer


def _ragged_workload(tok, block_size: int, n_req: int):
    """Mixed-difficulty prompts -> mixed prompt lengths and (after SFT)
    mixed EOS-driven generation lengths."""
    rng = random.Random(0)
    encs = []
    for i in range(n_req):
        level = 1 if i % 3 == 2 else 0
        p = sample_problem(rng, level=level).prompt
        encs.append(pad_to_block(tok.encode(p, bos=True), block_size,
                                 tok.pad_id))
    width = max(len(e) for e in encs)
    width += (-width) % block_size
    toks = np.zeros((n_req, width), np.int32)
    blocks = np.zeros((n_req,), np.int32)
    for i, e in enumerate(encs):
        toks[i, :len(e)] = e
        blocks[i] = len(e) // block_size
    return toks, blocks


def run(quick: bool = True) -> list[str]:
    from .common import bench_config, quick_sft
    cfg = bench_config()
    model, params, tok, _ = quick_sft(cfg, steps=60 if quick else 150,
                                      level=0)
    n_req = 16 if quick else 48
    max_len = 160 if quick else 256
    toks, blocks = _ragged_workload(tok, cfg.block_size, n_req)

    rows = ["batching,slots,requests,gen_tokens,wall_s,tok_per_s,"
            "denoise_steps,utilization"]
    for mode, slots in [("static", n_req), ("continuous", 4)]:
        engine = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=max_len, s_max=4, mode="dynamic", tau=0.7,
            temperature=1.0, batching=mode, n_slots=slots))
        engine.generate_ids(toks, blocks, jax.random.PRNGKey(1))  # compile
        engine.stats = EngineStats()
        engine.generate_ids(toks, blocks, jax.random.PRNGKey(2))
        s = engine.stats
        util = s.utilization if mode == "continuous" else 1.0
        rows.append(
            f"{mode},{slots},{n_req},{s.total_tokens},"
            f"{s.wall_seconds:.3f},"
            f"{s.total_tokens / max(s.wall_seconds, 1e-9):.0f},"
            f"{s.total_steps},{util:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
