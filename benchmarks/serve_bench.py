"""Serving throughput: static lock-step batching vs the slot-based
continuous batcher, and dense vs paged KV layouts at equal cache memory.

Section 1 (static vs continuous): the static path (the pre-refactor
engine) pads every request to the batch width and runs the full jitted
block loop to cache capacity — sequences that hit EOS early keep
re-committing frozen blocks until the batch drains.  The continuous path
serves the same requests through a small decode-slot pool that refills
freed slots at block boundaries.  Outputs are token-identical between
the two (see tests/test_scheduler), so tokens/sec is an
apples-to-apples comparison; ``utilization`` is the fraction of paid
slot-steps that advanced a live request.

Section 2 (dense vs paged): same KV budget — the paged pool gets exactly
the pages a 4-slot dense pool would reserve (``4 * n_blocks + 1``) but
three times the slots.  Requests carry a realistic per-request block
budget, so the paged scheduler's reservation-based admission packs more
concurrent requests into the same memory (``peak_active``), while dense
concurrency stays capped at 4 by worst-case-length slot regions.
Tokens are byte-identical across the two layouts; ``gen_tokens`` counts
to the first EOS inclusive.

Section 3 (prefix cache on vs off, and the admission KV layout): the
DiPO-shaped group-rollout workload — N prompts x G=8 trajectories each,
the exact shape ``rl.trainer`` submits — on equal paged pools.  With
the shared-prefix index on, each group's first member prefills and
registers the prompt's pages and the others map them straight into
their block tables: ``prefill_blocks`` drops (the admission-cost
saving) and ``peak_pages_live`` — pages referenced by live slots —
drops by nearly the duplicated-prompt footprint (the memory saving).
Odd members carry one divergent tail block, so their admissions are
*partial* hits that pay a suffix prefill; the prefix-on pool then runs
under both admission KV layouts — ``kernel="ref"`` gathers the hit
prefix into a dense-width copy per admission
(``admit_transient_kv_bytes`` > 0, asserted) while ``kernel="pallas"``
streams it in place (asserted exactly 0).  Tokens are byte-identical
across all three runs (asserted here, pinned in
tests/test_prefix_cache.py and tests/test_paged_attn.py).

Section 4 (mixed SamplingParams, the §4.2 heterogeneous-traffic
workload): requests round-robin over four per-request configurations —
different τ, temperature, mode and block budgets — through ONE paged
pool.  The per-row parameter vectors mean the pool's jitted advance is
traced exactly once for the whole mix (asserted: ``n_advance_traces``
stays 1 after warmup), and each request's tokens are byte-identical to
a homogeneous pool running only its configuration (asserted per row).
Reported: throughput, admit→finish latency p50/p95 in ticks, and the
trace count — the "no retrace, no rebuild" property the old
one-engine-per-τ sweep paid for.

Section 2b (tracing overhead): the §2 paged configuration drained on
one warmed scheduler, median of 3 drains per tracer mode (pair-
interleaved, alternating order, so warmup drift cancels), byte-parity
asserted between the drains.  Tracing is host-side bookkeeping around
already-asynchronous dispatches, so the tok/s delta must stay within
noise (target < 5%, printed); the traced drain's spans become the
``serve_equal_mem`` Chrome-trace artifact.  §3's prefix-on/pallas run
and §4's mixed drain also record, so ``benchmarks/artifacts/`` ends up
with one Perfetto-loadable lifecycle trace per structurally distinct
workload — group rollouts with prefix-hit labels, mixed params with
per-request SamplingParams on one pool — each schema-validated at
write time (``common.write_trace_artifact``).

Section 5 (decode KV layout: gather vs in-place): the same paged pools
as §2 (equal-memory ragged workload) and §3 (G=8 group rollouts,
prefix-shared pages) run once with ``kernel="ref"`` — ``paged_gather``
materializes a dense-width K/V copy per layer per tick — and once with
``kernel="pallas"`` — the page-aware kernel reads the pool in place.
Tokens are byte-identical between the two (asserted; pinned in
tests/test_paged_attn.py); the structurally meaningful number is
``transient_kv_bytes`` — the per-tick K/V copy the layout pays, which
drops to 0 in place (off-TPU the kernel runs interpreted, so CPU
wall-clock is a correctness harness, not the speed story — same caveat
as kernel_bench).
"""

from __future__ import annotations

import random
import time

import jax
import numpy as np

from repro.data.math_tasks import sample_problem
from repro.data.pipeline import pad_to_block
from repro.obs.metrics import Histogram
from repro.serving.api import SamplingParams
from repro.serving.engine import (EngineStats, GenerationConfig,
                                  RolloutEngine)
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import ModelServer

from .common import write_metrics_artifact, write_trace_artifact


def _ragged_workload(tok, block_size: int, n_req: int):
    """Mixed-difficulty prompts -> mixed prompt lengths and (after SFT)
    mixed EOS-driven generation lengths."""
    rng = random.Random(0)
    encs = []
    for i in range(n_req):
        level = 1 if i % 3 == 2 else 0
        p = sample_problem(rng, level=level).prompt
        encs.append(pad_to_block(tok.encode(p, bos=True), block_size,
                                 tok.pad_id))
    width = max(len(e) for e in encs)
    width += (-width) % block_size
    toks = np.zeros((n_req, width), np.int32)
    blocks = np.zeros((n_req,), np.int32)
    for i, e in enumerate(encs):
        toks[i, :len(e)] = e
        blocks[i] = len(e) // block_size
    return toks, blocks


def _drain_sched(params, sched, toks, blocks, keys, budget):
    for i in range(toks.shape[0]):
        sched.submit(toks[i], int(blocks[i]), keys[i],
                     max_new_blocks=budget)
    t0 = time.perf_counter()
    comps = list(sched.run(params))
    return comps, time.perf_counter() - t0


def _paged_vs_dense(model, params, toks, blocks, max_len, budget):
    """Same requests, same keys, equal KV memory: dense 4 slots vs a
    paged pool holding the dense pool's pages but 3x the slots."""
    cfg = model.cfg
    K = max_len // cfg.block_size
    dense_slots = 4
    n_pages = dense_slots * K + 1
    keys = jax.random.split(jax.random.PRNGKey(3), toks.shape[0])
    rows = []
    ref = None
    for cache, slots in [("dense", dense_slots),
                         ("paged", 3 * dense_slots)]:
        kw = dict(cache=cache)
        if cache == "paged":
            kw["n_pages"] = n_pages
        sched = SlotScheduler(
            model, n_slots=slots, max_len=max_len, s_max=4,
            mode="dynamic", tau=0.7, temperature=1.0, eos_id=1, **kw)
        # warm the jit caches on the same instance, then reset stats
        _drain_sched(params, sched, toks, blocks, keys, budget)
        sched.stats = type(sched.stats)()
        comps, dt = _drain_sched(params, sched, toks, blocks, keys,
                                 budget)
        got = {c.uid: c for c in comps}
        if ref is None:
            ref = got
        else:  # layouts must agree token-for-token
            for uid, c in ref.items():
                hi = (c.prompt_blocks + c.gen_blocks) * cfg.block_size
                np.testing.assert_array_equal(c.tokens[:hi],
                                              got[uid].tokens[:hi])
        s = sched.stats
        kv_blocks = dense_slots * K if cache == "dense" else n_pages - 1
        rows.append(
            f"{cache},{slots},{kv_blocks},{len(comps)},{s.gen_tokens},"
            f"{dt:.3f},{s.gen_tokens / max(dt, 1e-9):.0f},{s.ticks},"
            f"{s.peak_active},{s.utilization:.3f},"
            f"{s.peak_pages_in_use},{s.deferred}")
    return rows


def _trace_overhead(model, params, toks, blocks, max_len, budget):
    """§2b: the §2 paged pool drained tracer-off vs tracer-on on the
    same warmed instance, byte-parity asserted — the lifecycle tracer
    must be free (host-side appends around async dispatches; target
    < 5% tok/s).  The last traced drain's spans become the
    ``serve_equal_mem`` Chrome-trace artifact."""
    cfg = model.cfg
    K = max_len // cfg.block_size
    keys = jax.random.split(jax.random.PRNGKey(3), toks.shape[0])
    sched = SlotScheduler(
        model, n_slots=12, max_len=max_len, s_max=4, mode="dynamic",
        tau=0.7, temperature=1.0, eos_id=1, cache="paged",
        n_pages=4 * K + 1, trace=True)
    sched.tracer.enabled = False
    _drain_sched(params, sched, toks, blocks, keys, budget)   # warm jits
    rows, rates, n_spans, ref = [], {False: [], True: []}, 0, None
    # the delta being measured is a few host-side deque appends per
    # tick, far below single-drain CPU noise — so take the median of 3
    # drains per mode, pair-interleaved with alternating order so
    # residual warmup drift cancels instead of biasing one mode
    for pair in ((False, True), (True, False), (False, True)):
        for traced in pair:
            sched.tracer.enabled = traced
            sched.tracer.clear()
            sched.stats = type(sched.stats)()
            comps, dt = _drain_sched(params, sched, toks, blocks, keys,
                                     budget)
            got = {c.uid % toks.shape[0]: c for c in comps}
            if ref is None:
                ref = got
            else:  # tracing must not change a byte
                for uid, c in ref.items():
                    hi = (c.prompt_blocks + c.gen_blocks) \
                        * cfg.block_size
                    np.testing.assert_array_equal(c.tokens[:hi],
                                                  got[uid].tokens[:hi])
            s = sched.stats
            rates[traced].append(s.gen_tokens / max(dt, 1e-9))
            if traced:
                n_spans = len(sched.tracer)
    med = {t: float(np.median(rs)) for t, rs in rates.items()}
    for traced in (False, True):
        rows.append(f"{'on' if traced else 'off'},{toks.shape[0]},"
                    f"{sched.stats.gen_tokens},{med[traced]:.0f},"
                    f"{n_spans if traced else 0}")
    ovh = (med[False] - med[True]) / max(med[False], 1e-9) * 100
    rows.append(f"# tracing overhead {ovh:+.1f}% tok/s (target < 5%)")
    path = write_trace_artifact(
        "serve_equal_mem", sched.tracer.snapshot(),
        metadata={"section": "2b", "workload": "equal_mem_paged"})
    rows.append(f"# trace artifact -> {path}")
    return rows


def _group_rollout(model, params, tok, max_len, *, n_prompts, G, budget):
    """N prompts x G rollouts each (DiPO groups), prefix cache off vs on
    at equal pool size, and on across admission KV layouts.  Odd group
    members extend their prompt by one divergent block, so with the
    index on their admissions take the partial-hit *suffix prefill*
    path — the admission-time prefix gather the in-place prefill kernel
    eliminates.  Counter-based (no timing flakiness): prefill steps
    paid, prompt blocks served from shared pages, the live-page peak a
    retention-free pool would need, and the peak admission gather
    (``admit_transient_kv_bytes`` — asserted > 0 for the gathered
    ``kernel="ref"`` layout and exactly 0 for ``kernel="pallas"``)."""
    cfg = model.cfg
    bsz = cfg.block_size
    toks, blocks = _ragged_workload(tok, bsz, n_prompts)
    # one divergent extra block per prompt (a shifted copy of its first
    # block — any tokens that don't extend the registered chain)
    etoks = np.zeros((n_prompts, toks.shape[1] + bsz), np.int32)
    etoks[:, :toks.shape[1]] = toks
    for p in range(n_prompts):
        lo = int(blocks[p]) * bsz
        etoks[p, lo:lo + bsz] = (toks[p, :bsz] + 1) % 250
    keys = jax.random.split(jax.random.PRNGKey(5), n_prompts * G)
    n_slots = 2 * G
    n_pages = n_slots * (int(blocks.max()) + 1 + budget) + 1
    rows = []
    ref = None
    for pc, kernel in ((False, "ref"), (True, "ref"), (True, "pallas")):
        sched = SlotScheduler(
            model, n_slots=n_slots, max_len=max_len, s_max=4,
            mode="dynamic", tau=0.7, temperature=1.0, eos_id=1,
            cache="paged", n_pages=n_pages, prefix_cache=pc,
            kernel=kernel, trace=(pc and kernel == "pallas"))
        # group members adjacent, exactly as generate_group_ids submits;
        # odd members carry the divergent tail block (partial hits)
        for i in range(n_prompts * G):
            p = i // G
            if i % 2:
                sched.submit(etoks[p], int(blocks[p]) + 1, keys[i],
                             max_new_blocks=budget)
            else:
                sched.submit(toks[p], int(blocks[p]), keys[i],
                             max_new_blocks=budget)
        comps = {c.uid: c for c in sched.run(params)}
        if ref is None:
            ref = comps
        else:  # prefix sharing / kernel choice must not change a byte
            for uid, c in ref.items():
                hi = (c.prompt_blocks + c.gen_blocks) * cfg.block_size
                np.testing.assert_array_equal(c.tokens[:hi],
                                              comps[uid].tokens[:hi])
        s = sched.stats
        if pc:  # the admission gather exists iff the layout gathers
            assert (s.admit_transient_kv_bytes > 0) == (kernel == "ref"), \
                (kernel, s.admit_transient_kv_bytes)
        rows.append(
            f"{'on' if pc else 'off'},{kernel},{n_prompts},{G},"
            f"{n_pages - 1},{len(comps)},{s.prefill_blocks},"
            f"{s.prefix_hit_blocks},{s.shared_pages},{s.peak_pages_live},"
            f"{s.peak_pages_in_use},{s.ticks},{s.gen_tokens},"
            f"{s.admit_transient_kv_bytes}")
        if sched.tracer.enabled:
            # lifecycle spans must carry the labels the analysis
            # depends on: prefix-hit path on admission, kernel mode on
            # the decode span (the artifact consumer's contract)
            decode = [sp for sp in sched.tracer.snapshot()
                      if sp.cat == "request"
                      and sp.track.startswith("slot")]
            assert decode and all(
                "kernel_mode" in sp.args and "hit_blocks" in sp.args
                for sp in decode), "missing lifecycle labels"
            assert any(sp.args["hit_blocks"] > 0 for sp in decode), \
                "no prefix-hit admissions in a prefix-on group rollout"
            path = write_trace_artifact(
                "serve_group_rollout", sched.tracer.snapshot(),
                metadata={"section": "3", "G": G, "kernel": kernel})
            rows.append(f"# trace artifact -> {path}")
    return rows


def _mixed_params(model, params, toks, blocks, max_len):
    """§4: heterogeneous traffic on one pool — requests cycle over four
    SamplingParams (τ / temperature / mode / budget all differ); assert
    one advance trace for the whole mix and per-request byte-parity
    with homogeneous pools; report latency percentiles."""
    cfg = model.cfg
    n_req = toks.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(7), n_req)
    configs = [
        SamplingParams(tau=0.5, temperature=1.0, max_new_blocks=2),
        SamplingParams(tau=0.9, temperature=1.0, max_new_blocks=4),
        SamplingParams(tau=0.99, temperature=0.0, max_new_blocks=3),
        SamplingParams(mode="static", n_steps=4, temperature=1.0,
                       max_new_blocks=3),
    ]

    def drain(sched, param_for):
        for i in range(n_req):
            sched.submit(toks[i], int(blocks[i]), keys[i],
                         params=param_for(i))
        t0 = time.perf_counter()
        comps = {c.uid: c for c in sched.run(params)}
        return comps, time.perf_counter() - t0

    def fresh():
        return SlotScheduler(model, n_slots=4, max_len=max_len, s_max=4,
                             eos_id=1, cache="paged")

    # warm + measure on ONE instance: the warm drain pays the single
    # advance trace, the mixed measured drain must add zero — with the
    # lifecycle tracer recording (tracing must not retrace either)
    sched = fresh()
    mix_cfg = lambda i: configs[i % len(configs)]
    drain(sched, mix_cfg)
    sched.tracer.enabled = True
    sched.stats = type(sched.stats)()
    mixed, dt = drain(sched, mix_cfg)
    assert sched.n_advance_traces == 1, sched.n_advance_traces
    trace_path = write_trace_artifact(
        "serve_mixed_params", sched.tracer.snapshot(),
        metadata={"section": "4", "n_configs": len(configs)})
    metrics_path = write_metrics_artifact("serve_mixed_params",
                                          sched.stats.registry)
    sched.tracer.enabled = False
    # per-request parity: a homogeneous pool running only config c
    # produces the same bytes for the rows that used c in the mix.
    # uids restart at 0 per drain, so mixed uids live on [n_req, 2n_req)
    for ci, sp in enumerate(configs):
        homo, _ = drain(fresh(), lambda i: sp)
        for uid, c in mixed.items():
            i = uid - n_req          # submission index of this request
            if i % len(configs) != ci:
                continue
            h = homo[i]
            assert c.gen_blocks == h.gen_blocks
            hi = (c.prompt_blocks + c.gen_blocks) * cfg.block_size
            np.testing.assert_array_equal(c.tokens[:hi], h.tokens[:hi])
    # quantiles through the obs Histogram — the same reservoir
    # estimator the engine's latency gauges use, so the bench reports
    # what a live deployment's metrics endpoint would
    lat = Histogram("mixed_latency_ticks", "admit->finish latency",
                    reservoir=4096)
    for c in mixed.values():
        lat.observe(c.latency_ticks)
    s = sched.stats
    return [f"mixed4,{n_req},{s.gen_tokens},{dt:.3f},"
            f"{s.gen_tokens / max(dt, 1e-9):.0f},{s.ticks},"
            f"{lat.percentile(50):.0f},{lat.percentile(95):.0f},"
            f"{lat.percentile(99):.0f},{sched.n_advance_traces}",
            f"# trace artifact -> {trace_path}",
            f"# metrics artifact -> {metrics_path}"]


def _kernel_layouts(model, params, tok, toks, blocks, max_len, budget,
                    *, n_prompts, G):
    """§5: gathered fallback vs in-place kernel on the §2 equal-memory
    workload and the §3 G-group workload; byte-parity asserted, decode
    wall/tick latency and the per-tick transient KV copy reported."""
    cfg = model.cfg
    K = max_len // cfg.block_size
    gtoks, gblocks = _ragged_workload(tok, cfg.block_size, n_prompts)
    gkeys = jax.random.split(jax.random.PRNGKey(5), n_prompts * G)
    keys = jax.random.split(jax.random.PRNGKey(3), toks.shape[0])
    rows = []
    for workload in ("equal_mem", f"group_G{G}"):
        ref = None
        for kernel in ("ref", "pallas"):
            if workload == "equal_mem":
                sched = SlotScheduler(
                    model, n_slots=12, max_len=max_len, s_max=4,
                    mode="dynamic", tau=0.7, temperature=1.0, eos_id=1,
                    cache="paged", n_pages=4 * K + 1, prefix_cache=False,
                    kernel=kernel)
                submit = [(toks[i], int(blocks[i]), keys[i])
                          for i in range(toks.shape[0])]
            else:
                n_slots = 2 * G
                sched = SlotScheduler(
                    model, n_slots=n_slots, max_len=max_len, s_max=4,
                    mode="dynamic", tau=0.7, temperature=1.0, eos_id=1,
                    cache="paged", kernel=kernel,
                    n_pages=n_slots * (int(gblocks.max()) + budget) + 1)
                submit = [(gtoks[i // G], int(gblocks[i // G]), gkeys[i])
                          for i in range(n_prompts * G)]
            # warm the jit/kernel caches, then measure a fresh drain
            for t, b, k in submit:
                sched.submit(t, b, k, max_new_blocks=budget)
            list(sched.run(params))
            sched.stats = type(sched.stats)()
            for t, b, k in submit:
                sched.submit(t, b, k, max_new_blocks=budget)
            t0 = time.perf_counter()
            comps = {c.uid: c for c in sched.run(params)}
            dt = time.perf_counter() - t0
            if ref is None:
                ref = comps
            else:   # layouts must agree token-for-token
                for uid, c in ref.items():
                    hi = (c.prompt_blocks + c.gen_blocks) * cfg.block_size
                    np.testing.assert_array_equal(
                        c.tokens[:hi], comps[uid].tokens[:hi])
            s = sched.stats
            rows.append(
                f"{workload},{kernel},{len(comps)},{s.gen_tokens},"
                f"{dt:.3f},{dt / max(s.ticks, 1) * 1e3:.1f},{s.ticks},"
                f"{s.transient_kv_bytes}")
    return rows


def run(quick: bool = True, smoke: bool = False) -> list[str]:
    from .common import bench_config, quick_sft
    cfg = bench_config()
    # smoke (CI bench-smoke): tiniest shapes that still exercise every
    # section — the point is artifact schema validation, not numbers
    model, params, tok, _ = quick_sft(
        cfg, steps=20 if smoke else (60 if quick else 150), level=0)
    n_req = 8 if smoke else (16 if quick else 48)
    max_len = 160 if quick else 256
    toks, blocks = _ragged_workload(tok, cfg.block_size, n_req)

    rows = ["batching,slots,requests,gen_tokens,wall_s,tok_per_s,"
            "denoise_steps,utilization"]
    for mode, slots in [("static", n_req), ("continuous", 4)]:
        engine = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=max_len, s_max=4, mode="dynamic", tau=0.7,
            temperature=1.0, batching=mode, n_slots=slots))
        engine.generate_ids(toks, blocks, jax.random.PRNGKey(1))  # compile
        engine.stats = EngineStats()
        engine.generate_ids(toks, blocks, jax.random.PRNGKey(2))
        s = engine.stats
        util = s.utilization if mode == "continuous" else 1.0
        rows.append(
            f"{mode},{slots},{n_req},{s.total_tokens},"
            f"{s.wall_seconds:.3f},"
            f"{s.total_tokens / max(s.wall_seconds, 1e-9):.0f},"
            f"{s.total_steps},{util:.3f}")

    rows.append("cache,slots,kv_blocks,requests,gen_tokens,wall_s,"
                "tok_per_s,ticks,peak_active,utilization,"
                "peak_pages,deferred")
    budget = 3 if quick else 4          # response cap in blocks
    rows += _paged_vs_dense(model, params, toks, blocks, max_len, budget)

    rows.append("tracing,requests,gen_tokens,tok_per_s_med3,spans")
    rows += _trace_overhead(model, params, toks, blocks, max_len, budget)

    rows.append("prefix,kernel,prompts,G,pool_pages,requests,"
                "prefill_blocks,hit_blocks,shared_pages,peak_pages_live,"
                "peak_pages,ticks,gen_tokens,admit_transient_kv_bytes")
    rows += _group_rollout(model, params, tok, max_len,
                           n_prompts=2 if smoke else (4 if quick else 8),
                           G=4 if smoke else 8, budget=budget)

    rows.append("mix,requests,gen_tokens,wall_s,tok_per_s,ticks,"
                "latency_p50,latency_p95,latency_p99,advance_traces")
    rows += _mixed_params(model, params, toks, blocks, max_len)

    rows.append("workload,kernel,requests,gen_tokens,wall_s,ms_per_tick,"
                "ticks,transient_kv_bytes")
    rows += _kernel_layouts(model, params, tok, toks, blocks, max_len,
                            budget, n_prompts=2 if quick else 4,
                            G=8)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
