"""Table 1 analogue: task accuracy / decode efficiency of the two-stage
post-trained model on held-out synthetic math.

Columns mirror the paper's cells: accuracy, avg tokens revealed per
denoise step, avg output length — for static and dynamic (tau) decoding,
comparing the base (untrained), SFT, and SFT+DiPO checkpoints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.data.math_tasks import check_answer
from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer


def evaluate(model, params, tok: ByteTokenizer, *, n_problems=32,
             mode="dynamic", tau=0.9, s_max=8, seed=123, level=1,
             max_len=96) -> dict:
    ds = MathTaskDataset(tok, model.cfg.block_size, seq_len=max_len,
                         seed=seed, level=level)
    pb = next(ds.prompt_batches(n_problems))
    gen = decoding.generate(
        model, params, jnp.asarray(pb.prompt_tokens),
        jnp.asarray(pb.prompt_blocks), jax.random.PRNGKey(seed),
        max_len=max_len, s_max=s_max, mode=mode, tau=tau,
        n_steps=s_max, temperature=0.0, eos_id=tok.eos_id)
    toks = np.asarray(gen["tokens"])
    steps = np.asarray(gen["steps"])
    pbk = np.asarray(gen["prompt_blocks"])
    gbk = np.asarray(gen["gen_blocks"])
    bsz = model.cfg.block_size
    acc, tps, lens = [], [], []
    for i in range(n_problems):
        lo, hi = pbk[i] * bsz, (pbk[i] + gbk[i]) * bsz
        text = tok.decode(toks[i, lo:hi])
        acc.append(float(check_answer(text, int(pb.answers[i]))))
        denoise_steps = sum(steps[i, k * bsz:(k + 1) * bsz].max() + 1
                            for k in range(pbk[i], pbk[i] + gbk[i]))
        n_tok = hi - lo
        tps.append(n_tok / max(denoise_steps, 1))
        lens.append(float(n_tok))
    return {"acc": float(np.mean(acc)),
            "tokens_per_step": float(np.mean(tps)),
            "out_len": float(np.mean(lens))}


def run(quick: bool = True) -> list[str]:
    from .common import bench_config, quick_sft
    from repro.models.model import BlockDiffLM
    from repro.optim.adamw import AdamWConfig
    from repro.rl.trainer import DiPOTrainer, DiPOConfig
    from repro.serving.engine import RolloutEngine, GenerationConfig
    from repro.serving.server import ModelServer

    cfg = bench_config()
    n = 32 if quick else 64
    sft_steps = 200 if quick else 400
    rl_steps = 4 if quick else 12

    tok = ByteTokenizer()
    base_model = BlockDiffLM(cfg)
    base_params = base_model.init(jax.random.PRNGKey(0))

    model, sft_params, tok, ds = quick_sft(cfg, steps=sft_steps, level=0)

    # DiPO stage on top of SFT
    server = ModelServer(jax.tree.map(jnp.copy, sft_params))
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=96, s_max=4, mode="dynamic", tau=0.7, temperature=1.0))
    rl = DiPOTrainer(model, engine, AdamWConfig(lr=1e-4),
                     DiPOConfig(group_size=8, beta=0.05,
                                logprob_scheme="packed"), server.params)
    rl.run(ds.prompt_batches(8), rl_steps, jax.random.PRNGKey(5),
           verbose=False)
    rl_params = rl.params

    rows = ["model,decoding,acc,tokens_per_step,out_len"]
    for name, prm in [("base", base_params), ("sft", sft_params),
                      ("sft+dipo", rl_params)]:
        for mode, tau in [("static", 0.0), ("dynamic", 0.9)]:
            m = evaluate(base_model, prm, tok, n_problems=n, mode=mode,
                         tau=tau, level=0)
            rows.append(f"{name},{mode},{m['acc']:.3f},"
                        f"{m['tokens_per_step']:.2f},{m['out_len']:.0f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
