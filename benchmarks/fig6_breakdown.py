"""Fig. 6 analogue: per-RL-step wall-clock breakdown.

Compares the DiRL design against the pre-DiRL loop on the same hardware:

  * rollout            — blockwise engine generation (shared backend,
                         modest delta, as the paper observes);
  * logits+train       — DiPO update using (a) the fused one-pass packed
                         layout vs (b) sequential per-step replay (the
                         no-FlexAttention baseline);
  * weight update      — (a) in-place server push vs (b) the Fig. 5a
                         checkpoint round-trip (1 save + reload on next
                         rollout).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig
from repro.rl.trainer import DiPOTrainer, DiPOConfig
from repro.serving.engine import RolloutEngine, GenerationConfig
from repro.serving.server import ModelServer, OfflineWeightStore


def run(quick: bool = True) -> list[str]:
    from .common import bench_config, quick_sft
    cfg = bench_config()
    steps = 2 if quick else 6
    model, params, tok, ds = quick_sft(cfg, steps=60 if quick else 150,
                                       level=0)
    rows = ["setup,phase,seconds_per_step"]

    for setup, store_cls, scheme in [
            ("dirl(fused+inplace)", ModelServer, "packed"),
            ("baseline(replay+offline)", OfflineWeightStore, "replay")]:
        store = store_cls(jax.tree.map(jnp.copy, params))
        engine = RolloutEngine(model, store, GenerationConfig(
            max_len=96, s_max=4, mode="dynamic", tau=0.7, temperature=1.0))
        tr = DiPOTrainer(model, engine, AdamWConfig(lr=5e-5),
                         DiPOConfig(group_size=4, logprob_scheme=scheme),
                         store.params)
        tr.run(ds.prompt_batches(4), steps + 1, jax.random.PRNGKey(7),
               verbose=False)
        t = tr.timings[1:]  # drop compile step
        roll = float(np.mean([x["rollout_s"] for x in t]))
        train = float(np.mean([x["train_s"] for x in t]))
        upd = float(np.mean([x["update_s"] for x in t]))
        if store_cls is OfflineWeightStore:
            upd += store.load_seconds  # reload paid at next rollout
        rows += [f"{setup},rollout,{roll:.3f}",
                 f"{setup},logits+train,{train:.3f}",
                 f"{setup},weight_update,{upd:.4f}"]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
