"""Microbench: paged-decode attention — gathered fallback vs in-place.

Raw-kernel counterpart of serve_bench §5 (no model, no scheduler): one
decode step of current-block queries against a shared KV page pool, at
growing pool widths.  Two numbers per shape:

* ``us_per_call`` — wall-clock of the jitted layout (CPU caveat: the
  Pallas path runs under ``interpret=True`` off-TPU, so its CPU time is
  a correctness harness, not the speed story — identical caveat to
  kernel_bench's interpret-mode rows);
* ``transient_kv_bytes`` — the per-call K/V copy the layout
  materializes outside the resident pool.  This is the structurally
  meaningful column: the gather scales with slots x K*bsz while the
  in-place kernel stays at 0, which is the capacity headroom the
  page-aware kernel buys at serving scale.

Max-abs deviation between the two layouts is reported per shape
(f32 flash-vs-plain-softmax rounding; token-level byte parity is
pinned in tests/test_paged_attn.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A


def _setup(key, *, B, K, Hkv, Dk, Dv, bsz):
    """Random pool + a ragged table (per-row mapped block counts drawn
    uniformly from [1, K], trailing blocks -1), limits mid-run."""
    P = B * K + 1
    ks = jax.random.split(key, 5)
    cache = A.PagedAttnCache(
        k=jax.random.normal(ks[0], (P, bsz, Hkv, Dk), jnp.float32),
        v=jax.random.normal(ks[1], (P, bsz, Hkv, Dv), jnp.float32),
        pos=jnp.asarray(
            np.arange(P * bsz).reshape(P, bsz) % (K * bsz), jnp.int32))
    rs = np.random.RandomState(0)
    table = np.full((B, K), -1, np.int64)
    perm = rs.permutation(P - 1) + 1          # never the null page
    t = 0
    for b in range(B):
        kb = rs.randint(1, K + 1)
        table[b, :kb] = perm[t:t + kb]
        t += kb
    blk = rs.randint(1, K, (B,))
    positions = blk[:, None] * bsz + np.arange(bsz)[None, :]
    limit = blk * bsz
    k_self = jax.random.normal(ks[2], (B, bsz, Hkv, Dk), jnp.float32)
    v_self = jax.random.normal(ks[3], (B, bsz, Hkv, Dv), jnp.float32)
    q = jax.random.normal(ks[4], (B, bsz, 4 * Hkv, Dk), jnp.float32)
    return (cache, jnp.asarray(table, jnp.int32), k_self, v_self,
            jnp.asarray(positions, jnp.int32),
            jnp.asarray(limit, jnp.int32), q)


def run(quick: bool = True) -> list[str]:
    from .common import timed
    rows = ["kernel,slots,K,bsz,Hkv,Dk,us_per_call,transient_kv_bytes,"
            "max_abs_dev"]
    shapes = [dict(B=8, K=8, Hkv=2, Dk=32, Dv=32, bsz=16)]
    if not quick:
        shapes += [dict(B=16, K=16, Hkv=2, Dk=64, Dv=64, bsz=32),
                   dict(B=8, K=16, Hkv=1, Dk=72, Dv=64, bsz=32)]  # MLA
    for sh in shapes:
        args = _setup(jax.random.PRNGKey(0), **sh)
        cache, table = args[0], args[1]
        kw = dict(scale=sh["Dk"] ** -0.5, softcap=None, window=None)
        outs = {}
        for kernel in ("ref", "pallas"):
            layout = A.resolve_kv_layout(cache, kernel)
            fn = jax.jit(lambda q, c, t, ksf, vsf, pos, lim, _l=layout:
                         _l.attend(q, ksf, vsf, pos, c, block_table=t,
                                   cache_limit=lim, **kw))
            cache_, table_, ksf, vsf, pos, lim, q = args
            t = timed(lambda: fn(q, cache_, table_, ksf, vsf, pos, lim),
                      warmup=1, iters=3)
            outs[kernel] = fn(q, cache_, table_, ksf, vsf, pos, lim)
            tb = A.transient_kv_bytes(cache, sh["B"], sh["K"], kernel)
            dev = 0.0 if kernel == "ref" else float(
                jnp.abs(outs["pallas"] - outs["ref"]).max())
            rows.append(
                f"{kernel},{sh['B']},{sh['K']},{sh['bsz']},{sh['Hkv']},"
                f"{sh['Dk']},{t * 1e6:.0f},{tb},{dev:.2e}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
