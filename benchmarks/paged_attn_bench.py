"""Microbench: the paged-attention kernel family — gathered vs in-place.

Raw-kernel counterpart of serve_bench §3/§5 (no model, no scheduler),
covering both passes the family serves:

* ``decode``  — one denoise step of current-block queries against a
  shared KV page pool (ragged per-row block counts, mid-run limits);
* ``prefill`` — one shared-prefix suffix prefill: plain-mode suffix
  queries against (hit-prefix pages ++ suffix self keys), the
  admission-time pass.

Three numbers per (pass, shape, kernel):

* ``us_per_call`` / ``tok_s`` — wall-clock of the jitted layout (CPU
  caveat: the Pallas path runs under ``interpret=True`` off-TPU, so its
  CPU time is a correctness harness, not the speed story — the ``mode``
  column says which path actually ran and why);
* ``transient_kv_bytes`` — the per-call K/V copy the layout
  materializes outside the resident pool.  This is the structurally
  meaningful column: the decode gather scales with slots x K*bsz and
  the prefill gather with the hit-prefix width, while the in-place
  kernels stay at 0 — the capacity headroom the page-aware family buys
  at serving scale.

Results flow through the shared ``common.write_bench_json`` path into
``benchmarks/BENCH_paged_attn.json`` (the cross-PR perf trajectory,
validated by CI's bench-smoke job); the returned CSV rows are the
human-readable view of the same entries.

Max-abs deviation between the two layouts is reported per shape (f32
flash-vs-plain-softmax rounding on decode; 0.0 expected on prefill,
where the in-place kernel replays the reference chunk walk — token- and
byte-level parity is pinned in tests/test_paged_attn.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import SeqMeta
from repro.kernels.paged_attn import plan_exec
from repro.models import attention as A

ENTRY_KEYS = ("pass", "kernel", "B", "K", "bsz", "Hkv", "Dk", "Dv",
              "us_per_call", "tok_s", "transient_kv_bytes", "mode",
              "mode_reason", "max_abs_dev")


def _decode_setup(key, *, B, K, Hkv, Dk, Dv, bsz):
    """Random pool + a ragged table (per-row mapped block counts drawn
    uniformly from [1, K], trailing blocks -1), limits mid-run."""
    P = B * K + 1
    ks = jax.random.split(key, 5)
    cache = A.PagedAttnCache(
        k=jax.random.normal(ks[0], (P, bsz, Hkv, Dk), jnp.float32),
        v=jax.random.normal(ks[1], (P, bsz, Hkv, Dv), jnp.float32),
        pos=jnp.asarray(
            np.arange(P * bsz).reshape(P, bsz) % (K * bsz), jnp.int32))
    rs = np.random.RandomState(0)
    table = np.full((B, K), -1, np.int64)
    perm = rs.permutation(P - 1) + 1          # never the null page
    t = 0
    for b in range(B):
        kb = rs.randint(1, K + 1)
        table[b, :kb] = perm[t:t + kb]
        t += kb
    blk = rs.randint(1, K, (B,))
    positions = blk[:, None] * bsz + np.arange(bsz)[None, :]
    limit = blk * bsz
    k_self = jax.random.normal(ks[2], (B, bsz, Hkv, Dk), jnp.float32)
    v_self = jax.random.normal(ks[3], (B, bsz, Hkv, Dv), jnp.float32)
    q = jax.random.normal(ks[4], (B, bsz, 4 * Hkv, Dk), jnp.float32)
    return (cache, jnp.asarray(table, jnp.int32), k_self, v_self,
            jnp.asarray(positions, jnp.int32),
            jnp.asarray(limit, jnp.int32), q)


def _prefill_setup(key, *, B, K, Ts, Hkv, Dk, Dv, bsz):
    """Shared-prefix suffix prefill: every row has K fully-hit prefix
    pages (sequential positions) and a Ts-block suffix to commit."""
    P = B * K + 1
    ks = jax.random.split(key, 6)
    cache = A.PagedAttnCache(
        k=jax.random.normal(ks[0], (P, bsz, Hkv, Dk), jnp.float32),
        v=jax.random.normal(ks[1], (P, bsz, Hkv, Dv), jnp.float32),
        pos=jnp.zeros((P, bsz), jnp.int32))
    table = np.zeros((B, K), np.int32)
    pos = np.full((P, bsz), -1, np.int32)
    pg = 1
    for b in range(B):
        for j in range(K):
            table[b, j] = pg
            pos[pg] = j * bsz + np.arange(bsz)
            pg += 1
    cache = cache._replace(pos=jnp.asarray(pos))
    T = Ts * bsz
    positions = np.broadcast_to(K * bsz + np.arange(T), (B, T))
    q = jax.random.normal(ks[2], (B, T, 4 * Hkv, Dk), jnp.float32)
    k_self = jax.random.normal(ks[3], (B, T, Hkv, Dk), jnp.float32)
    v_self = jax.random.normal(ks[4], (B, T, Hkv, Dv), jnp.float32)
    meta = SeqMeta(copy=jnp.zeros((B, T), jnp.int32),
                   block=jnp.asarray(positions // bsz, jnp.int32),
                   step=jnp.zeros((B, T), jnp.int32),
                   pos=jnp.asarray(positions, jnp.int32),
                   valid=jnp.ones((B, T), bool))
    return cache, jnp.asarray(table), q, k_self, v_self, meta


def _entry(sh, pass_, kernel, us, tokens, tb, dev):
    plan = plan_exec(sh["bsz"], sh["Dk"], sh["Dv"]) \
        if kernel == "pallas" else None
    return {"pass": pass_, "kernel": kernel, "B": sh["B"], "K": sh["K"],
            "bsz": sh["bsz"], "Hkv": sh["Hkv"], "Dk": sh["Dk"],
            "Dv": sh["Dv"], "us_per_call": round(us * 1e6, 1),
            "tok_s": round(tokens / max(us, 1e-12), 1),
            "transient_kv_bytes": tb,
            "mode": plan.mode if plan else "",
            "mode_reason": plan.reason if plan else "",
            "max_abs_dev": dev}


def _bench_decode(shapes, iters) -> list[dict]:
    from .common import timed
    entries = []
    for sh in shapes:
        args = _decode_setup(jax.random.PRNGKey(0), **sh)
        cache, table, ksf, vsf, pos, lim, q = args
        kw = dict(scale=sh["Dk"] ** -0.5, softcap=None, window=None)
        outs = {}
        for kernel in ("ref", "pallas"):
            layout = A.resolve_kv_layout(cache, kernel)
            fn = jax.jit(lambda q, c, t, ksf, vsf, pos, lim, _l=layout:
                         _l.attend(q, ksf, vsf, pos, c, block_table=t,
                                   cache_limit=lim, **kw))
            t = timed(lambda: fn(q, cache, table, ksf, vsf, pos, lim),
                      warmup=1, iters=iters)
            outs[kernel] = fn(q, cache, table, ksf, vsf, pos, lim)
            tb = A.transient_kv_bytes(cache, sh["B"], sh["K"], kernel)
            dev = 0.0 if kernel == "ref" else float(
                jnp.abs(outs["pallas"] - outs["ref"]).max())
            entries.append(_entry(sh, "decode", kernel, t,
                                  sh["B"] * sh["bsz"], tb, dev))
    return entries


def _bench_prefill(shapes, iters) -> list[dict]:
    from .common import timed
    entries = []
    for sh in shapes:
        cache, table, q, ksf, vsf, meta = _prefill_setup(
            jax.random.PRNGKey(1), **sh)
        kw = dict(block_size=sh["bsz"], impl="chunked",
                  scale=sh["Dk"] ** -0.5, softcap=None, window=None)
        outs = {}
        for kernel in ("ref", "pallas"):
            layout = A.resolve_kv_layout(cache, kernel)
            fn = jax.jit(lambda q, c, t, ksf, vsf, m, _l=layout:
                         _l.prefill_attend(q, ksf, vsf, m, c,
                                           context_table=t, **kw))
            t = timed(lambda: fn(q, cache, table, ksf, vsf, meta),
                      warmup=1, iters=iters)
            outs[kernel] = fn(q, cache, table, ksf, vsf, meta)
            tb = A.prefill_transient_kv_bytes(cache, sh["B"], sh["K"],
                                              kernel)
            dev = 0.0 if kernel == "ref" else float(
                jnp.abs(outs["pallas"] - outs["ref"]).max())
            tokens = sh["B"] * sh["Ts"] * sh["bsz"]
            entries.append(_entry(sh, "prefill", kernel, t, tokens, tb,
                                  dev))
    return entries


def run(quick: bool = True, smoke: bool = False) -> list[str]:
    from .common import write_bench_json
    decode_shapes = [dict(B=8, K=8, Hkv=2, Dk=32, Dv=32, bsz=16)]
    prefill_shapes = [dict(B=4, K=4, Ts=2, Hkv=2, Dk=32, Dv=32, bsz=16)]
    if smoke:
        decode_shapes = [dict(B=2, K=2, Hkv=1, Dk=16, Dv=16, bsz=8)]
        prefill_shapes = [dict(B=1, K=2, Ts=1, Hkv=1, Dk=16, Dv=16,
                               bsz=8)]
    elif not quick:
        decode_shapes += [
            dict(B=16, K=16, Hkv=2, Dk=64, Dv=64, bsz=32),
            dict(B=8, K=16, Hkv=1, Dk=72, Dv=64, bsz=32)]   # MLA-ish
        prefill_shapes += [
            dict(B=4, K=8, Ts=4, Hkv=2, Dk=64, Dv=64, bsz=32),
            dict(B=2, K=8, Ts=2, Hkv=1, Dk=72, Dv=64, bsz=32)]
    iters = 1 if smoke else 3
    entries = _bench_decode(decode_shapes, iters) \
        + _bench_prefill(prefill_shapes, iters)
    path = write_bench_json("paged_attn", entries)
    rows = [",".join(ENTRY_KEYS)]
    rows += [",".join(str(e[k]) for k in ENTRY_KEYS) for e in entries]
    rows.append(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
