"""Serving launcher: stand up a RolloutEngine on the selected mesh and
answer a request batch (or run a throughput loop).

  PYTHONPATH=src python -m repro.launch.serve --arch tiny
  PYTHONPATH=src python -m repro.launch.serve --arch tiny --ckpt ck.msgpack --tau 0.95

Mixed per-request traffic: ``--tau`` (and ``--temperature``) accept a
comma-separated list — requests round-robin over the values as
per-request ``SamplingParams`` on ONE slot pool, exercising the
request-granular decode path (no engine rebuild, no retrace per
config).  A single value behaves as before.

``--cache paged --kernel pallas`` serves the pool through the in-place
page-aware kernels (``kernels.paged_attn`` — decode and suffix
prefill); the stats line then reports the per-tick and admission-time
transient KV copies (0 in place vs the gathered fallback's dense-width
bytes) plus the kernels' execution mode — ``compiled`` or
``interpret``, and why — so TPU users can see when a sub-tile page
shape or a non-TPU backend silently put them on the slow path.
"""

from __future__ import annotations

import argparse


def _float_list(s: str) -> list[float]:
    return [float(v) for v in s.split(",") if v != ""]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tau", type=_float_list, default=[0.9],
                    help="dynamic threshold; a comma list (e.g. "
                         "0.5,0.9,0.99) round-robins per-request "
                         "SamplingParams over one pool")
    ap.add_argument("--temperature", type=_float_list, default=[0.0],
                    help="sampling temperature; comma list round-robins "
                         "like --tau")
    ap.add_argument("--max-new-blocks", type=int, default=None,
                    help="per-request response budget in blocks")
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--s-max", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batching", choices=["continuous", "static"],
                    default="continuous")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-slot pool size (continuous batching)")
    ap.add_argument("--cache", choices=["dense", "paged"],
                    default="dense",
                    help="KV layout: per-slot regions | shared page pool")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged: pool size (default = dense-equivalent)")
    ap.add_argument("--kernel", choices=["ref", "pallas"], default="ref",
                    help="paged decode KV layout: gather pages into a "
                         "dense-width copy per step (ref) or read the "
                         "page pool in place (pallas; interpret-mode "
                         "off-TPU)")
    ap.add_argument("--prefix-cache", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="paged: share committed prompt pages across "
                         "requests (default: on when --cache paged and "
                         "the backbone is pure-attention)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(request lifecycles + scheduler tick phases; "
                         "open in Perfetto / chrome://tracing). A "
                         ".jsonl path dumps raw spans instead")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write end-of-run metrics: .prom/.txt = "
                         "Prometheus text exposition, anything else = "
                         "the metrics JSON envelope")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a real XLA profiler trace of the run "
                         "into DIR (jax.profiler; open in TensorBoard "
                         "or Perfetto) — the honest device-time view")
    args = ap.parse_args()

    import jax

    from repro import configs
    from repro.checkpoint.io import load_pytree
    from repro.data.math_tasks import sample_problem
    from repro.data.tokenizer import ByteTokenizer
    from repro.models.model import BlockDiffLM
    from repro.obs import export, profile
    from repro.serving.engine import (GenerationConfig, RolloutEngine,
                                      SamplingParams)
    from repro.serving.server import ModelServer

    import random
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params = load_pytree(args.ckpt, params)

    server = ModelServer(params)
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=args.max_len, s_max=args.s_max, mode="dynamic",
        tau=args.tau[0], temperature=args.temperature[0],
        batching=args.batching, n_slots=args.slots,
        cache=args.cache, n_pages=args.pages,
        prefix_cache=args.prefix_cache, kernel=args.kernel,
        trace=args.trace_out is not None))
    rng = random.Random(0)
    prompts = [sample_problem(rng, level=0).prompt
               for _ in range(args.requests)]
    # one SamplingParams per request, cycling over the CLI value lists
    sampling = [SamplingParams(
        tau=args.tau[i % len(args.tau)],
        temperature=args.temperature[i % len(args.temperature)],
        max_new_blocks=args.max_new_blocks,
        eos_id=ByteTokenizer().eos_id)
        for i in range(args.requests)]
    mixed = len(args.tau) > 1 or len(args.temperature) > 1
    # opt-in device profiling: a no-op context unless --profile-dir
    with profile.capture(args.profile_dir) as profiling:
        if args.batching == "continuous":
            # same per-request keys as generate_texts(rng=PRNGKey(1))
            # uses on the static path, so the printed completions match
            # the --batching static run byte-for-byte (parity check)
            keys = jax.random.split(jax.random.PRNGKey(1), args.requests)
            for p, sp, k in zip(prompts, sampling, keys):
                engine.submit(p, k, params=sp)
            outs = {out.uid: out for out in engine.stream()}
            for uid in sorted(outs):
                out = outs[uid]
                tag = f"tau={out.params.tau:g} " if mixed else ""
                print(f"{prompts[uid]!r} -> {out.text!r}")
                print(f"  [{uid}] {tag}finish={out.finish_reason} "
                      f"latency={out.latency_ticks} ticks "
                      f"v{out.param_version}")
        else:
            outs = engine.generate_texts(prompts, jax.random.PRNGKey(1),
                                         sampling=sampling)
            for p, o in zip(prompts, outs):
                print(f"{p!r} -> {o!r}")
    if profiling:
        print(f"[obs] XLA profiler trace -> {args.profile_dir}")
    if args.trace_out:
        if args.trace_out.endswith(".jsonl"):
            n = export.write_jsonl(args.trace_out,
                                   engine.tracer.snapshot())
            print(f"[obs] {n} spans -> {args.trace_out}")
        else:
            export.write_chrome_trace(
                args.trace_out, engine.tracer.snapshot(),
                metadata={"tool": "repro.launch.serve"})
            print(f"[obs] Chrome trace ({len(engine.tracer)} spans, "
                  f"{engine.tracer.dropped} dropped) -> "
                  f"{args.trace_out}")
    if args.metrics_out:
        regs = [engine.stats.registry]
        if engine._sched is not None:
            regs.append(engine._sched.stats.registry)
        if args.metrics_out.endswith((".prom", ".txt")):
            export.write_prometheus(args.metrics_out, *regs)
        else:
            export.write_metrics_json(args.metrics_out, *regs)
        print(f"[obs] metrics -> {args.metrics_out}")
    s = engine.stats
    line = (f"[engine] {s.rollouts} rollouts | {s.total_tokens} tokens | "
            f"{s.tokens_per_step:.2f} tokens/denoise-step | "
            f"{s.total_tokens / max(s.wall_seconds, 1e-9):.0f} tok/s | "
            f"weights v{s.param_version}")
    if args.batching == "continuous":
        line += (f" | slot-util {s.utilization:.0%}"
                 f" | latency p50 {s.latency_p50:.0f}"
                 f"/p95 {s.latency_p95:.0f}"
                 f"/p99 {s.latency_p99:.0f} ticks")
        if args.cache == "paged" and engine.scheduler.prefix is not None:
            line += f" | prefix-hit {s.prefix_hit_rate:.0%}"
        if args.cache == "paged":
            line += (f" | kernel {args.kernel} "
                     f"(transient KV {s.transient_kv_bytes / 1024:.0f} "
                     f"KiB/tick, admit "
                     f"{s.admit_transient_kv_bytes / 1024:.0f} KiB)")
            plan = engine.scheduler.kernel_plan
            if plan is not None:
                line += f" | exec {plan.mode}: {plan.reason}"
        if mixed:
            line += (f" | {engine.scheduler.n_advance_traces} advance "
                     f"trace(s) across {args.requests} mixed requests")
    print(line)


if __name__ == "__main__":
    main()
