"""Serving launcher: stand up a RolloutEngine on the selected mesh and
answer a request batch (or run a throughput loop).

  PYTHONPATH=src python -m repro.launch.serve --arch tiny
  PYTHONPATH=src python -m repro.launch.serve --arch tiny --ckpt ck.msgpack --tau 0.95
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tau", type=float, default=0.9)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--s-max", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batching", choices=["continuous", "static"],
                    default="continuous")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-slot pool size (continuous batching)")
    ap.add_argument("--cache", choices=["dense", "paged"],
                    default="dense",
                    help="KV layout: per-slot regions | shared page pool")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged: pool size (default = dense-equivalent)")
    ap.add_argument("--prefix-cache", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="paged: share committed prompt pages across "
                         "requests (default: on when --cache paged and "
                         "the backbone is pure-attention)")
    args = ap.parse_args()

    import jax

    from repro import configs
    from repro.checkpoint.io import load_pytree
    from repro.data.math_tasks import sample_problem
    from repro.data.tokenizer import ByteTokenizer
    from repro.models.model import BlockDiffLM
    from repro.serving.engine import GenerationConfig, RolloutEngine
    from repro.serving.server import ModelServer

    import random
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params = load_pytree(args.ckpt, params)

    server = ModelServer(params)
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=args.max_len, s_max=args.s_max, mode="dynamic",
        tau=args.tau, batching=args.batching, n_slots=args.slots,
        cache=args.cache, n_pages=args.pages,
        prefix_cache=args.prefix_cache))
    rng = random.Random(0)
    prompts = [sample_problem(rng, level=0).prompt
               for _ in range(args.requests)]
    outs = engine.generate_texts(prompts, jax.random.PRNGKey(1))
    for p, o in zip(prompts, outs):
        print(f"{p!r} -> {o!r}")
    s = engine.stats
    line = (f"[engine] {s.rollouts} rollouts | {s.total_tokens} tokens | "
            f"{s.tokens_per_step:.2f} tokens/denoise-step | "
            f"{s.total_tokens / max(s.wall_seconds, 1e-9):.0f} tok/s")
    if args.batching == "continuous":
        line += f" | slot-util {s.utilization:.0%}"
        if args.cache == "paged" and engine.scheduler.prefix is not None:
            line += f" | prefix-hit {s.prefix_hit_rate:.0%}"
    print(line)


if __name__ == "__main__":
    main()
