"""The lowered step functions (train_step / prefill_step / serve_step) and
their ShapeDtypeStruct input specs for every (arch x input-shape) combo."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.block_diffusion import sft_loss
from repro.core.masks import plain_layout
from repro.models.model import BlockDiffLM
from repro.optim import adamw


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def make_train_step(model: BlockDiffLM, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch, rng):
        def loss_fn(p):
            return sft_loss(model, p, batch, rng)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om, "loss": loss}
    return train_step


def make_prefill_step(model: BlockDiffLM):
    def prefill_step(params, tokens, valid, caches, memory=None):
        meta = plain_layout(tokens, valid,
                            block_size=model.cfg.block_size)
        logits, out = model.forward_masked(params, tokens, meta,
                                           caches=caches, memory=memory)
        return logits, out["caches"]
    return prefill_step


def make_serve_step(model: BlockDiffLM):
    def serve_step(params, block_ids, positions, caches, cache_limit,
                   memory=None):
        return model.decode_step(params, block_ids, positions, caches,
                                 cache_limit=cache_limit, memory=memory)
    return serve_step


def input_specs(arch: str, shape_name: str, *, dtype: str = "bfloat16",
                opt_cfg: adamw.AdamWConfig | None = None,
                attn_impl: str = "structured") -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    Returns {"cfg", "model", "kind", "args": tuple_of_SDS, "params",
    "opt_state"} — weak-type-correct, shardable, no device allocation.
    Modality frontends contribute precomputed embedding stand-ins (the
    allowed stub).  ``attn_impl`` selects the training attention backend
    (all are differentiable, incl. the pallas custom-VJP kernels).
    """
    shp = configs.INPUT_SHAPES[shape_name]
    cfg = configs.get_config(arch, dtype=dtype, param_dtype=dtype,
                             remat=True, attn_impl=attn_impl,
                             moe_groups=32)
    model = BlockDiffLM(cfg)
    params = jax.eval_shape(
        functools.partial(model.init), jax.ShapeDtypeStruct((2,), jnp.uint32))

    B, L = shp.global_batch, shp.seq_len
    bsz = cfg.block_size
    out = {"cfg": cfg, "model": model, "kind": shp.kind, "params": params}

    memory = None
    if cfg.n_extra_tokens:
        memory = sds((B, cfg.n_extra_tokens, cfg.d_model), dtype)
    out["memory"] = memory

    if shp.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        opt_state = jax.eval_shape(
            functools.partial(adamw.init_state, opt_cfg), params)
        batch = {"tokens": sds((B, L), "int32"),
                 "prompt_mask": sds((B, L), "bool"),
                 "valid": sds((B, L), "bool")}
        if memory is not None:
            batch["memory"] = memory
        out.update(opt_state=opt_state, batch=batch,
                   rng=sds((2,), "uint32"), opt_cfg=opt_cfg)
    elif shp.kind == "prefill":
        caches = jax.eval_shape(
            functools.partial(model.make_caches, B, L))
        out.update(tokens=sds((B, L), "int32"), valid=sds((B, L), "bool"),
                   caches=caches)
    else:  # decode
        caches = jax.eval_shape(
            functools.partial(model.make_caches, B, L))
        out.update(block_ids=sds((B, bsz), "int32"),
                   positions=sds((B, bsz), "int32"),
                   caches=caches, cache_limit=sds((B,), "int32"))
    return out
