"""Distributed training launcher.

Builds the mesh, shards params/optimizer with the production partition
rules, and runs the blockwise-diffusion SFT loop.  On the CPU container it
runs a real (tiny) training job on the 1x1 host mesh; on a TPU slice the
same entry point takes --mesh single|multi and the full configs.

  PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch sdar-8b --mesh single --dry-run
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile only (see repro.launch.dryrun for "
                         "the full sweep)")
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    import os
    if args.mesh != "host":
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.checkpoint.io import save_pytree
    from repro.data.pipeline import MathTaskDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.steps import make_train_step
    from repro.models.model import BlockDiffLM
    from repro.optim import adamw

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    model = BlockDiffLM(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, clip_norm=1.0)
    step_fn = make_train_step(model, opt_cfg)

    with mesh:
        params_shape = jax.eval_shape(model.init,
                                      jax.ShapeDtypeStruct((2,), jnp.uint32))
        pspecs = shd.sanitize_specs(
            shd.param_specs(params_shape, cfg.n_experts), params_shape,
            mesh)
        ospecs = {"m": pspecs, "v": pspecs, "count": P()}
        bspecs = shd.train_batch_specs(mesh)
        ns = lambda s: shd.to_named(mesh, s)
        jstep = jax.jit(step_fn,
                        in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs),
                                      NamedSharding(mesh, P())),
                        donate_argnums=(0, 1))

        if args.dry_run:
            from repro.launch.steps import input_specs
            si = input_specs(args.arch, "train_4k")
            lowered = jstep.lower(si["params"], si["opt_state"],
                                  si["batch"], si["rng"])
            compiled = lowered.compile()
            print(compiled.memory_analysis())
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if k in ("flops", "bytes accessed")})
            return

        tok = ByteTokenizer()
        ds = MathTaskDataset(tok, cfg.block_size, seq_len=args.seq_len)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = adamw.init_state(opt_cfg, params)
        print(f"[train] {cfg.name}: {model.param_count(params):,} params "
              f"on mesh {dict(mesh.shape)}")
        rng = jax.random.PRNGKey(1)
        it = ds.sft_batches(args.batch)
        for i in range(args.steps):
            rng, k = jax.random.split(rng)
            batch = {kk: jnp.asarray(v) for kk, v in
                     next(it).asdict().items()}
            t0 = time.perf_counter()
            params, opt_state, m = jstep(params, opt_state, batch, k)
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                print(f"[{i:4d}] loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({time.perf_counter() - t0:.2f}s)")
        if args.save:
            save_pytree(args.save, params)
            print(f"saved {args.save}")


if __name__ == "__main__":
    main()
