"""Distributed training launcher.

Builds the mesh, shards params/optimizer with the production partition
rules, and runs the blockwise-diffusion SFT loop.  On the CPU container it
runs a real (tiny) training job on the 1x1 host mesh; on a TPU slice the
same entry point takes --mesh single|multi and the full configs.

  PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch sdar-8b --mesh single --dry-run

With ``--rl-steps N`` the launcher continues into DiPO post-training on
the SFT'd weights (the paper's stage 2): a ModelServer + RolloutEngine
pair and the synchronous ``DiPOTrainer`` — or, with ``--async``, the
overlapped ``rl.pipeline`` producer/consumer loop whose staleness
window ``--staleness-k`` bounds how many updates a consumed rollout may
lag (K=0 reproduces the sync loop bitwise).

  PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 50 \\
      --rl-steps 10 --async --staleness-k 2
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--attn-impl", default="structured",
                    choices=["ref", "structured", "chunked", "pallas"],
                    help="training attention backend; pallas runs the "
                         "differentiable tile-sparse kernels (interpret "
                         "mode off-TPU)")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile only (see repro.launch.dryrun for "
                         "the full sweep)")
    ap.add_argument("--save", default=None)
    # ---- DiPO post-training (stage 2) ----
    ap.add_argument("--rl-steps", type=int, default=0,
                    help="DiPO updates after SFT (0 = SFT only)")
    ap.add_argument("--async", dest="async_rl", action="store_true",
                    help="overlap rollout generation and DiPO updates "
                         "(rl.pipeline producer/consumer loop)")
    ap.add_argument("--staleness-k", type=int, default=1,
                    help="async: max updates a consumed rollout may lag "
                         "(0 = bitwise-equal to the sync loop)")
    ap.add_argument("--group-size", type=int, default=4,
                    help="DiPO rollouts per prompt (G)")
    ap.add_argument("--rl-prompts", type=int, default=4,
                    help="prompts per DiPO update (P)")
    ap.add_argument("--rl-lr", type=float, default=1e-4)
    args = ap.parse_args()

    import os
    if args.mesh != "host":
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.checkpoint.io import save_pytree
    from repro.data.pipeline import MathTaskDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.steps import make_train_step
    from repro.models.model import BlockDiffLM
    from repro.optim import adamw

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = cfg.replace(attn_impl=args.attn_impl)
    from repro.kernels.ops import train_exec_plan
    plan = train_exec_plan(cfg.attn_impl)
    print(f"[train] attn {plan.impl} | exec {plan.mode}: {plan.reason}")
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    model = BlockDiffLM(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, clip_norm=1.0)
    step_fn = make_train_step(model, opt_cfg)

    with mesh:
        params_shape = jax.eval_shape(model.init,
                                      jax.ShapeDtypeStruct((2,), jnp.uint32))
        pspecs = shd.sanitize_specs(
            shd.param_specs(params_shape, cfg.n_experts), params_shape,
            mesh)
        ospecs = {"m": pspecs, "v": pspecs, "count": P()}
        bspecs = shd.train_batch_specs(mesh)
        ns = lambda s: shd.to_named(mesh, s)
        jstep = jax.jit(step_fn,
                        in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs),
                                      NamedSharding(mesh, P())),
                        donate_argnums=(0, 1))

        if args.dry_run:
            from repro.launch.steps import input_specs
            si = input_specs(args.arch, "train_4k",
                             attn_impl=args.attn_impl)
            lowered = jstep.lower(si["params"], si["opt_state"],
                                  si["batch"], si["rng"])
            compiled = lowered.compile()
            print(compiled.memory_analysis())
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if k in ("flops", "bytes accessed")})
            return

        tok = ByteTokenizer()
        ds = MathTaskDataset(tok, cfg.block_size, seq_len=args.seq_len)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = adamw.init_state(opt_cfg, params)
        print(f"[train] {cfg.name}: {model.param_count(params):,} params "
              f"on mesh {dict(mesh.shape)}")
        rng = jax.random.PRNGKey(1)
        it = ds.sft_batches(args.batch)
        for i in range(args.steps):
            rng, k = jax.random.split(rng)
            batch = {kk: jnp.asarray(v) for kk, v in
                     next(it).asdict().items()}
            t0 = time.perf_counter()
            params, opt_state, m = jstep(params, opt_state, batch, k)
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                print(f"[{i:4d}] loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({time.perf_counter() - t0:.2f}s)")
        if args.rl_steps:
            from repro.rl.pipeline import AsyncDiPOTrainer
            from repro.rl.trainer import DiPOConfig, DiPOTrainer
            from repro.serving.engine import (GenerationConfig,
                                              RolloutEngine)
            from repro.serving.server import ModelServer

            # the server holds its own copy: the DiPO step donates the
            # trainer's buffers and pushes fresh ones each update
            server = ModelServer(jax.tree.map(jnp.copy, params))
            engine = RolloutEngine(model, server, GenerationConfig(
                max_len=args.seq_len, s_max=4, mode="dynamic", tau=0.7,
                temperature=1.0, cache="paged",
                n_slots=max(args.rl_prompts * args.group_size // 2, 2)),
                tokenizer=tok)
            rl_cfg = DiPOConfig(group_size=args.group_size,
                                logprob_scheme="packed")
            rl_opt = adamw.AdamWConfig(lr=args.rl_lr)
            rng, kr = jax.random.split(rng)
            if args.async_rl:
                tr = AsyncDiPOTrainer(model, engine, rl_opt, rl_cfg,
                                      params,
                                      staleness_k=args.staleness_k)
                mode = f"async K={args.staleness_k}"
            else:
                tr = DiPOTrainer(model, engine, rl_opt, rl_cfg, params)
                mode = "sync"
            print(f"[rl] DiPO {mode}: {args.rl_steps} updates, "
                  f"P={args.rl_prompts} G={args.group_size}")
            hist = tr.run(ds.prompt_batches(args.rl_prompts),
                          args.rl_steps, kr)
            params = tr.params
            print(f"[rl] done: server v{server.version}, final "
                  f"acc={hist[-1]['acc']:.3f} "
                  f"reward={hist[-1]['reward_mean']:.3f}")

        if args.save:
            save_pytree(args.save, params)
            print(f"saved {args.save}")


if __name__ == "__main__":
    main()
