"""Production mesh definitions (deliverable e, step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so 512 placeholder host devices exist; real deployments get the
same shapes from the TPU slice topology.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the single real device (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
