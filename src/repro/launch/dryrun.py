import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) pair, ``jax.jit(step).lower(...)
.compile()`` must succeed on BOTH production meshes:

  * single pod : (16, 16)    ("data", "model")     = 256 chips
  * multi pod  : (2, 16, 16) ("pod", "data", "model") = 512 chips

and we record memory_analysis / cost_analysis / collective traffic into
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch rwkv6-1.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_prefill_step,
                                make_serve_step, make_train_step)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _build(arch: str, shape: str, mesh, spec_overrides=None):
    """Returns (jitted_fn, example_args) for the combo on this mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    si = input_specs(arch, shape)
    cfg, model = si["cfg"], si["model"]
    pspecs = shd.sanitize_specs(
        shd.param_specs(si["params"], cfg.n_experts), si["params"], mesh)
    ns = lambda specs: shd.to_named(mesh, specs)
    dp = shd.batch_axes(mesh)

    if si["kind"] == "train":
        fn = make_train_step(model, si["opt_cfg"])
        ospecs = {"m": pspecs, "v": pspecs, "count": P()}
        bspecs = shd.train_batch_specs(mesh)
        if "memory" in si["batch"]:
            bspecs = dict(bspecs, memory=P(dp, None, None))
        in_sh = (ns(pspecs), ns(ospecs), ns(bspecs),
                 NamedSharding(mesh, P()))
        out_sh = (ns(pspecs), ns(ospecs), None)
        args = (si["params"], si["opt_state"], si["batch"], si["rng"])
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(0, 1))
    elif si["kind"] == "prefill":
        fn = make_prefill_step(model)
        cspecs = shd.sanitize_specs(
            shd.cache_specs(si["caches"], mesh, shard_seq=False),
            si["caches"], mesh)
        in_sh = (ns(pspecs), NamedSharding(mesh, P(dp, None)),
                 NamedSharding(mesh, P(dp, None)), ns(cspecs))
        args = (si["params"], si["tokens"], si["valid"], si["caches"])
        if si["memory"] is not None:
            in_sh = in_sh + (NamedSharding(mesh, P(dp, None, None)),)
            args = args + (si["memory"],)
        jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=(3,))
    else:  # decode
        fn = make_serve_step(model)
        shard_seq = configs.INPUT_SHAPES[shape].global_batch < 16
        cspecs = shd.sanitize_specs(
            shd.cache_specs(si["caches"], mesh, shard_seq=shard_seq),
            si["caches"], mesh)
        bspec = P(None, None) if shard_seq else P(dp, None)
        in_sh = (ns(pspecs), NamedSharding(mesh, bspec),
                 NamedSharding(mesh, bspec), ns(cspecs),
                 NamedSharding(mesh, P(bspec[0])))
        args = (si["params"], si["block_ids"], si["positions"],
                si["caches"], si["cache_limit"])
        if si["memory"] is not None:
            in_sh = in_sh + (NamedSharding(mesh, bspec + (None,)),)
            args = args + (si["memory"],)
        jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=(3,))
    return jfn, args, si


def run_combo(arch: str, shape: str, mesh_kind: str, *,
              save: bool = True, verbose: bool = True) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = 512 if multi else 256
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "n_chips": n_chips, "ok": False}
    t0 = time.time()
    try:
        with mesh:
            jfn, args, si = _build(arch, shape, mesh)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        try:
            hlo_text = compiled.as_text()
        except Exception:
            hlo_text = lowered.as_text()
        coll = hlo.collective_stats(hlo_text)
        terms = hlo.roofline_terms(cost or {}, coll, n_chips)

        cfg = si["cfg"]
        total_params = sum(
            x.size for x in jax.tree_util.tree_leaves(si["params"]))
        nact = hlo.active_params(cfg, total_params)
        shp = configs.INPUT_SHAPES[shape]
        batch_tokens = shp.global_batch * (
            shp.seq_len if si["kind"] != "decode" else cfg.block_size)
        mf = hlo.model_flops(cfg, nact, batch_tokens, si["kind"])

        from repro.models.config import layer_pattern
        pre, grp, ng = layer_pattern(cfg)
        rec.update(
            ok=True,
            # cost_analysis counts while-loop bodies ONCE (calibrated in
            # EXPERIMENTS.md §Methodology): in-loop flops/bytes/collective
            # contributions are to be scaled by ~layer_scan_trips when
            # absolute magnitudes (not before/after ratios) are needed.
            layer_scan_trips=ng,
            layers_per_trip=len(grp),
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=_mem_dict(mem),
            cost={k: float(v) for k, v in (cost or {}).items()
                  if isinstance(v, (int, float))},
            collectives=coll,
            roofline=terms,
            dominant=hlo.dominant_term(terms),
            total_params=int(total_params),
            active_params=int(nact),
            model_flops_global=mf,
            model_flops_per_chip=mf / n_chips,
            useful_flop_ratio=(mf / n_chips) / max(terms["flops"], 1.0),
        )
    except Exception as e:  # noqa: BLE001 — record the failure
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)

    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    if verbose:
        if rec["ok"]:
            t = rec["roofline"]
            print(f"[OK ] {arch:24s} {shape:12s} {mesh_kind:6s} "
                  f"dom={rec['dominant']:10s} "
                  f"tc={t['t_compute_s']:.3e} tm={t['t_memory_s']:.3e} "
                  f"tx={t['t_collective_s']:.3e} "
                  f"bytes/dev={rec['memory'].get('temp_mb', '?')}MB "
                  f"({rec['wall_s']}s)")
        else:
            print(f"[FAIL] {arch} {shape} {mesh_kind}: {rec['error']}")
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("generated_code_size_in_bytes",
                 "argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if "temp_size_in_bytes" in out:
        out["temp_mb"] = out["temp_size_in_bytes"] // 2**20
    if "argument_size_in_bytes" in out:
        out["args_mb"] = out["argument_size_in_bytes"] // 2**20
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        pairs = configs.arch_shape_pairs()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in pairs:
        for mk in meshes:
            path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mk}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        continue
            rec = run_combo(arch, shape, mk)
            n_fail += 0 if rec["ok"] else 1
    print(f"dry-run complete, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
