"""Roofline report generator (deliverable g).

Reads experiments/dryrun/*.json and emits the §Roofline markdown table:
per (arch x shape x mesh) the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and a what-would-move-it note.

Usage: PYTHONPATH=src python -m repro.launch.rooflines [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

NOTES = {
    ("compute", "train"): "raise per-chip batch or cut attention "
                          "overcompute (kernel tile-skip on TPU)",
    ("compute", "prefill"): "tile-skip block-causal attention; larger "
                            "q-chunks for MXU occupancy",
    ("compute", "decode"): "batch more requests per chip",
    ("memory", "train"): "less remat recompute traffic; fuse noising/CE",
    ("memory", "prefill"): "KV-cache write combining; bf16 cache",
    ("memory", "decode"): "cache-read bound: quantise cache / MQA-share; "
                          "raise batch to amortise weight reads",
    ("collective", "train"): "shrink FSDP all-gathers (wider model axis "
                             "or param prefetch overlap); reduce-scatter "
                             "grads in bf16",
    ("collective", "prefill"): "keep activations model-sharded through "
                               "the layer (avoid re-gather)",
    ("collective", "decode"): "replicate small weights; avoid resharding "
                              "the cache between layers",
}


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def fmt(x: float) -> str:
    return f"{x:.3g}"


def table(recs: list[dict], kind_of) -> str:
    hdr = ("| arch | shape | mesh | t_compute (s) | t_memory (s) | "
           "t_collective (s) | dominant | MODEL/HLO flops | note |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        t = r["roofline"]
        kind = kind_of(r)
        note = NOTES.get((r["dominant"], kind), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt(t['t_compute_s'])} | {fmt(t['t_memory_s'])} "
            f"| {fmt(t['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {note} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    from repro import configs

    def kind_of(r):
        return configs.INPUT_SHAPES[r["shape"]].kind

    recs = load_records(args.mesh)
    print(table(recs, kind_of))
    # summary: dominant-term histogram
    from collections import Counter
    print("dominant-term histogram:",
          dict(Counter(r["dominant"] for r in recs)))


if __name__ == "__main__":
    main()
