"""HLO-level analysis for the roofline report.

``cost_analysis()`` provides FLOPs and HBM bytes; collective traffic is
NOT in cost_analysis, so we parse the compiled module text and sum the
shaped bytes of every collective op, with per-op effective-traffic
multipliers (ring algorithms):

    all-reduce          2 * size * (n-1)/n     (~2x: reduce-scatter + all-gather)
    all-gather          1 * size * (n-1)/n     (size = gathered output)
    reduce-scatter      1 * input  * (n-1)/n
    all-to-all          1 * size  * (n-1)/n
    collective-permute  1 * size

n (participants) is read from replica_groups when present.  The returned
``collective_bytes`` is the per-device effective traffic in bytes.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[8,128]' or a tuple '(f32[2,4]{1,0}, f32[2,4]{1,0})'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, top_n: int = 10) -> dict:
    """Sum effective per-device collective traffic from HLO text."""
    per_op = defaultdict(lambda: {"count": 0, "bytes": 0})
    total = 0.0
    tops: list[tuple[int, str, str, str]] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        shape_str, op = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        n = None
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                n = int(g2.group(2))
        n = n or 2
        ring = (n - 1) / n
        if op == "all-reduce":
            eff = 2.0 * size * ring
        elif op == "collective-permute":
            eff = float(size)
        else:
            eff = size * ring
        per_op[op]["count"] += 1
        per_op[op]["bytes"] += int(eff)
        total += eff
        md = ""
        mm = re.search(r'op_name="([^"]*)"', line)
        if mm:
            md = mm.group(1)[-90:]
        tops.append((int(eff), op, shape_str[:70], md))
    tops.sort(reverse=True)
    return {"total_bytes": int(total), "per_op": dict(per_op),
            "top_ops": [{"bytes": b, "op": o, "shape": s, "where": w}
                        for b, o, s, w in tops[:top_n]]}


# ------------------------- roofline terms ---------------------------------

# TPU v5e-class constants given by the assignment
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


def roofline_terms(cost: dict, coll: dict, n_chips: int) -> dict:
    """The three roofline terms, in seconds.

    cost_analysis flops/bytes are per-device program numbers under SPMD
    (the compiled module is the per-device program), so chips divide only
    through the sharded shapes already reflected there; we still record
    both raw and per-chip-normalised views.
    """
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll["total_bytes"])
    return {
        "flops": flops,
        "hbm_bytes": bytes_hbm,
        "collective_bytes": cbytes,
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": bytes_hbm / HBM_BW,
        "t_collective_s": cbytes / LINK_BW,
    }


def dominant_term(terms: dict) -> str:
    t = {"compute": terms["t_compute_s"], "memory": terms["t_memory_s"],
         "collective": terms["t_collective_s"]}
    return max(t, key=t.get)


def model_flops(cfg, n_active_params: int, batch_tokens: int,
                kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (forward-only decode/prefill)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * batch_tokens


def active_params(cfg, total_params: int) -> int:
    """Active (per-token) parameter count for MoE configs."""
    if not cfg.n_experts:
        return total_params
    f = cfg.resolved_moe_d_ff
    d = cfg.d_model
    n_moe_layers = sum(
        1 for i in range(cfg.n_layers) if cfg.layer_spec(i).ffn == "moe")
    per_expert = 3 * d * f
    routed_total = cfg.n_experts * per_expert * n_moe_layers
    routed_active = cfg.top_k * per_expert * n_moe_layers
    return total_params - routed_total + routed_active
