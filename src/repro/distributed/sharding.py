"""Partition rules: parameter-path regex -> PartitionSpec.

Sharding philosophy (DESIGN.md §5):
  * ``model``  — tensor axis: heads, ffn hidden, expert dim (E >= 16),
                 vocab;
  * ``data``   — FSDP axis: the *other* matrix dim of every large weight,
                 so params & optimizer state scale with the full mesh
                 (the ZeRO-1 analogue of the paper's DeepSpeed setup);
  * ``pod``    — pure data parallel between pods (params replicated
                 across pods; gradients all-reduce over DCN).

Rules match on the path suffix and describe the TRAILING dims of the
leaf; leading dims (the scanned-group axis G) are padded with None.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.modules import tree_paths

D, M = "data", "model"


def _rules(n_experts: int) -> list[tuple[str, P]]:
    expert_parallel = n_experts >= 16
    if expert_parallel:
        eg = P(M, D, None)    # (E, d, f)
        ed = P(M, None, D)    # (E, f, d)
    else:
        eg = P(None, D, M)
        ed = P(None, M, D)
    return [
        # vocab over model; d replicated.  (Sharding d over data makes
        # the unembed contract over a data-sharded dim, and GSPMD then
        # replicates the full-batch logits — measured 69 GiB all-reduce
        # in the sdar-8b train step.  See EXPERIMENTS.md §Perf iter 1.)
        (r"embed/table$", P(M, None)),
        (r"lm_head/w$", P(None, M)),
        # attention / cross-attention
        (r"(attn|cross)/w[qkv]/w$", P(D, M)),
        (r"(attn|cross)/wo/w$", P(M, D)),
        (r"attn/wq_a/w$", P(D, None)),
        (r"attn/wq_b/w$", P(None, M)),
        (r"attn/w_dkv/w$", P(D, None)),
        (r"attn/w_kb/w$", P(None, M)),
        (r"attn/w_vb/w$", P(None, M)),
        (r"cross/gate$", P()),
        # dense ffn / shared experts
        (r"(ffn|shared)/w_(gate|up)/w$", P(D, M)),
        (r"(ffn|shared)/w_down/w$", P(M, D)),
        # MoE
        (r"moe/router/w$", P(D, None)),
        (r"experts/w_(gate|up)$", eg),
        (r"experts/w_down$", ed),
        # rwkv6
        (r"rwkv/w[rkvg]/w$", P(D, M)),
        (r"rwkv/wo/w$", P(M, D)),
        (r"rwkv/lora_w1/w$", P(D, None)),
        (r"rwkv/lora_w2$", P(None, None, M)),
        (r"rwkv/w_lora1/w$", P(D, None)),
        (r"rwkv/w_lora2/w$", P(None, M)),
        (r"rwkv/w0$", P(M)),
        (r"rwkv/u$", P(M, None)),
        (r"rwkv/ln_(scale|bias)$", P(M, None)),
        (r"rwkv/mu(_base)?$", P()),
        # rwkv channel mix
        (r"cm/wk/w$", P(D, M)),
        (r"cm/wv/w$", P(M, D)),
        (r"cm/wr/w$", P(D, M)),
        (r"cm/mu_[kr]$", P()),
        # mamba
        (r"mamba/in_proj/w$", P(D, M)),
        (r"mamba/conv_w$", P(None, M)),
        (r"mamba/conv_b$", P(M)),
        (r"mamba/w_xdt/w$", P(M, None)),
        (r"mamba/w_dt/w$", P(None, M)),
        (r"mamba/dt_bias$", P(M)),
        (r"mamba/w_[BC]/w$", P(M, None)),
        (r"mamba/A_log$", P(M, None)),
        (r"mamba/D$", P(M)),
        (r"mamba/out_proj/w$", P(M, D)),
        # projector (modality frontend -> d_model)
        (r"projector/w$", P(None, D)),
        # norms and everything scalar: replicated
        (r"(norm|ckv_norm|q_norm)/(scale|bias)$", P()),
    ]


def _pad_spec(spec: P, ndim: int) -> P:
    pad = ndim - len(spec)
    assert pad >= 0, (spec, ndim)
    return P(*([None] * pad + list(spec)))


def param_specs(params_shape, n_experts: int = 0):
    """PartitionSpec pytree matching ``params_shape`` (shapes or arrays)."""
    rules = _rules(n_experts)
    flat = tree_paths(params_shape)
    out = {}
    for path, leaf in flat:
        spec = None
        for pat, sp in rules:
            if re.search(pat, path):
                spec = _pad_spec(sp, leaf.ndim)
                break
        if spec is None:
            spec = P()  # replicate by default (norms, scalars)
        out[path] = spec
    # rebuild tree
    leaves, treedef = jax.tree_util.tree_flatten(params_shape)
    spec_leaves = [out[p] for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, spec_leaves)


def opt_state_specs(pspecs):
    """Optimizer state mirrors param sharding; count replicated."""
    return {"m": pspecs, "v": pspecs, "count": P()}


def batch_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes of this mesh (('pod','data') or ('data',))."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def train_batch_specs(mesh: Mesh):
    dp = batch_axes(mesh)
    return {"tokens": P(dp, None), "prompt_mask": P(dp, None),
            "valid": P(dp, None)}


def cache_specs(caches_shape, mesh: Mesh, *, shard_seq: bool):
    """Shardings for decode caches.

    Attention caches (B, S, Hkv, D): batch over dp, kv-heads over model
    when they divide the axis; otherwise the SEQUENCE dim takes the model
    axis (flash-decoding style partial attention — GSPMD inserts the
    softmax-stat combine).  ``shard_seq`` (long_500k, batch 1): the
    sequence dim shards over data (and over data+model when kv-heads
    don't divide).  SSM states: batch over dp, channel dim over model.
    """
    dp = batch_axes(mesh)
    msize = mesh.shape[M]

    def spec_for(path: str, leaf):
        if leaf.ndim == 0:
            return P()
        if re.search(r"/(k|v)$", path) and leaf.ndim >= 4:
            # stacked (G, B, S, Hkv, D) or plain (B, S, Hkv, D)
            base = [None] * (leaf.ndim - 4)
            hkv = leaf.shape[-2]
            heads_shardable = hkv % msize == 0
            if shard_seq:
                if heads_shardable:
                    return P(*base, None, D, M, None)
                return P(*base, None, (D, M), None, None)
            if heads_shardable:
                return P(*base, dp, None, M, None)
            return P(*base, dp, M, None, None)
        if re.search(r"/pos$", path):
            base = [None] * (leaf.ndim - 2)
            if shard_seq:
                return P(*base, None, D)
            return P(*base, dp, None)
        if re.search(r"/(wkv)$", path):      # (…, B, H, dk, dv)
            base = [None] * (leaf.ndim - 4)
            return P(*base, dp if not shard_seq else None, M, None, None)
        if re.search(r"/(ssm)$", path):      # (…, B, di, ds)
            base = [None] * (leaf.ndim - 3)
            return P(*base, dp if not shard_seq else None, M, None)
        if re.search(r"/(conv)$", path):     # (…, B, W-1, di)
            base = [None] * (leaf.ndim - 3)
            return P(*base, dp if not shard_seq else None, None, M)
        if re.search(r"/(shift|cm_shift)$", path):  # (…, B, d)
            base = [None] * (leaf.ndim - 2)
            return P(*base, dp if not shard_seq else None, None)
        return P()

    flat = tree_paths(caches_shape)
    leaves, treedef = jax.tree_util.tree_flatten(caches_shape)
    spec_leaves = [spec_for(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, spec_leaves)


def sanitize_specs(specs, shapes, mesh: Mesh):
    """Drop sharding on any dim the mesh doesn't divide (e.g. seamless's
    vocab 256206 on a 16-way axis) — jit in_shardings are strict about
    divisibility, unlike lazy GSPMD constraints."""
    def fix(spec, leaf):
        if not isinstance(spec, P):
            return spec
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        out = []
        for size, ax in zip(leaf.shape, dims):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            out.append(ax if size % total == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
