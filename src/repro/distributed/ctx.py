"""Activation sharding hints.

``shard_hint(x, *spec)`` applies a with_sharding_constraint when a mesh
context is active (the dry-run / production path) and is a no-op on the
single-device CPU test path.  Axis names that don't exist on the current
mesh are dropped, so model code can say ("batch", None, None) once and
have it mean (('pod','data'), ...) on the multi-pod mesh and ('data', ...)
on the single-pod mesh.

This is §Perf iteration 1: without these constraints GSPMD resolves the
FSDP weight-sharding / batch-sharding conflict by *replicating the global
batch* inside every layer (measured: 33.8 GiB all-reduces per FFN in the
sdar-8b train step).  Pinning activations to batch sharding flips XLA to
the intended strategy — all-gather the (small) weight shards instead.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH = "batch"  # symbolic: expands to the mesh's data-parallel axes


def _current_mesh():
    # `with mesh:` (the dry-run / launcher idiom) sets the legacy thread
    # resource, not the new abstract-mesh context; check both.  The
    # abstract-mesh getter only exists on newer jax releases.
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        if m is not None and not m.empty:
            return m
    try:
        from jax._src.mesh import thread_resources
        pm = thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def shard_hint(x, *spec):
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    out = []
    for ax in spec:
        if ax == BATCH:
            out.append(dp if dp else None)
        elif ax is None:
            out.append(None)
        else:
            axes = ax if isinstance(ax, tuple) else (ax,)
            kept = tuple(a for a in axes if a in names)
            out.append(kept if kept else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*out))
    except (ValueError, TypeError):
        return x
