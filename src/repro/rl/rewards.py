"""Verifiable rewards (the math-verify role)."""

from __future__ import annotations

import numpy as np

from repro.data.math_tasks import check_answer, parse_answer
from repro.data.tokenizer import ByteTokenizer


def math_rewards(tokenizer: ByteTokenizer, gen: dict,
                 answers: np.ndarray, block_size: int) -> np.ndarray:
    """1.0 for an exactly-correct '#### <answer>', small shaping for a
    parseable answer, 0 otherwise."""
    tokens = np.asarray(gen["tokens"])
    pb = np.asarray(gen["prompt_blocks"])
    gb = np.asarray(gen["gen_blocks"])
    B = tokens.shape[0]
    r = np.zeros((B,), np.float32)
    for i in range(B):
        start = int(pb[i]) * block_size
        end = start + int(gb[i]) * block_size
        text = tokenizer.decode(tokens[i, start:end])
        if check_answer(text, int(answers[i])):
            r[i] = 1.0
        elif parse_answer(text) is not None:
            r[i] = 0.1
    return r
