"""Version-tagged replay queue for async DiPO.

A bounded FIFO of :class:`RolloutGroup` records — one entry per DiPO
prompt group (the G rollouts whose relative rewards define the
advantages).  Every group is stamped with the ``ModelServer`` param
version that produced it, so the consumer can account staleness
*exactly*: ``staleness = consumer_version - group.version``.

Beyond the staleness window K the queue applies one of two policies:

``"importance"``  keep the group; the consumer corrects with the
                  explicit ratio ``exp(logp - old_logp)`` built from
                  the behaviour log-probs *sealed* onto the group at
                  the last version boundary it crossed while queued
                  (``core.dipo.dipo_loss(old_logp=...)`` — Eq. 6 with
                  pi_old = the stale rollout policy).
``"discard"``     drop the group at pop time (counted in the
                  ``groups_discarded`` counter) — the conservative
                  on-policy-ish variant that trades samples for bias.

Capacity is a *soft* bound enforced by the producer (it stops admitting
new prompt batches while ``full``); ``push`` itself always accepts, so
rollouts already in flight in the slot pool can always land.

Observability: queue depth / peak-depth gauges, produced / consumed /
discarded counters and a consumption-staleness histogram, all in the
shared ``dirl_pipeline`` metrics namespace.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class RolloutGroup:
    """One completed DiPO prompt group, queue-ready.

    ``gen`` holds the raw per-member rollout arrays in the layout
    ``decoding.rollout_to_batch`` consumes (host numpy; rows = the G
    group members in submission order): ``tokens``/``steps`` (G, L),
    ``prompt_blocks``/``gen_blocks``/``denoise_steps`` (G,), ``done``
    (G,).  ``old_logp`` are the behaviour policy's per-token log-probs
    (G, L) under the params tagged by ``version``.  They start out None
    and are *sealed* lazily (``RolloutProducer.seal_queued``) only when
    the group is still queued at a version boundary — a group consumed
    within its harvest window keeps None forever, because its ratio is
    identically 1 and the consumer realises Eq. 7 for it via the fused
    step's ``fresh`` mask, with no behaviour forward ever paid.
    """
    prompt_id: int               # global production index (FIFO order)
    gen: dict
    rewards: np.ndarray          # (G,) float32 verifiable rewards
    version: int                 # server version at harvest (the tag)
    version_min: int             # min over members' per-block versions
    version_max: int             # max over members' per-block versions
    old_logp: np.ndarray | None = None

    @property
    def group_size(self) -> int:
        return int(self.gen["tokens"].shape[0])

    def staleness(self, current_version: int) -> int:
        return current_version - self.version


class ReplayQueue:
    """Bounded FIFO of rollout groups with staleness accounting."""

    def __init__(self, capacity: int, staleness_k: int,
                 policy: str = "importance",
                 registry: MetricsRegistry | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if staleness_k < 0:
            raise ValueError(
                f"staleness_k must be >= 0, got {staleness_k}")
        if policy not in ("importance", "discard"):
            raise ValueError(
                f"policy must be importance|discard, got {policy!r}")
        self.capacity = capacity
        self.staleness_k = staleness_k
        self.policy = policy
        self._q: deque[RolloutGroup] = deque()
        self.registry = registry if registry is not None \
            else MetricsRegistry("dirl_pipeline")
        self._depth = self.registry.gauge(
            "queue_depth", "rollout groups waiting in the replay queue")
        self._peak = self.registry.gauge(
            "queue_peak_depth", "max replay-queue depth observed")
        self._produced = self.registry.counter(
            "groups_produced", "rollout groups pushed by the producer")
        self._consumed = self.registry.counter(
            "groups_consumed", "rollout groups consumed by DiPO steps")
        self._discarded = self.registry.counter(
            "groups_discarded",
            "groups dropped for exceeding the staleness window")
        self._staleness = self.registry.histogram(
            "staleness", "consumer_version - group.version at pop")
        self._sealed = self.registry.counter(
            "groups_sealed",
            "groups whose behaviour log-probs were sealed at a "
            "version boundary")

    # ---------------------------------------------------------- state
    @property
    def depth(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        """Producer backpressure signal (push itself never refuses)."""
        return len(self._q) >= self.capacity

    def __len__(self) -> int:
        return len(self._q)

    def groups(self) -> list[RolloutGroup]:
        """Snapshot of queued groups in FIFO order (for sealing)."""
        return list(self._q)

    # ------------------------------------------------------------ ops
    def push(self, group: RolloutGroup) -> None:
        self._q.append(group)
        self._produced.inc()
        self._depth.set(len(self._q))
        self._peak.max(len(self._q))

    def n_ready(self, current_version: int) -> int:
        """Groups a pop at ``current_version`` would deliver (i.e. the
        queue depth minus heads the discard policy would evict)."""
        if self.policy != "discard":
            return len(self._q)
        return sum(g.staleness(current_version) <= self.staleness_k
                   for g in self._q)

    def pop_batch(self, n: int, current_version: int
                  ) -> list[RolloutGroup]:
        """Pop ``n`` groups in FIFO order, applying the beyond-K policy.

        Under ``"discard"`` over-stale heads are evicted (counted) and
        never returned; under ``"importance"`` every group is
        consumable — the stored behaviour log-probs make the update
        correct at any recorded staleness.  Raises if fewer than ``n``
        eligible groups are queued (the consumer is expected to pump
        the producer until ``n_ready``).
        """
        out: list[RolloutGroup] = []
        while len(out) < n:
            if not self._q:
                raise RuntimeError(
                    f"replay queue exhausted: wanted {n} groups, got "
                    f"{len(out)} (pump the producer before popping)")
            g = self._q.popleft()
            stale = g.staleness(current_version)
            if stale < 0:
                raise RuntimeError(
                    f"group {g.prompt_id} tagged version {g.version} > "
                    f"consumer version {current_version} — version "
                    "bookkeeping corrupted")
            if self.policy == "discard" and stale > self.staleness_k:
                self._discarded.inc()
                continue
            self._staleness.observe(stale)
            self._consumed.inc()
            out.append(g)
        self._depth.set(len(self._q))
        return out
