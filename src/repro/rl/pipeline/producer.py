"""Async rollout producer — keeps the slot pool full across updates.

Drives the engine's ``submit`` / ``stream_completions`` surface: prompt
batches are expanded to G adjacent group members (one prefill + one KV
copy per unique prompt under ``cache="paged"`` + ``prefix_cache``, same
as ``generate_group_ids``) and submitted into the *live* pool, then the
pool is pumped one completion at a time.  Because ``stream_completions``
re-reads ``ModelServer.params`` every tick, weight pushes land at block
boundaries with the pool still full — in-flight requests finish their
current block on the old weights and pick the new ones up at the next
advance.  Finished groups are scored (``math_rewards``), tagged with
the harvest-time param version and pushed into the ``ReplayQueue``.

Bounded staleness is enforced at *admission*: prompt batch ``b`` may be
submitted only once ``server.version - base_version + staleness_k >=
b`` — the consumer lands exactly one update per batch, so nothing a
newly admitted rollout produces can exceed the window.  ``K = 0``
degenerates to fully serial produce→consume, which reproduces the
synchronous ``DiPOTrainer`` *bitwise*: the rng layout below is
identical to ``train_step``'s (master-key split per batch, one extra
split, then per-sequence keys), each row's tokens depend only on its
own prompt + key + params (per-row rng independence), and every batch
then rolls out under exactly the weights the sync loop would have used.

For ``K >= 1`` the behaviour policy's trajectory log-probs (π_old of
the importance-corrected update) are stored *lazily*: a group consumed
within its harvest window has ratio identically 1 (behaviour == current
policy) and needs no stored values at all — the consumer's ``fresh``
mask realises Eq. 7 for it inside the fused step.  Only groups still
queued when the consumer is about to land a weight push get *sealed*
(``seal_queued``): one jitted ``trajectory_logprobs`` forward per such
group, under the harvest-window weights while they are still live.  At
steady state the backlog at a boundary is empty or tiny, so the
behaviour forward — a real per-update cost when computed eagerly at
harvest — almost never runs.  Within-flight drift (a request finishing
on newer weights than it started on) is recorded exactly via the
``Completion`` per-block version vector (``version_min`` /
``version_max`` on the group) but the sealed behaviour is evaluated
once under the harvest version — the standard one-policy-per-sample
approximation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.core.trajectory import trajectory_logprobs
from repro.rl.pipeline.replay import ReplayQueue, RolloutGroup
from repro.rl.rewards import math_rewards
from repro.serving.engine import RolloutEngine


class RolloutProducer:
    """Streams DiPO rollout groups into a replay queue.

    Single-threaded cooperative design: the consumer loop calls
    ``submit_next()`` when the admission gate opens and ``pump()`` to
    advance the pool — there is no background thread, so the donation
    invariant (never tick the pool between the train step's dispatch
    and the ``update_weights`` push) is structural, not locked.
    """

    def __init__(self, engine: RolloutEngine, queue: ReplayQueue,
                 rl_cfg, prompt_batches, rng, *,
                 base_version: int | None = None):
        self.engine = engine
        self.queue = queue
        self.rl_cfg = rl_cfg
        self._batches = prompt_batches
        self._rng = rng
        self.base_version = base_version if base_version is not None \
            else getattr(engine.store, "version", 0)
        self.staleness_k = queue.staleness_k
        self.next_batch = 0                    # next batch index to submit
        self._inflight: dict[int, tuple[int, int]] = {}  # uid -> (pid, g)
        self._partial: dict[int, dict] = {}    # pid -> group assembly
        self._n_prompts = 0                    # global prompt_id counter
        self._stream = None
        self.tracer = engine.tracer
        # behaviour log-probs (π_old) for importance-corrected
        # consumption — run only by seal_queued, i.e. only for groups
        # that actually cross a version boundary while queued.  Never
        # runs at K = 0 (fully serial: the queue is empty at every
        # boundary), keeping K = 0 bitwise equal to the sync trainer
        # AND free of the extra compile.
        self._behavior_logp = jax.jit(functools.partial(
            trajectory_logprobs, engine.model,
            s_max=engine.gen_cfg.s_max, scheme=rl_cfg.logprob_scheme))

    # ----------------------------------------------------------- state
    @property
    def inflight(self) -> int:
        """Requests currently owned by the pool (submitted, unharvested)."""
        return len(self._inflight)

    def can_submit(self, version: int) -> bool:
        """Bounded-staleness admission gate for the *next* prompt batch.

        Batch ``b`` is consumed by update ``b`` (FIFO, one update per
        batch), i.e. at version ``base + b`` — so admitting while
        ``b <= (version - base) + K`` caps consumption staleness at K.
        Never deadlocks: the batch the consumer needs next is
        ``b = version - base``, which always satisfies the gate.
        Queue capacity backpressures on top.
        """
        return (not self.queue.full) and \
            self.next_batch <= (version - self.base_version) + \
            self.staleness_k

    # ------------------------------------------------------------- ops
    def submit_next(self) -> int:
        """Pull the next prompt batch and submit its P*G group rollouts
        into the live pool (group members adjacent).  Returns P."""
        cfg = self.rl_cfg
        self._rng, k = jax.random.split(self._rng)
        batch = next(self._batches)
        P = batch.prompt_tokens.shape[0]
        G = cfg.group_size
        # rng layout — byte-identical to DiPOTrainer.train_step: the
        # run loop's split handed us k; train_step splits once more and
        # fans the second key out per sequence
        _, kr = jax.random.split(k)
        keys = decoding._per_seq_keys(kr, P * G)
        toks = np.repeat(np.asarray(batch.prompt_tokens), G, axis=0)
        blocks = np.repeat(np.asarray(batch.prompt_blocks), G, axis=0)
        sampling = None
        if cfg.group_taus:
            sampling = [self.engine.gen_cfg.sampling(
                tau=cfg.group_taus[p % len(cfg.group_taus)])
                for p in range(P) for _ in range(G)]
        plist, _ = self.engine._resolve_sampling(P * G, sampling, blocks)
        sched = self.engine.scheduler
        with self.tracer.span("submit_batch", cat="producer",
                              track="producer", batch=self.next_batch,
                              prompts=P):
            for p in range(P):
                pid = self._n_prompts + p
                self._partial[pid] = {"comps": [None] * G, "n": 0,
                                      "answer": int(batch.answers[p]),
                                      "batch": self.next_batch}
                for g in range(G):
                    i = p * G + g
                    uid = sched.submit(toks[i], int(blocks[i]), keys[i],
                                       params=plist[i])
                    self._inflight[uid] = (pid, g)
        self._n_prompts += P
        self.next_batch += 1
        return P

    def pump(self) -> int:
        """Advance the pool until one completion is harvested; finalize
        its group if that completion was the last member.  Returns the
        number of completions harvested (0 = nothing in flight)."""
        if not self._inflight:
            return 0
        if self._stream is None:
            self._stream = self.engine.stream_completions()
        try:
            comp = next(self._stream)
        except StopIteration:
            self._stream = None
            return 0
        pid, g = self._inflight.pop(comp.uid)
        slot = self._partial[pid]
        slot["comps"][g] = comp
        slot["n"] += 1
        if slot["n"] == len(slot["comps"]):
            self._finalize(pid)
        return 1

    def _finalize(self, pid: int) -> None:
        """Assemble a finished group, score it, tag it, queue it."""
        slot = self._partial.pop(pid)
        comps = slot["comps"]
        G = len(comps)
        bsz = self.engine.model.cfg.block_size
        gen = {
            "tokens": np.stack([c.tokens for c in comps]),
            "steps": np.stack([c.steps for c in comps]),
            "gen_blocks": np.array([c.gen_blocks for c in comps],
                                   np.int32),
            "prompt_blocks": np.array([c.prompt_blocks for c in comps],
                                      np.int32),
            # drain-path parity: a zero-budget row is never flagged done
            "done": np.array([c.gen_blocks > 0 for c in comps], bool),
            "denoise_steps": np.array([c.denoise_steps for c in comps],
                                      np.int32),
        }
        answers = np.full((G,), slot["answer"], np.int64)
        versions = [int(v) for c in comps
                    for v in (c.param_version, *c.block_versions)]
        with self.tracer.span("finalize_group", cat="producer",
                              track="producer", prompt_id=pid,
                              batch=slot["batch"]):
            rewards = math_rewards(self.engine.tok, gen, answers, bsz)
            version = getattr(self.engine.store, "version", 0)
            # old_logp stays None until (unless) the group crosses a
            # version boundary in the queue — see seal_queued
            self.queue.push(RolloutGroup(
                prompt_id=pid, gen=gen, rewards=rewards,
                version=version, version_min=min(versions),
                version_max=max(versions)))

    def seal_queued(self) -> int:
        """Seal behaviour log-probs onto queued groups about to cross a
        version boundary.

        The consumer calls this immediately before landing
        ``update_weights`` — the only moment a queued group's
        harvest-window params are still live but about to be donated.
        Groups consumed within their window never pay this forward
        (ratio ≡ 1; the fused step's ``fresh`` mask applies Eq. 7 to
        them), so at steady state — empty backlog at every boundary —
        sealing costs nothing.  Returns the number of groups sealed.
        """
        todo = [g for g in self.queue.groups() if g.old_logp is None]
        if not todo:
            return 0
        store = self.engine.store
        if hasattr(store, "params_versioned"):
            version, params = store.params_versioned()
        else:
            version, params = getattr(store, "version", 0), store.params
        bsz = self.engine.model.cfg.block_size
        with self.tracer.span("seal_backlog", cat="producer",
                              track="producer", groups=len(todo)):
            for g in todo:
                if g.version != version:
                    raise RuntimeError(
                        f"group {g.prompt_id} harvested at version "
                        f"{g.version} was never sealed before version "
                        f"{version} — its behaviour params are gone")
                roll = decoding.rollout_to_batch(
                    {k: jnp.asarray(v) for k, v in g.gen.items()},
                    jnp.zeros((g.group_size,), jnp.float32),
                    jnp.zeros((g.group_size,), jnp.int32), bsz)
                g.old_logp = np.asarray(jax.lax.stop_gradient(
                    self._behavior_logp(params, roll)))
        self.queue.registry.get("groups_sealed").inc(len(todo))
        return len(todo)
