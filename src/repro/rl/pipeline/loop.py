"""Bounded-staleness DiPO consumer — the async pipeline's train loop.

``AsyncDiPOTrainer`` owns the same objects as the synchronous
``DiPOTrainer`` (params, optimizer state, the fused donating step from
``rl.trainer.make_dipo_step`` — literally the same jaxpr) but consumes
rollout groups from a :class:`~repro.rl.pipeline.replay.ReplayQueue`
fed by a :class:`~repro.rl.pipeline.producer.RolloutProducer` instead
of generating them inline.  Per update:

1. **fill** — open the bounded-staleness admission gate (submit up to
   K batches ahead) and pump the pool until P groups are ready.  This
   is where the overlap lives: while update ``b``'s stragglers decode,
   batches ``b+1..b+K`` already occupy the freed slots, so the pool
   never pays the synchronous tail-drain idle.
2. **train** — pop P groups (FIFO, re-sorted to prompt order),
   assemble the flat ``RolloutBatch`` and dispatch the fused step.
   With ``staleness_k > 0`` every row rides in with an ``old_logp``
   entry plus a per-row ``fresh`` flag: sealed groups carry their
   stored behaviour log-probs (Eq. 6 importance ratio), fresh groups
   — rolled out under the *current* params — are marked and the step
   substitutes ``stop_gradient(logp)`` in-trace (exactly Eq. 7, no
   behaviour forward ever paid for them).  One executable covers both,
   so mixed fresh/sealed, mixed-version batches never retrace
   (``step_traces == 1``).  At ``K = 0`` old_logp/fresh are None
   (pure Eq. 7, exactly the sync path).  Before dispatch the queue
   backlog is *sealed* (``producer.seal_queued``): any group about to
   cross this version boundary gets its behaviour log-probs computed
   now, while its harvest-window params are still live — the backlog
   is empty at steady state, so this forward almost never runs.
3. **update** — land ``ModelServer.update_weights(..., sync=False)``
   immediately after dispatch.  The step donated the old param buffers
   (which the server shares), so *nothing may tick the pool or read
   server params between dispatch and this push* — the loop is
   single-threaded and does neither (sealing happened pre-dispatch);
   ``params_at`` raises loudly if a consumer ever caches across the
   swap.  In-flight requests pick the new weights up at their next
   block boundary (drain-free push; the per-block version record on
   each ``Completion`` witnesses it).

Metric pulls are deferred to the end of ``run`` — the per-update hot
path never calls ``block_until_ready``, letting host-side fill work
overlap the device step (the sync trainer syncs every step for honest
phase timing; here the overlap *is* the product).

``staleness_k = 0`` reproduces ``DiPOTrainer.run`` parameter updates
bitwise (tests/test_async_rl.py pins it over multiple steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.core.masks import packed_layout
from repro.core.trajectory import trajectory_logprobs
from repro.kernels.ops import layout_tile_stats
from repro.obs import profile
from repro.obs.metrics import MetricsRegistry
from repro.optim import adamw
from repro.rl.pipeline.producer import RolloutProducer
from repro.rl.pipeline.replay import ReplayQueue
from repro.rl.trainer import DiPOConfig, make_dipo_step
from repro.serving.engine import RolloutEngine


class AsyncDiPOTrainer:
    def __init__(self, model, engine: RolloutEngine,
                 opt_cfg: adamw.AdamWConfig, rl_cfg: DiPOConfig, params,
                 *, staleness_k: int = 1, policy: str = "importance",
                 queue_capacity: int | None = None):
        self.model = model
        self.engine = engine
        self.rl_cfg = rl_cfg
        self.opt_cfg = opt_cfg
        self.params = params
        self.opt_state = adamw.init_state(opt_cfg, params)
        self.ref_params = jax.tree.map(jnp.copy, params) \
            if rl_cfg.beta else None
        self.staleness_k = staleness_k
        self.policy = policy
        # capacity rarely binds — the admission gate (K batches ahead)
        # is the real backpressure; an explicit capacity adds a hard
        # memory bound on top for long-running deployments
        self.queue_capacity = queue_capacity or 4096
        self.timings: list[dict] = []
        self.tracer = engine.tracer
        # one shared namespace for the whole pipeline: queue gauges /
        # staleness histogram (registered by ReplayQueue) + the
        # consumer's own instruments
        self.metrics = MetricsRegistry("dirl_pipeline")
        self._updates = self.metrics.counter(
            "updates", "DiPO updates landed on the server")
        self._step_traces = self.metrics.gauge(
            "step_traces", "compilations of the fused DiPO step")
        self._batches_ahead = self.metrics.gauge(
            "batches_ahead", "submitted-but-unconsumed prompt batches")
        # tile-map sparsity of the consumed batch's packed-layout
        # forward (incl. the sealing forward) — set *before* the step
        # dispatch so the gauge never syncs the overlapped device work
        self._tile_gauges = {
            f: self.metrics.gauge(
                f"attn_tile_{f}",
                f"attention tile-map {f.replace('_', ' ')} this update")
            for f in ("visit_fraction", "partial_fraction",
                      "full_fraction")}
        self._stats_layout = (
            rl_cfg.logprob_scheme == "packed"
            or (rl_cfg.logprob_scheme == "auto"
                and not model.cfg.ssm_kind))
        s_max = engine.gen_cfg.s_max
        # the sync trainer's fused step, verbatim — same jaxpr, same
        # donation contract; old_logp switches Eq. 7 <-> Eq. 6
        self._step = make_dipo_step(model, opt_cfg, rl_cfg, s_max)
        self._ref_logp = jax.jit(functools.partial(
            trajectory_logprobs, model, s_max=s_max,
            scheme=rl_cfg.logprob_scheme))
        self.queue: ReplayQueue | None = None
        self.producer: RolloutProducer | None = None

    # ------------------------------------------------------------------
    def _fill(self, producer: RolloutProducer, queue: ReplayQueue,
              n_groups: int, max_batches: int) -> int:
        """Pump the pipeline until ``n_groups`` groups are ready.

        Submission happens opportunistically whenever the staleness
        gate opens, so the pool backfills freed slots with future
        batches while the current one finishes.  Returns the server
        version the ready check was made at.
        """
        while True:
            version = getattr(self.engine.store, "version", 0)
            while producer.next_batch < max_batches and \
                    producer.can_submit(version):
                producer.submit_next()
            self._batches_ahead.set(
                producer.next_batch - self._updates.value)
            if queue.n_ready(version) >= n_groups:
                return version
            if producer.pump() == 0:
                raise RuntimeError(
                    f"async pipeline stalled: {queue.n_ready(version)}/"
                    f"{n_groups} groups ready, nothing in flight and "
                    f"the admission gate is closed (batch "
                    f"{producer.next_batch}, version {version}) — "
                    "discard-policy evictions may have outrun the "
                    "prompt budget")

    def run(self, prompt_batches, steps: int, rng, *, log_every: int = 1,
            verbose: bool = True) -> list[dict]:
        cfg = self.rl_cfg
        G = cfg.group_size
        bsz = self.model.cfg.block_size
        queue = ReplayQueue(self.queue_capacity, self.staleness_k,
                            self.policy, registry=self.metrics)
        # the producer consumes the master key exactly like the sync
        # run loop (one split per prompt batch) — the substrate of the
        # K = 0 bitwise-equivalence contract
        producer = RolloutProducer(self.engine, queue, cfg,
                                   prompt_batches, rng)
        self.queue, self.producer = queue, producer
        raw: list[dict] = []
        P = producer.submit_next()        # first batch defines P
        for i in range(steps):
            with self.tracer.span("fill", cat="consumer",
                                  track="consumer", update=i) as sp_fill:
                version = self._fill(producer, queue, P, steps)

            with self.tracer.span("train", cat="consumer",
                                  track="consumer", update=i) as sp_train:
                groups = queue.pop_batch(P, version)
                # FIFO pop order is completion order; restore prompt
                # order so row layout matches the sync trainer's
                groups.sort(key=lambda g: g.prompt_id)
                gen = {k: jnp.asarray(
                    np.concatenate([g.gen[k] for g in groups]))
                    for k in groups[0].gen}
                rewards = np.concatenate([g.rewards for g in groups])
                gid = np.repeat(np.arange(P, dtype=np.int32), G)
                roll = decoding.rollout_to_batch(
                    gen, jnp.asarray(rewards), jnp.asarray(gid), bsz)
                if self._stats_layout:
                    _, meta, _, _ = packed_layout(
                        roll.tokens, roll.steps, roll.valid,
                        block_size=bsz,
                        mask_token=self.model.cfg.resolved_mask_token,
                        s_max=self.engine.gen_cfg.s_max)
                    stats = layout_tile_stats(meta)
                    for f, g in self._tile_gauges.items():
                        g.set(stats[f])
                old_logp = fresh = None
                if self.staleness_k > 0:
                    # one executable for any fresh/sealed mix: sealed
                    # rows carry stored behaviour, fresh rows (still
                    # on-policy, old_logp never materialised) are
                    # flagged and the step substitutes
                    # stop_gradient(logp) in-trace — Eq. 7 for free
                    L = int(gen["tokens"].shape[1])
                    old_logp = jnp.asarray(np.concatenate(
                        [np.zeros((g.group_size, L), np.float32)
                         if g.old_logp is None else g.old_logp
                         for g in groups]))
                    fresh = jnp.asarray(np.concatenate(
                        [np.full((g.group_size,), g.old_logp is None)
                         for g in groups]))
                    # seal the backlog BEFORE dispatch: the step below
                    # donates the very buffers the queued groups'
                    # harvest-window behaviour must be evaluated under
                    producer.seal_queued()
                ref_logp = None
                if self.ref_params is not None:
                    ref_logp = jax.lax.stop_gradient(
                        self._ref_logp(self.ref_params, roll))
                with profile.annotate("dipo_step"):
                    self.params, self.opt_state, metrics = self._step(
                        self.params, self.opt_state, roll, old_logp,
                        fresh, ref_logp, P)
                # NO block_until_ready here: metric pulls are deferred
                # to the end of run, so the next fill's host work
                # overlaps this step's device compute

            # donation window: the step above donated the buffers the
            # server still references — land the push before anything
            # can tick the pool or read server params
            with self.tracer.span("update", cat="consumer",
                                  track="consumer", update=i) as sp_upd:
                new_version = self.engine.store.update_weights(
                    self.params, sync=False)

            self._updates.inc()
            self._step_traces.set(self._step.n_traces)
            stale = [g.staleness(version) for g in groups]
            timing = {"fill_s": sp_fill.dur, "train_s": sp_train.dur,
                      "update_s": sp_upd.dur}
            self.timings.append(timing)
            raw.append({"metrics": metrics, "rewards": rewards,
                        "stale": stale, "depth": queue.depth,
                        "version": new_version, "timing": timing})
            if verbose and (i % log_every == 0 or i == steps - 1):
                print(f"[adipo {i:3d}] v{new_version} "
                      f"stale={max(stale)} depth={queue.depth} "
                      f"inflight={producer.inflight} "
                      f"(fill {timing['fill_s']:.2f}s "
                      f"train {timing['train_s']:.2f}s)")

        # deferred metric pull: one sync at the end instead of one per
        # update (float() blocks on each device value)
        history = []
        for r in raw:
            m = {k: float(v) for k, v in r["metrics"].items()}
            m.update(r["timing"])
            m["reward_mean"] = float(np.mean(r["rewards"]))
            m["acc"] = float(np.mean(r["rewards"] >= 1.0))
            m["staleness_max"] = int(max(r["stale"]))
            m["staleness_mean"] = float(np.mean(r["stale"]))
            m["queue_depth"] = int(r["depth"])
            m["param_version"] = int(r["version"])
            m["step_traces"] = self._step.n_traces
            history.append(m)
        return history
