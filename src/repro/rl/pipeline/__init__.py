"""Async RL post-training pipeline (paper §4.2, Fig. 5b taken online).

Runs rollout generation and DiPO updates as overlapping stages instead
of the synchronous rollout↔update alternation of ``rl.trainer``:

``replay``    version-tagged replay queue — a bounded FIFO of rollout
              groups, each stamped with the ``ModelServer`` param
              version that produced it, with staleness accounting and
              discard / importance-correct policies beyond K versions.
``producer``  async rollout producer — drives the engine's ``submit`` /
              ``stream_completions`` surface so group rollouts stream
              into the queue while the slot pool stays full (prefix-
              cache prompt dedupe included).
``loop``      bounded-staleness consumer — the DiPO step consumes from
              the queue with per-group importance weights
              ``pi_theta / pi_theta_old`` from the stored rollout
              log-probs, and lands ``ModelServer.update_weights`` at
              block boundaries *without draining the pool*: in-flight
              requests finish their current block on the old params and
              pick the new ones up at the next ``advance_block`` (the
              per-block version record rides on each ``Completion``).

``staleness_k=0`` degenerates to fully serial production/consumption
and reproduces ``DiPOTrainer``'s parameter updates **bitwise** (tests/
test_async_rl.py) — correctness stays machine-checkable while K>=1
buys the wall-clock overlap.
"""

from repro.rl.pipeline.loop import AsyncDiPOTrainer
from repro.rl.pipeline.producer import RolloutProducer
from repro.rl.pipeline.replay import ReplayQueue, RolloutGroup

__all__ = ["AsyncDiPOTrainer", "ReplayQueue", "RolloutGroup",
           "RolloutProducer"]
