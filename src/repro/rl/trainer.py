"""DiPO RL trainer — the paper's Fig. 5b online loop.

Per step: pull fresh prompts -> rollout G trajectories per prompt through
the RolloutEngine (reading the live server weights) -> verifiable rewards
-> trajectory-exact log-probs -> DiPO update -> push params in place into
the server.  The per-phase wall-clock breakdown is recorded, which is what
benchmarks/fig6 compares against the offline-checkpoint baseline.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import TraceGuard
from repro.core import decoding
from repro.core.dipo import dipo_loss
from repro.core.masks import packed_layout
from repro.core.trajectory import trajectory_logprobs
from repro.kernels.ops import layout_tile_stats
from repro.obs import profile
from repro.obs.metrics import MetricsRegistry
from repro.rl.rewards import math_rewards
from repro.optim import adamw
from repro.serving.engine import RolloutEngine


@dataclasses.dataclass
class DiPOConfig:
    group_size: int = 8          # G rollouts per prompt
    eps: float = 0.2
    beta: float = 0.0            # KL-to-reference coefficient
    aggregate: str = "token"     # Eq.8 (DAPO) | "seq" (Eq.6)
    normalize_std: bool = False
    logprob_scheme: str = "auto"  # packed | replay | fused_approx
    # optional per-group denoise thresholds (DiFFPO's "reason fast and
    # furious" lever): prompt group p rolls out with tau
    # ``group_taus[p % len(group_taus)]`` instead of the engine default
    # — request-granular SamplingParams, so the mixed-τ batch shares
    # one pool with zero retraces and prompt pages still dedupe per
    # group (params never touch prompt KV).  None = engine default τ.
    group_taus: tuple[float, ...] | None = None


def make_dipo_step(model, opt_cfg: adamw.AdamWConfig, rl_cfg: DiPOConfig,
                   s_max: int) -> TraceGuard:
    """Build the fused, donating DiPO update step.

    One definition serves both the synchronous ``DiPOTrainer`` and the
    async ``rl.pipeline`` consumer, so the two paths compile the *same*
    jaxpr — the substrate of the pipeline's K=0 bitwise-equivalence
    contract.  ``old_logp`` is the behaviour policy's per-token
    log-probs: ``None`` selects the online Eq. 7 stop-gradient variant
    (fresh on-policy rollouts); an array selects the explicit Eq. 6
    importance ratio ``exp(logp - old_logp)`` — the off-policy
    correction bounded-staleness consumption relies on.  ``fresh`` is a
    per-row bool mask accompanying an ``old_logp`` array: True rows
    were rolled out under the *current* params, so their behaviour IS
    the current policy and the stored value is replaced with
    ``stop_gradient(logp)`` — exactly Eq. 7 for that row, at zero
    extra forwards.  A mixed batch (some rows sealed with stored
    behaviour, some fresh) therefore needs only ONE executable, and the
    common all-fresh case never pays a behaviour forward at all.
    Versions never enter the traced computation (staleness is host-side
    bookkeeping; ``old_logp``/``fresh`` are plain per-row data), so
    mixed-version batches reuse one compiled executable — ``n_traces``
    witnesses it.
    """
    def step_fn(params, opt_state, roll, old_logp, fresh, ref_logp,
                n_groups):
        def loss_fn(p):
            logp = trajectory_logprobs(
                model, p, roll, s_max=s_max,
                scheme=rl_cfg.logprob_scheme)
            ol = old_logp
            if ol is not None and fresh is not None:
                ol = jnp.where(fresh[:, None],
                               jax.lax.stop_gradient(logp), ol)
            return dipo_loss(
                logp, roll, old_logp=ol, ref_logp=ref_logp,
                n_groups=n_groups, eps=rl_cfg.eps, beta=rl_cfg.beta,
                aggregate=rl_cfg.aggregate,
                normalize_std=rl_cfg.normalize_std)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om, "loss": loss}

    # TraceGuard preserves step_fn's signature (functools.wraps),
    # so static_argnames still resolves n_groups when it is passed
    # positionally; n_traces witnesses one compile per n_groups
    return TraceGuard(step_fn, donate_argnums=(0, 1),
                      static_argnames=("n_groups",), name="dipo_step")


class DiPOTrainer:
    def __init__(self, model, engine: RolloutEngine,
                 opt_cfg: adamw.AdamWConfig, rl_cfg: DiPOConfig, params):
        self.model = model
        self.engine = engine
        self.rl_cfg = rl_cfg
        self.opt_cfg = opt_cfg
        self.params = params
        self.opt_state = adamw.init_state(opt_cfg, params)
        # real copy: the train step donates its params buffers, and the
        # reference policy must survive every update
        self.ref_params = jax.tree.map(jnp.copy, params) \
            if rl_cfg.beta else None
        self.timings: list[dict] = []
        # phase spans land on the engine's tracer (track "trainer", so
        # one export shows rollout / reward / train / update intervals
        # interleaved with the serving ticks they drive); aggregates go
        # to the dirl_trainer metrics namespace
        self.tracer = engine.tracer
        self.metrics = MetricsRegistry("dirl_trainer")
        self._phase_seconds = self.metrics.histogram(
            "phase_seconds", "per-phase wall time per train step",
            labelnames=("phase",))
        self._steps_total = self.metrics.counter(
            "steps", "train steps executed")
        self._step_traces = self.metrics.gauge(
            "step_traces", "compilations of the fused DiPO step")
        # tile-map sparsity of the packed-layout logprob forward — what
        # the pallas training kernels visit/skip on this step's batch
        self._tile_gauges = {
            f: self.metrics.gauge(
                f"attn_tile_{f}",
                f"attention tile-map {f.replace('_', ' ')} this step")
            for f in ("visit_fraction", "partial_fraction",
                      "full_fraction")}
        # packed is the layout the attention backbones actually run;
        # replay/fused_approx never build the packed mask
        self._stats_layout = (
            rl_cfg.logprob_scheme == "packed"
            or (rl_cfg.logprob_scheme == "auto"
                and not model.cfg.ssm_kind))
        s_max = engine.gen_cfg.s_max
        # the same fused step the async pipeline consumer runs (always
        # called with old_logp=None here: fresh rollouts every step are
        # exactly on-policy — Eq. 7)
        self._step = make_dipo_step(model, opt_cfg, rl_cfg, s_max)
        self._ref_logp = jax.jit(functools.partial(
            trajectory_logprobs, model, s_max=s_max,
            scheme=rl_cfg.logprob_scheme))

    def train_step(self, prompt_batch, rng) -> dict:
        cfg = self.rl_cfg
        bsz = self.model.cfg.block_size
        P = prompt_batch.prompt_tokens.shape[0]
        G = cfg.group_size

        # ---- rollout (G per prompt) ----------------------------------
        # the group entry keeps each group's members adjacent, so a
        # paged + prefix-cache engine prefills and stores every unique
        # prompt once instead of G times (rng layout identical to the
        # old np.repeat + generate_ids path — rollouts are unchanged).
        # obs spans replace the old perf_counter pairs: same intervals,
        # but they also land on the shared tracer (track "trainer") and
        # aggregate into the dirl_trainer phase histogram.
        with self.tracer.span("rollout", cat="trainer",
                              track="trainer") as sp_roll:
            answers = np.repeat(prompt_batch.answers, G, axis=0)
            rng, kr = jax.random.split(rng)
            sampling = None
            if cfg.group_taus:
                # per-group τ: one SamplingParams per prompt, expanded
                # to the group's G adjacent members
                sampling = [self.engine.gen_cfg.sampling(
                    tau=cfg.group_taus[p % len(cfg.group_taus)])
                    for p in range(P)]
            gen = self.engine.generate_group_ids(
                prompt_batch.prompt_tokens, prompt_batch.prompt_blocks,
                kr, G, sampling=sampling)

        # ---- rewards ---------------------------------------------------
        with self.tracer.span("reward", cat="trainer",
                              track="trainer") as sp_rew:
            rewards = math_rewards(self.engine.tok, gen, answers, bsz)
            group = np.repeat(np.arange(P, dtype=np.int32), G)
            roll = decoding.rollout_to_batch(
                gen, jnp.asarray(rewards), jnp.asarray(group), bsz)

        # ---- logits + policy update -----------------------------------
        with self.tracer.span("train", cat="trainer",
                              track="trainer") as sp_train:
            ref_logp = None
            if self.ref_params is not None:
                ref_logp = jax.lax.stop_gradient(
                    self._ref_logp(self.ref_params, roll))
            with profile.annotate("dipo_step"):
                self.params, self.opt_state, metrics = self._step(
                    self.params, self.opt_state, roll, None, None,
                    ref_logp, P)
            # deliberate: t_train must measure the real step, and metrics
            # are pulled to host right below anyway
            jax.block_until_ready(metrics["loss"])  # dirlint: ok(hot-sync)

        # ---- in-place server update ------------------------------------
        with self.tracer.span("update", cat="trainer",
                              track="trainer") as sp_upd:
            self.engine.store.update_weights(self.params)
            # offline stores pay the reload on the *next* rollout;
            # in-place stores are done here.

        timing = {"rollout_s": sp_roll.dur, "reward_s": sp_rew.dur,
                  "train_s": sp_train.dur, "update_s": sp_upd.dur}
        for phase in ("rollout", "reward", "train", "update"):
            self._phase_seconds.labels(phase=phase).observe(
                timing[f"{phase}_s"])
        self._steps_total.inc()
        self._step_traces.set(self._step.n_traces)
        if self._stats_layout:
            # host-side rebuild of the packed mask metadata (cheap: meta
            # only, no forward) -> per-step sparsity gauges
            _, meta, _, _ = packed_layout(
                roll.tokens, roll.steps, roll.valid, block_size=bsz,
                mask_token=self.model.cfg.resolved_mask_token,
                s_max=self.engine.gen_cfg.s_max)
            stats = layout_tile_stats(meta)
            for f, g in self._tile_gauges.items():
                g.set(stats[f])
        if self.engine.last_call.get("batching") == "continuous":
            timing["rollout_util"] = self.engine.last_call["utilization"]
            timing["prefix_hit_rate"] = \
                self.engine.last_call["prefix_hit_rate"]
        self.timings.append(timing)
        out = {k: float(v) for k, v in metrics.items()}
        out.update(timing)
        out["step_traces"] = self._step.n_traces
        out["reward_mean"] = float(np.mean(rewards))
        out["acc"] = float(np.mean(rewards >= 1.0))
        return out

    def run(self, prompt_batches, steps: int, rng, *, log_every: int = 1,
            verbose: bool = True) -> list[dict]:
        history = []
        for i in range(steps):
            rng, k = jax.random.split(rng)
            m = self.train_step(next(prompt_batches), k)
            history.append(m)
            if verbose and (i % log_every == 0 or i == steps - 1):
                print(f"[dipo {i:3d}] loss={m['loss']:.4f} "
                      f"acc={m['acc']:.3f} reward={m['reward_mean']:.3f} "
                      f"clip={m['clip_frac']:.3f} "
                      f"(roll {m['rollout_s']:.2f}s train {m['train_s']:.2f}s "
                      f"update {m['update_s']:.3f}s)")
        return history
