"""TraceGuard — a jitted callable that counts its own compilations.

Generalizes the scheduler's hand-rolled ``n_advance_traces`` counter
(the zero-retrace contract's witness since the mixed-``SamplingParams``
pool landed): wrap any function destined for ``jax.jit`` and the guard
counts how many times jax actually *traces* it — the body increment
only runs under tracing, so cache hits leave the counter untouched.
``donate_argnums`` / ``static_argnames`` / ``static_argnums`` pass
through to ``jax.jit`` unchanged, and ``functools.wraps`` preserves the
wrapped signature so ``static_argnames`` keeps resolving positionally
passed arguments.

Optionally the guard enforces a transfer contract at call time:
``transfer_guard="disallow"`` runs every call under
``jax.transfer_guard("disallow")``, turning silent host<->device
copies (implicit ``np.asarray`` pulls, scalar captures) into errors —
the runtime complement of dirlint's static ``trace-host-pull`` rule.

Usage::

    self._advance = TraceGuard(advance_impl, donate_argnums=(1,),
                               name="advance")
    ...
    self._state = self._advance(params, self._state)
    assert self._advance.n_traces == 1     # zero-retrace contract
"""

from __future__ import annotations

import functools

import jax

__all__ = ["TraceGuard"]


class TraceGuard:
    """Wrap ``fn`` in ``jax.jit`` with a compile counter.

    fn              the function to jit
    donate_argnums  / static_argnums / static_argnames: forwarded to
                    ``jax.jit``
    transfer_guard  None (off) or a ``jax.transfer_guard`` level
                    ("allow" | "log" | "disallow" | ...) applied around
                    every call
    name            label for ``stats()`` (defaults to fn.__name__)
    """

    def __init__(self, fn, *, donate_argnums=(), static_argnums=(),
                 static_argnames=(), transfer_guard: str | None = None,
                 name: str | None = None, **jit_kwargs):
        self.name = name or getattr(fn, "__name__", "jitted")
        self.transfer_guard = transfer_guard
        self._n_traces = 0

        @functools.wraps(fn)
        def counted(*args, **kwargs):
            # runs only while jax traces (compiles) — cache hits skip it
            self._n_traces += 1
            return fn(*args, **kwargs)

        if donate_argnums:
            jit_kwargs["donate_argnums"] = donate_argnums
        if static_argnums:
            jit_kwargs["static_argnums"] = static_argnums
        if static_argnames:
            jit_kwargs["static_argnames"] = static_argnames
        self._jit = jax.jit(counted, **jit_kwargs)

    @property
    def n_traces(self) -> int:
        """Compilations so far (1 == the zero-retrace contract holds)."""
        return self._n_traces

    def reset(self) -> None:
        """Zero the counter (the compile cache is NOT cleared — a reset
        guard counts only *new* traces)."""
        self._n_traces = 0

    def stats(self) -> dict:
        return {"name": self.name, "n_traces": self._n_traces}

    def __call__(self, *args, **kwargs):
        if self.transfer_guard is not None:
            with jax.transfer_guard(self.transfer_guard):
                return self._jit(*args, **kwargs)
        return self._jit(*args, **kwargs)

    def lower(self, *args, **kwargs):
        return self._jit.lower(*args, **kwargs)

    def __repr__(self):
        return f"TraceGuard({self.name}, n_traces={self._n_traces})"
