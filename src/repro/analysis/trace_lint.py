"""Pass 1 — trace hygiene over everything reachable from a jit site.

Finds every ``jax.jit`` / ``TraceGuard`` call site in the tree, resolves
the jitted callable (plain function, ``self.method``,
``functools.partial`` target, or a factory-returned nested def like
``make_train_step``), seeds its non-static parameters as *tainted*
(traced values), and walks the call graph propagating taint
interprocedurally.  Inside tainted code it flags:

``trace-branch``     Python ``if``/``while``/``for``/``assert`` whose
                     condition (or iterable) is a traced value — the
                     classic retrace-per-value / leaked-tracer bug.
                     ``x is None`` / ``isinstance(x, T)`` tests are
                     exempt (they are static under tracing), as is
                     iterating a ``.items()``-style call (dict pytree
                     structure is static).
``trace-host-pull``  ``float()``/``int()``/``bool()``, ``.item()``/
                     ``.tolist()``, or ``np.asarray``/``np.array`` on a
                     traced value — a host round-trip that fails (or
                     silently constant-folds) under tracing.
``hot-sync``         ``jax.block_until_ready`` / ``jax.device_get``
                     inside a registered per-tick/per-step hot path
                     (scheduler tick, engine drain/stream, trainer
                     step) — host syncs that serialize dispatch.
``obs-in-trace``     any ``obs.metrics`` / ``obs.trace`` call inside
                     jit-reachable code — instrumentation is host-side
                     bookkeeping *between* dispatches; inside a trace
                     it runs at trace time (recording garbage once per
                     compilation) or leaks a tracer into a span or
                     metric.  Detected via import-alias calls
                     (``trace.Tracer(...)``, ``obs.MetricsRegistry``),
                     resolved callees living in an obs module, locally
                     constructed obs handles, and ``*.tracer.<span-
                     API>()`` method chains.

Taint is deliberately shape-transparent: ``x.shape`` / ``x.ndim`` /
``x.dtype`` / ``len(x)`` of a tracer are static, so branching on them
is fine and stays unflagged.
"""

from __future__ import annotations

import ast
from collections import deque

from .astutils import FunctionInfo, Project, attr_path
from .rules import Finding

__all__ = ["run", "HOT_PATHS", "EXTRA_ROOTS"]

# per-tick / per-step host-side hot paths: block_until_ready/device_get
# anywhere in their (repo-local) call graph is a dispatch stall
HOT_PATHS = [
    ("repro.serving.scheduler", "SlotScheduler.step"),
    ("repro.serving.engine", "RolloutEngine.generate_ids"),
    ("repro.serving.engine", "RolloutEngine._generate_ids_continuous"),
    ("repro.serving.engine", "RolloutEngine.stream"),
    ("repro.rl.trainer", "DiPOTrainer.train_step"),
    ("repro.sft.trainer", "SFTTrainer.train_step"),
]

# always-traced entry points reached through dynamic dispatch the
# resolver cannot follow (KVLayout.attend -> Pallas wrappers): lint
# them with every non-defaulted parameter tainted
EXTRA_ROOTS = [
    ("repro.kernels.paged_attn", "paged_decode_attention"),
    ("repro.kernels.paged_attn", "paged_prefill_attention"),
    ("repro.kernels.block_diff_attn", "block_diff_attention"),
    ("repro.kernels.ops", "chunked_masked_attention"),
]

# duck-typed method calls on a hinted parameter name: "model" is always
# the BlockDiffLM, so model.decode_step(...) resolves statically
PARAM_TYPE_HINTS = {
    "model": ("repro.models.model", "BlockDiffLM"),
}

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "aval",
                "weak_type", "sharding"}
_STATIC_CALLS = {"isinstance", "len", "type", "hasattr", "callable",
                 "getattr", "issubclass", "id", "repr"}
_HOST_PULL_NAMES = {"float", "int", "bool"}
_HOST_PULL_METHODS = {"item", "tolist"}
_SYNC_ATTRS = {"block_until_ready", "device_get"}

# obs modules whose calls must never be jit-reachable (profile/export
# are not listed: annotate() is trace-legal and exporters are cold
# paths no jit site can reach)
_OBS_MODULES = {"repro.obs", "repro.obs.metrics", "repro.obs.trace"}
# Tracer's recording API: a `<anything>.tracer.<one of these>()` chain
# is an obs call even when the receiver cannot be resolved statically
# (e.g. `self.tracer.span(...)`).  Deliberately excludes generic names
# like `set`/`add` that jnp's `.at[...]` API shares.
_TRACER_METHODS = {"span", "begin", "end", "instant", "amend",
                   "snapshot"}

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _is_jax_attr(module, node: ast.expr, attr: str) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == attr
            and isinstance(node.value, ast.Name)
            and module.import_aliases.get(node.value.id) == "jax")


def _is_jit_site(module, call: ast.Call) -> bool:
    f = call.func
    if _is_jax_attr(module, f, "jit"):
        return True
    if isinstance(f, ast.Name) and f.id == "TraceGuard":
        return True
    if isinstance(f, ast.Attribute) and f.attr == "TraceGuard":
        return True
    return False


def _is_partial(module, call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Name) and f.id == "partial":
        return True
    return (isinstance(f, ast.Attribute) and f.attr == "partial"
            and isinstance(f.value, ast.Name)
            and module.import_aliases.get(f.value.id) == "functools")


def _const_strs(node) -> list[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, str)]
    return []


def _const_ints(node) -> list[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, int)]
    return []


def _scope_stmts(body):
    """Every statement in this scope, recursing into compound
    statements but never into nested function/class definitions."""
    for stmt in body:
        if isinstance(stmt, _DEFS):
            continue
        yield stmt
        for _, val in ast.iter_fields(stmt):
            if isinstance(val, list):
                yield from _scope_stmts(
                    [s for s in val if isinstance(s, ast.stmt)])
        for h in getattr(stmt, "handlers", []):
            yield from _scope_stmts(h.body)


def _expr_calls(stmt):
    """Call nodes among this statement's own expressions (nested
    statements, lambdas and defs excluded)."""
    stack = [stmt]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Call):
            yield n
        for c in ast.iter_child_nodes(n):
            if isinstance(c, (ast.stmt, ast.Lambda)) or \
                    isinstance(c, _DEFS):
                continue
            stack.append(c)


def _scope_calls(body):
    for stmt in _scope_stmts(body):
        yield from _expr_calls(stmt)


class _Resolver:
    """Project resolution + PARAM_TYPE_HINTS method dispatch."""

    def __init__(self, project: Project):
        self.project = project

    def resolve(self, module, scope, cls, func_expr):
        fi = self.project.resolve_callable(module, scope, cls, func_expr)
        if fi is not None:
            return fi
        if isinstance(func_expr, ast.Attribute) and \
                isinstance(func_expr.value, ast.Name):
            hint = PARAM_TYPE_HINTS.get(func_expr.value.id)
            if hint and hint[0] in self.project.modules:
                return self.project.modules[hint[0]].functions.get(
                    f"{hint[1]}.{func_expr.attr}")
        return None


# --------------------------------------------------------------------------
# jit-site discovery
# --------------------------------------------------------------------------


def find_jit_sites(project: Project, resolver: _Resolver):
    """Yield (target FunctionInfo, seed-tainted param frozenset)."""
    for module in project.modules.values():
        scopes = [("", None, module.tree.body)]
        scopes += [(fi.qualname, fi.cls_name, fi.node.body)
                   for fi in module.functions.values()]
        for scope, cls, body in scopes:
            for call in _scope_calls(body):
                if not _is_jit_site(module, call) or not call.args:
                    continue
                yield from _resolve_site(project, resolver, module,
                                         scope, cls, body, call)


def _resolve_site(project, resolver, module, scope, cls, body, call):
    target = call.args[0]
    bound_pos, bound_kw = 0, set()
    if isinstance(target, ast.Call) and _is_partial(module, target) \
            and target.args:
        bound_pos = len(target.args) - 1
        bound_kw = {kw.arg for kw in target.keywords if kw.arg}
        target = target.args[0]
    fi = resolver.resolve(module, scope, cls, target)
    if fi is None and isinstance(target, ast.Name):
        # local `step_fn = make_train_step(...)` factory pattern
        for stmt in _scope_stmts(body):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == target.id \
                    and isinstance(stmt.value, ast.Call):
                factory = resolver.resolve(module, scope, cls,
                                           stmt.value.func)
                if factory is not None:
                    fi = project.resolve_factory_return(factory)
    if fi is None:
        return
    statics = set()
    params = fi.params
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            statics |= set(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            statics |= {params[i] for i in _const_ints(kw.value)
                        if i < len(params)}
    tainted = frozenset(p for i, p in enumerate(params)
                        if i >= bound_pos and p not in statics
                        and p not in bound_kw)
    if tainted:
        yield fi, tainted


def _no_default_params(fi: FunctionInfo) -> frozenset:
    a = fi.node.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    if pos and pos[0] in ("self", "cls"):
        pos = pos[1:]
    n_def = len(a.defaults)
    out = set(pos[:len(pos) - n_def] if n_def else pos)
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is None:
            out.add(p.arg)
    return frozenset(out)


# --------------------------------------------------------------------------
# intraprocedural taint walk
# --------------------------------------------------------------------------


class _FnTaint:
    def __init__(self, resolver: _Resolver, fi: FunctionInfo,
                 tainted: frozenset, findings: list, enqueue):
        self.r = resolver
        self.fi = fi
        self.module = fi.module
        self.path = str(fi.module.path)
        self.tainted: set[str] = set(tainted)
        self.findings = findings
        self.enqueue = enqueue
        self._flagged: set[tuple] = set()
        # local names bound to obs objects (`t = Tracer(...)`): later
        # method calls on them are obs calls even without resolution
        self._obs_handles: set[str] = set()

    def run(self):
        for _ in range(2):        # fixpoint for loop-carried taint
            for stmt in self.fi.node.body:
                self.stmt(stmt)

    def flag(self, rule: str, node, msg: str):
        key = (rule, node.lineno)
        if key in self._flagged:
            return
        self._flagged.add(key)
        self.findings.append(Finding(rule, self.path, node.lineno, msg))

    # ------------------------------------------------------ expressions
    def is_tainted(self, e) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _SHAPE_ATTRS:
                return False
            # fields declared static via register_dataclass metadata
            # (LayerCtx.mode, .write_cache, ...) are host values even
            # when the carrying pytree is traced
            if e.attr in self.r.project.static_fields:
                return False
            return self.is_tainted(e.value)
        if isinstance(e, ast.Subscript):
            return self.is_tainted(e.value) or self.is_tainted(e.slice)
        if isinstance(e, ast.Call):
            if isinstance(e.func, ast.Name) and \
                    e.func.id in _STATIC_CALLS:
                return False
            if isinstance(e.func, ast.Attribute) and \
                    e.func.attr in ("keys", "items", "values"):
                # dict *structure* is static even for tracer pytrees;
                # the yielded values re-taint through loop targets
                return self.is_tainted(e.func.value)
            args = list(e.args) + [kw.value for kw in e.keywords]
            return self.is_tainted(e.func) or \
                any(self.is_tainted(a) for a in args)
        if isinstance(e, ast.BoolOp):
            return any(self.is_tainted(v) for v in e.values)
        if isinstance(e, ast.BinOp):
            return self.is_tainted(e.left) or self.is_tainted(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.is_tainted(e.operand)
        if isinstance(e, ast.Compare):
            return self.is_tainted(e.left) or \
                any(self.is_tainted(c) for c in e.comparators)
        if isinstance(e, ast.IfExp):
            return any(self.is_tainted(x)
                       for x in (e.test, e.body, e.orelse))
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_tainted(x) for x in e.elts)
        if isinstance(e, ast.Dict):
            return any(self.is_tainted(x)
                       for x in list(e.keys) + list(e.values)
                       if x is not None)
        if isinstance(e, ast.Starred):
            return self.is_tainted(e.value)
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            return any(self.is_tainted(g.iter) for g in e.generators)
        if isinstance(e, ast.NamedExpr):
            return self.is_tainted(e.value)
        return False

    def _is_static_guard(self, t) -> bool:
        """Tests that are Python-static even over tracers."""
        if isinstance(t, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in t.ops):
            return True
        if isinstance(t, ast.Compare) and t.ops and all(
                isinstance(op, (ast.In, ast.NotIn)) for op in t.ops):
            # `"key" in pytree` / `x in ("a", "b")`: dict *structure*
            # and literal membership are static; membership in a traced
            # array (`x in arr`) is not, and stays flagged
            if isinstance(t.left, ast.Constant) and \
                    isinstance(t.left.value, str):
                return True
            if all(isinstance(c, (ast.Tuple, ast.List, ast.Set))
                   for c in t.comparators):
                return True
        if isinstance(t, ast.Call) and isinstance(t.func, ast.Name) \
                and t.func.id in _STATIC_CALLS:
            return True
        if isinstance(t, ast.UnaryOp) and isinstance(t.op, ast.Not):
            return self._is_static_guard(t.operand)
        if isinstance(t, ast.BoolOp):
            return all(self._is_static_guard(v) or not self.is_tainted(v)
                       for v in t.values)
        return False

    # ------------------------------------------------------- statements
    def assign_target(self, tgt, value_tainted: bool):
        if isinstance(tgt, ast.Name):
            if value_tainted:
                self.tainted.add(tgt.id)
            else:
                self.tainted.discard(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self.assign_target(e, value_tainted)
        elif isinstance(tgt, ast.Starred):
            self.assign_target(tgt.value, value_tainted)
        # attribute/subscript stores: untracked

    def stmt(self, s):
        if isinstance(s, _DEFS):
            return
        self.scan_calls(s)
        if isinstance(s, ast.Assign):
            if isinstance(s.value, ast.Call) and \
                    self._obs_call_kind(s.value) is not None:
                for tgt in s.targets:
                    if isinstance(tgt, ast.Name):
                        self._obs_handles.add(tgt.id)
            t = self.is_tainted(s.value)
            if isinstance(s.value, ast.Tuple) and len(s.targets) == 1 \
                    and isinstance(s.targets[0], ast.Tuple) \
                    and len(s.targets[0].elts) == len(s.value.elts):
                for tgt, v in zip(s.targets[0].elts, s.value.elts):
                    self.assign_target(tgt, self.is_tainted(v))
            else:
                for tgt in s.targets:
                    self.assign_target(tgt, t)
        elif isinstance(s, ast.AugAssign):
            if self.is_tainted(s.value):
                self.assign_target(s.target, True)
        elif isinstance(s, ast.AnnAssign) and s.value is not None:
            self.assign_target(s.target, self.is_tainted(s.value))
        elif isinstance(s, (ast.If, ast.While)):
            if self.is_tainted(s.test) and \
                    not self._is_static_guard(s.test):
                kind = "while" if isinstance(s, ast.While) else "if"
                self.flag("trace-branch", s,
                          f"Python `{kind}` on a traced value in "
                          f"{self.fi.qualname} (retraces per value or "
                          "leaks the tracer); use jnp.where/lax.cond")
            narrowed = self._narrow_names(s.test)
            saved = {n for n in narrowed if n in self.tainted}
            self.tainted -= saved
            for sub in s.body:
                self.stmt(sub)
            self.tainted |= saved
            for sub in s.orelse:
                self.stmt(sub)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            it_tainted = self.is_tainted(s.iter)
            if it_tainted and not isinstance(s.iter, ast.Call):
                self.flag("trace-branch", s,
                          f"Python `for` over a traced value in "
                          f"{self.fi.qualname} (statically unrolls / "
                          "leaks the tracer); use lax.fori_loop/scan")
            self.assign_target(s.target, it_tainted)
            for sub in s.body + s.orelse:
                self.stmt(sub)
        elif isinstance(s, ast.Assert):
            if self.is_tainted(s.test) and \
                    not self._is_static_guard(s.test):
                self.flag("trace-branch", s,
                          f"assert on a traced value in "
                          f"{self.fi.qualname} (forces concretization); "
                          "assert on .shape/.dtype or use checkify")
        elif isinstance(s, ast.With):
            for sub in s.body:
                self.stmt(sub)
        elif isinstance(s, ast.Try):
            for sub in s.body + s.orelse + s.finalbody:
                self.stmt(sub)
            for h in s.handlers:
                for sub in h.body:
                    self.stmt(sub)

    def _narrow_names(self, test) -> set[str]:
        """Names an isinstance/is-None guard makes host-static in the
        body (approximate flow-sensitivity)."""
        out = set()
        if isinstance(test, ast.Call) and \
                isinstance(test.func, ast.Name) and \
                test.func.id == "isinstance" and test.args and \
                isinstance(test.args[0], ast.Name):
            out.add(test.args[0].id)
        if isinstance(test, ast.Compare) and \
                isinstance(test.left, ast.Name) and all(
                    isinstance(op, (ast.Is, ast.IsNot))
                    for op in test.ops):
            out.add(test.left.id)
        if isinstance(test, ast.BoolOp):
            for v in test.values:
                out |= self._narrow_names(v)
        return out

    # ---------------------------------------------------- calls / edges
    def scan_calls(self, stmt):
        for node in _expr_calls(stmt):
            if _is_jit_site(self.module, node):
                continue
            self._check_sinks(node)
            if self._check_obs(node):
                continue        # don't chase taint into obs internals
            self._edges(node)

    def _obs_call_kind(self, call: ast.Call) -> str | None:
        """How this call lands in repro.obs (a display string), or None."""
        f = call.func
        if isinstance(f, ast.Attribute):
            root = f.value
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                m = self.r.project.module_of_alias(self.module, root.id)
                if m is not None and m.name in _OBS_MODULES:
                    return f"{m.name}.{f.attr}"
                if root.id in self._obs_handles:
                    return f"{root.id}.{f.attr}"
            if f.attr in _TRACER_METHODS:
                p = attr_path(f)
                # `<anything>.tracer.span(...)` — the conventional
                # handle name makes the receiver recognizable even when
                # its type cannot be resolved (self.tracer, eng.tracer)
                if p is not None and "tracer" in p.split(".")[:-1]:
                    return p
        elif isinstance(f, ast.Name):
            src = self.module.from_imports.get(f.id)
            if src is not None and src[0] in _OBS_MODULES:
                return f"{src[0]}.{src[1]}"
        callee = self.r.resolve(self.module, self.fi.qualname,
                                self.fi.cls_name, f)
        if callee is not None and callee.module.name in _OBS_MODULES:
            return f"{callee.module.name}:{callee.qualname}"
        return None

    def _check_obs(self, call: ast.Call) -> bool:
        kind = self._obs_call_kind(call)
        if kind is None:
            return False
        self.flag("obs-in-trace", call,
                  f"obs call {kind}() reachable in jitted body "
                  f"{self.fi.qualname}; metrics/spans are host-side "
                  "bookkeeping — record them between dispatches, not "
                  "inside the trace")
        return True

    def _check_sinks(self, call: ast.Call):
        f = call.func
        if isinstance(f, ast.Name) and f.id in _HOST_PULL_NAMES and \
                len(call.args) == 1 and self.is_tainted(call.args[0]):
            self.flag("trace-host-pull", call,
                      f"{f.id}() on a traced value in "
                      f"{self.fi.qualname} (host pull fails under "
                      "tracing)")
        elif isinstance(f, ast.Attribute):
            if f.attr in _HOST_PULL_METHODS and self.is_tainted(f.value):
                self.flag("trace-host-pull", call,
                          f".{f.attr}() on a traced value in "
                          f"{self.fi.qualname}")
            elif f.attr in ("asarray", "array") and \
                    isinstance(f.value, ast.Name) and \
                    self.module.import_aliases.get(f.value.id) == \
                    "numpy" and call.args and \
                    self.is_tainted(call.args[0]):
                self.flag("trace-host-pull", call,
                          f"np.{f.attr}() on a traced value in "
                          f"{self.fi.qualname} (device->host copy "
                          "fails under tracing); use jnp")

    def _edges(self, call: ast.Call):
        callee = self.r.resolve(self.module, self.fi.qualname,
                                self.fi.cls_name, call.func)
        if callee is not None:
            self._call_edge(call, callee)
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            self._fn_value_edge(arg)

    def _call_edge(self, call: ast.Call, callee: FunctionInfo):
        names = callee.all_params
        offset = 0
        if names and names[0] in ("self", "cls") and \
                isinstance(call.func, ast.Attribute):
            offset = 1
        tainted = set()
        for i, a in enumerate(call.args):
            if isinstance(a, ast.Starred):
                continue
            j = i + offset
            if j < len(names) and self.is_tainted(a):
                tainted.add(names[j])
        for kw in call.keywords:
            if kw.arg and kw.arg in names and self.is_tainted(kw.value):
                tainted.add(kw.arg)
        if tainted:
            self.enqueue(callee, frozenset(tainted))

    def _fn_value_edge(self, arg):
        """A function *value* passed into a call inside traced code is
        assumed traced with every parameter a tracer (lax control flow,
        vmap, grad, tree.map bodies)."""
        if isinstance(arg, ast.Lambda):
            params = [p.arg for p in arg.args.posonlyargs
                      + arg.args.args + arg.args.kwonlyargs]
            saved = set(self.tainted)
            self.tainted |= set(params)
            for node in ast.walk(arg.body):
                if isinstance(node, ast.Call):
                    self._check_sinks(node)
            self.tainted = saved
            return
        if isinstance(arg, ast.Call) and _is_partial(self.module, arg) \
                and arg.args:
            inner = self.r.resolve(self.module, self.fi.qualname,
                                   self.fi.cls_name, arg.args[0])
            if inner is not None:
                bound_pos = len(arg.args) - 1
                bound_kw = {kw.arg for kw in arg.keywords if kw.arg}
                ps = inner.params
                tset = frozenset(p for i, p in enumerate(ps)
                                 if i >= bound_pos and p not in bound_kw)
                if tset:
                    self.enqueue(inner, tset)
            return
        if isinstance(arg, ast.Name):
            fi = self.r.resolve(self.module, self.fi.qualname,
                                self.fi.cls_name, arg)
            if fi is not None and fi.params:
                self.enqueue(fi, frozenset(fi.params))


# --------------------------------------------------------------------------
# hot-path sync scan (no taint needed)
# --------------------------------------------------------------------------


def _hot_sync_scan(project: Project, resolver: _Resolver,
                   findings: list):
    queue = deque()
    seen = set()
    for mod_name, qual in HOT_PATHS:
        mod = project.modules.get(mod_name)
        if mod and qual in mod.functions:
            queue.append((mod.functions[qual], f"{mod_name}:{qual}"))
    flagged = set()
    while queue:
        fi, root = queue.popleft()
        key = (id(fi.module), fi.qualname, root)
        if key in seen:
            continue
        seen.add(key)
        for call in _scope_calls(fi.node.body):
            f = call.func
            is_sync = any(_is_jax_attr(fi.module, f, a)
                          for a in _SYNC_ATTRS)
            if isinstance(f, ast.Attribute) and \
                    f.attr == "block_until_ready" and not call.args:
                is_sync = True              # arr.block_until_ready()
            if is_sync:
                fkey = (str(fi.module.path), call.lineno)
                if fkey not in flagged:
                    flagged.add(fkey)
                    findings.append(Finding(
                        "hot-sync", str(fi.module.path), call.lineno,
                        f"host sync in per-tick hot path {root} "
                        f"(via {fi.qualname}); gate it behind an "
                        "opt-in latency-stats flag"))
                continue
            callee = resolver.resolve(fi.module, fi.qualname,
                                      fi.cls_name, f)
            if callee is not None:
                queue.append((callee, root))


# --------------------------------------------------------------------------
# pass driver
# --------------------------------------------------------------------------


def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    resolver = _Resolver(project)

    seen: set[tuple] = set()
    queue: deque = deque()

    def enqueue(fi: FunctionInfo, tainted: frozenset):
        key = (id(fi.module), fi.qualname, tainted)
        if key not in seen:
            seen.add(key)
            queue.append((fi, tainted))

    for fi, tainted in find_jit_sites(project, resolver):
        enqueue(fi, tainted)
    for mod_name, fname in EXTRA_ROOTS:
        mod = project.modules.get(mod_name)
        if mod and fname in mod.functions:
            fi = mod.functions[fname]
            seeds = _no_default_params(fi)
            if seeds:
                enqueue(fi, seeds)

    budget = 4000                      # worklist backstop
    while queue and budget:
        budget -= 1
        fi, tainted = queue.popleft()
        _FnTaint(resolver, fi, tainted, findings, enqueue).run()

    _hot_sync_scan(project, resolver, findings)
    return findings
