"""Pass 2 — donation safety: no reads of a donated buffer after the call.

``jax.jit(fn, donate_argnums=...)`` marks argument buffers as dead on
entry: the backend may alias them into the outputs, and any later host
read of the old array raises ``RuntimeError: Array has been deleted``
(or silently reads garbage on backends without the check).  This pass
tracks every jit/TraceGuard object created with ``donate_argnums`` —
local variables *and* ``self._x`` attributes declared in one method and
called in another — and, at each call site, flags a ``Load`` of a
donated argument expression after the call in the enclosing scope,
unless it was rebound first (the canonical
``self._state = self._advance(params, self._state)`` shape rebinds in
the very statement, which is safe).

Paths are tracked as dotted Name/Attribute chains ("params",
"self._state").  A read of the donated path *or any extension of it*
("self._state.caches") counts; a store to the path *or any prefix*
clears it.  Calls inside a loop wrap around: if the donated path is not
rebound by the end of the loop body, the next iteration's call re-reads
the dead buffer and is flagged at the call line.  ``jfn.lower(...)``
only traces — it is not a call of the donated function and never flags
(the ``launch/dryrun.py`` pattern).
"""

from __future__ import annotations

import ast

from .astutils import Module, Project, attr_path
from .rules import Finding

__all__ = ["run"]

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _is_jit_ctor(module: Module, call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "jit" and \
            isinstance(f.value, ast.Name) and \
            module.import_aliases.get(f.value.id) == "jax":
        return True
    if isinstance(f, ast.Name) and f.id == "TraceGuard":
        return True
    if isinstance(f, ast.Attribute) and f.attr == "TraceGuard":
        return True
    return False


def _donate_argnums(call: ast.Call) -> tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, int))
    return ()


def _collect_decls(module: Module) -> dict[str, tuple[int, ...]]:
    """Every ``<path> = jax.jit(..., donate_argnums=...)`` in the
    module, path as written ("jfn", "self._advance")."""
    decls: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Assign) and
                isinstance(node.value, ast.Call) and
                _is_jit_ctor(module, node.value)):
            continue
        nums = _donate_argnums(node.value)
        if not nums:
            continue
        for tgt in node.targets:
            path = attr_path(tgt)
            if path:
                decls[path] = nums
    return decls


def _stores_in(stmt: ast.stmt, path: str) -> bool:
    """Does this statement bind ``path`` or a prefix of it?"""
    for node in ast.walk(stmt):
        if isinstance(node, (ast.Name, ast.Attribute)) and \
                isinstance(getattr(node, "ctx", None), ast.Store):
            p = attr_path(node)
            if p and (p == path or path.startswith(p + ".")):
                return True
        if isinstance(node, (ast.For, ast.AsyncFor)):
            p = attr_path(node.target)
            if p and (p == path or path.startswith(p + ".")):
                return True
    return False


def _reads_in(node: ast.AST, path: str) -> int | None:
    """Line of the first Load of ``path`` (or an extension of it) in
    this subtree."""
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)) and \
                isinstance(getattr(n, "ctx", None), ast.Load):
            p = attr_path(n)
            if p and (p == path or p.startswith(path + ".")):
                return n.lineno
    return None


class _ScopeCheck:
    """Check one function scope for post-donation reads."""

    def __init__(self, module: Module, decls: dict, findings: list,
                 scope_name: str):
        self.module = module
        self.decls = decls
        self.findings = findings
        self.scope_name = scope_name

    def check(self, body: list):
        self._walk_block(body, after=[])

    # ``after``: list of statement blocks that execute after the current
    # block finishes (innermost first), used to continue the read scan
    # past the enclosing statement.
    def _walk_block(self, body: list, after: list):
        for i, stmt in enumerate(body):
            if isinstance(stmt, _DEFS):
                continue
            rest = body[i + 1:]
            for call in self._donating_calls(stmt):
                self._check_call(stmt, call, rest, after)
            # recurse into compound statements
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                # loop bodies wrap around: the body itself re-executes
                self._walk_block(stmt.body,
                                 [stmt.body, rest] + after)
                self._walk_block(stmt.orelse, [rest] + after)
            elif isinstance(stmt, ast.If):
                self._walk_block(stmt.body, [rest] + after)
                self._walk_block(stmt.orelse, [rest] + after)
            elif isinstance(stmt, ast.With):
                self._walk_block(stmt.body, [rest] + after)
            elif isinstance(stmt, ast.Try):
                for blk in (stmt.body, stmt.orelse, stmt.finalbody):
                    self._walk_block(blk, [rest] + after)
                for h in stmt.handlers:
                    self._walk_block(h.body, [rest] + after)

    def _donating_calls(self, stmt: ast.stmt):
        for node in ast.walk(stmt):
            if isinstance(node, _DEFS):
                continue
            if isinstance(node, ast.Call):
                p = attr_path(node.func)
                if p and p in self.decls:
                    yield node

    def _check_call(self, stmt: ast.stmt, call: ast.Call,
                    rest: list, after: list):
        nums = self.decls[attr_path(call.func)]
        for idx in nums:
            if idx >= len(call.args):
                continue
            path = attr_path(call.args[idx])
            if path is None:
                continue                 # literal/temporary: dies here
            if _stores_in(stmt, path):
                continue                 # rebound in the call statement
            # the call statement itself is excluded from `rest`, but a
            # loop wrap-around block may contain it again — there the
            # re-call's own argument read is a genuine dead-buffer read
            line = self._first_read(path, [rest] + after)
            if line is not None:
                self.findings.append(Finding(
                    "post-donation-read", str(self.module.path), line,
                    f"`{path}` was donated to "
                    f"`{attr_path(call.func)}` (donate_argnums includes"
                    f" {idx}) at line {call.lineno} in "
                    f"{self.scope_name} and is read afterwards — the "
                    "buffer is deleted; rebind it from the call's "
                    "output or drop the donation"))

    def _first_read(self, path: str, blocks: list) -> int | None:
        for block in blocks:
            for stmt in block:
                if isinstance(stmt, _DEFS):
                    continue
                line = _reads_in(stmt, path)
                if line is not None:
                    return line
                if _stores_in(stmt, path):
                    return None          # rebound before any read
        return None


def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for module in project.modules.values():
        decls = _collect_decls(module)
        if not decls:
            continue
        for fi in module.functions.values():
            _ScopeCheck(module, decls, findings,
                        fi.qualname).check(fi.node.body)
        _ScopeCheck(module, decls, findings,
                    "<module>").check(module.tree.body)
    return findings
