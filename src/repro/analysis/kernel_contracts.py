"""Pass 3 — Pallas kernel contracts, checked on CPU without a TPU.

The paged kernels' correctness rests on invariants no unit test states
directly: every BlockSpec index map must stay inside its operand at
every grid point (the block table's ``-1`` holes redirect to the null
page, never out of the pool), scratch buffers must be (8, 128)-tile
aligned whenever the plan promises tile alignment, ``plan_exec`` must
resolve the full (interpret, pad) matrix to its documented modes, and
the masking contract (null pages, ``pos = -1`` holes, ``cache_limit``,
sliding window, MLA) must stay pinned by parity tests.

The differentiable training kernel gets the same treatment: the
``block_diff_attention`` matrix (aligned/subtile × compiled/interpret)
is driven *through ``jax.grad``*, so one capture records the
lse-emitting forward plus both backward launches (dQ, dKV) and their
BlockSpecs/scratch are bounds- and tile-checked like any other launch;
``kernel-parity-coverage`` additionally requires the gradient-parity
grid in ``tests/test_kernels.py`` to keep the VJP pinned vs autodiff.

None of this needs a TPU.  ``capture_launches`` monkeypatches
``pl.pallas_call`` on the shared pallas module (both kernel files bind
it via ``from jax.experimental import pallas as pl``, so the attribute
lookup happens at call time) to *record* each launch — grid, specs,
scratch, concrete operands — and return zeros of ``out_shape`` instead
of running.  Index maps are then evaluated over the whole grid with the
real scalar-prefetch operands (vmapped, so the table lookups inside the
maps run as one batched computation) and bounds-checked against the
operand shapes.  A separate ``jax.eval_shape`` of the *unpatched*
kernel traces the kernel body abstractly, catching in-body shape
mismatches that capture alone would miss.
"""

from __future__ import annotations

import ast
import contextlib
import dataclasses
import functools
import itertools
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl_mod

from .rules import Finding

__all__ = ["capture_launches", "check_launch", "check_kernels",
           "check_parity_coverage", "run"]

_LANES = 128
_SUBLANES = 8

_DEFAULT_TESTS = Path(__file__).resolve().parents[3] / "tests" / \
    "test_paged_attn.py"


# ---------------------------------------------------------------------------
# launch capture
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Launch:
    """One recorded ``pl.pallas_call`` invocation."""
    name: str                      # kernel body function name
    grid: tuple
    num_scalar_prefetch: int
    in_specs: list                 # BlockSpec per non-prefetch operand
    out_specs: list
    scratch: list                  # [(shape tuple, dtype), ...]
    operands: list                 # concrete args (prefetch first)
    out_shapes: list               # [(shape, dtype), ...]
    interpret: bool


def _sds_list(out_shape) -> list:
    if isinstance(out_shape, (list, tuple)):
        return [(tuple(o.shape), o.dtype) for o in out_shape]
    return [(tuple(out_shape.shape), out_shape.dtype)]


@contextlib.contextmanager
def capture_launches():
    """Patch ``pallas_call`` to record launches and return zeros.

    Yields the list that accumulates ``Launch`` records.  The kernel
    body never runs and nothing is lowered, so this works on any
    backend — including "compiled"-mode plans on a CPU host.
    """
    launches: list[Launch] = []
    real = pl_mod.pallas_call

    def fake(kernel, *, grid_spec=None, grid=None, in_specs=None,
             out_specs=None, out_shape=None, scratch_shapes=(),
             interpret=False, **_kw):
        if grid_spec is not None:
            g = tuple(grid_spec.grid)
            npf = int(getattr(grid_spec, "num_scalar_prefetch", 0))
            ins = list(grid_spec.in_specs)
            outs = grid_spec.out_specs
            scr = grid_spec.scratch_shapes
        else:
            g = tuple(grid) if grid is not None else ()
            npf = 0
            ins = list(in_specs or [])
            outs = out_specs
            scr = scratch_shapes
        outs = list(outs) if isinstance(outs, (list, tuple)) else [outs]
        scratch = [(tuple(s.shape), getattr(s, "dtype", None))
                   for s in (scr or [])]
        name = getattr(getattr(kernel, "func", kernel), "__name__",
                       "<kernel>")
        shapes = _sds_list(out_shape)

        def runner(*operands):
            launches.append(Launch(name, g, npf, ins, outs, scratch,
                                   list(operands), shapes,
                                   bool(interpret)))
            zeros = [jnp.zeros(s, d) for s, d in shapes]
            if isinstance(out_shape, (list, tuple)):
                return type(out_shape)(zeros)
            return zeros[0]

        return runner

    pl_mod.pallas_call = fake
    try:
        yield launches
    finally:
        pl_mod.pallas_call = real


# ---------------------------------------------------------------------------
# per-launch checks
# ---------------------------------------------------------------------------


def _eval_index_map(index_map, grid: tuple, prefetch: list):
    """Evaluate ``index_map`` at every grid point in one batched call.

    Returns an int array of shape (n_points, n_block_dims)."""
    points = np.array(list(itertools.product(*(range(g) for g in grid))),
                      dtype=np.int32)

    def at_point(pt):
        idx = index_map(*(pt[i] for i in range(len(grid))), *prefetch)
        # anchor constants to the batch so vmap output is uniform
        return tuple(jnp.asarray(x) + 0 * pt[0] for x in idx)

    cols = jax.vmap(at_point)(points)
    return np.stack([np.asarray(c) for c in cols], axis=1)


def check_launch(launch: Launch, *, require_tile: bool, path: str,
                 line: int, where: str) -> list[Finding]:
    """Bounds-check every index map and (optionally) scratch tiling."""
    findings: list[Finding] = []
    prefetch = [jnp.asarray(x) for x in
                launch.operands[:launch.num_scalar_prefetch]]
    block_ops = launch.operands[launch.num_scalar_prefetch:]
    pairs = list(zip(launch.in_specs,
                     [tuple(o.shape) for o in block_ops])) + \
        list(zip(launch.out_specs, [s for s, _ in launch.out_shapes]))

    for spec_i, (spec, shape) in enumerate(pairs):
        block = tuple(spec.block_shape)
        if len(block) != len(shape):
            findings.append(Finding(
                "kernel-oob-index", path, line,
                f"{where}: spec #{spec_i} block rank {len(block)} != "
                f"operand rank {len(shape)} ({block} vs {shape})"))
            continue
        idx = _eval_index_map(spec.index_map, launch.grid, prefetch)
        for d, bs in enumerate(block):
            if bs is None:
                continue
            col = idx[:, d]
            bad = (col < 0) | ((col + 1) * bs > shape[d])
            if bad.any():
                pt = tuple(int(x) for x in
                           np.array(list(itertools.product(
                               *(range(g) for g in launch.grid))))
                           [int(np.argmax(bad))])
                findings.append(Finding(
                    "kernel-oob-index", path, line,
                    f"{where}: spec #{spec_i} dim {d} block index "
                    f"{int(col[int(np.argmax(bad))])} x block {bs} "
                    f"escapes operand dim {shape[d]} at grid point "
                    f"{pt}"))
                break

    if require_tile:
        for i, (shape, dtype) in enumerate(launch.scratch):
            if len(shape) < 2:
                continue
            if shape[-1] % _LANES or shape[-2] % _SUBLANES:
                findings.append(Finding(
                    "kernel-scratch-tile", path, line,
                    f"{where}: scratch #{i} shape {shape} "
                    f"({dtype}) is not ({_SUBLANES}, {_LANES})-tile "
                    "aligned but the plan promises tile alignment"))
    return findings


# ---------------------------------------------------------------------------
# kernel drivers: real shapes, full plan matrix
# ---------------------------------------------------------------------------


def _decode_args(*, aligned: bool):
    from ..kernels import paged_attn as pa
    if aligned:
        B, n, H, Hkv, Dk, Dv, P, K = 2, 8, 4, 2, 128, 128, 6, 3
    else:
        B, n, H, Hkv, Dk, Dv, P, K = 2, 4, 4, 2, 40, 40, 5, 3
    # table exercises -1 holes, the max page id, and an all-hole row
    table = np.full((B, K), -1, np.int32)
    table[0, 0] = P - 1
    table[0, 2] = 0
    args = (
        jnp.zeros((B, n, H, Dk), jnp.float32),
        jnp.zeros((P, n, Hkv, Dk), jnp.float32),
        jnp.zeros((P, n, Hkv, Dv), jnp.float32),
        jnp.zeros((P, n), jnp.int32),
        jnp.asarray(table),
        jnp.zeros((B, n, Hkv, Dk), jnp.float32),
        jnp.zeros((B, n, Hkv, Dv), jnp.float32),
        jnp.zeros((B, n), jnp.int32),
        jnp.zeros((B,), jnp.int32),
    )
    return pa.paged_decode_attention, args, (n, Dk, Dv), (B, n, H, Dv)


def _prefill_args(*, aligned: bool):
    from ..kernels import paged_attn as pa
    if aligned:
        B, bsz, Ts, H, Hkv, Dk, Dv, P, Kp = 2, 8, 2, 4, 2, 128, 128, 6, 2
    else:
        # Kp + Ts chosen so the compact scratch row count stays a
        # sublane multiple under tile padding (Lk = (Kp+Ts)*bsz = 16)
        B, bsz, Ts, H, Hkv, Dk, Dv, P, Kp = 2, 4, 2, 4, 2, 40, 40, 5, 2
    T = Ts * bsz
    table = np.full((B, Kp), -1, np.int32)
    table[0, 0] = P - 1
    table[1, :] = [0, 1]
    args = (
        jnp.zeros((B, T, H, Dk), jnp.float32),
        jnp.zeros((P, bsz, Hkv, Dk), jnp.float32),
        jnp.zeros((P, bsz, Hkv, Dv), jnp.float32),
        jnp.zeros((P, bsz), jnp.int32),
        jnp.asarray(table),
        jnp.zeros((B, T, Hkv, Dk), jnp.float32),
        jnp.zeros((B, T, Hkv, Dv), jnp.float32),
        jnp.zeros((B, T), jnp.int32),
    )
    return pa.paged_prefill_attention, args, (bsz, Dk, Dv), (B, T, H, Dv)


# (shape, plan_exec kwargs, expected mode, expected padded)
_PLAN_MATRIX = [
    ("aligned", dict(interpret=True, pad=False), "interpret", False),
    ("subtile", dict(interpret=True, pad=True), "interpret", True),
    ("aligned", dict(interpret=False, pad=False), "compiled", False),
    ("subtile", dict(interpret=False, pad=None), "compiled", True),
]


def _check_paged_kernel(make_args, label: str) -> list[Finding]:
    from ..kernels import paged_attn as pa
    findings: list[Finding] = []
    path = str(Path(pa.__file__))
    for shape_kind, kw, want_mode, want_padded in _PLAN_MATRIX:
        fn, args, (bsz, dk, dv), out_shape = make_args(
            aligned=shape_kind == "aligned")
        line = fn.__code__.co_firstlineno
        where = f"{label}[{shape_kind}, interpret={kw['interpret']}, " \
            f"pad={kw['pad']}]"
        plan = pa.plan_exec(bsz, dk, dv, **kw)
        if (plan.mode, plan.padded) != (want_mode, want_padded):
            findings.append(Finding(
                "kernel-plan-matrix", path, line,
                f"{where}: plan_exec resolved to ({plan.mode}, "
                f"padded={plan.padded}), documented mode is "
                f"({want_mode}, padded={want_padded})"))
            continue
        call = functools.partial(fn, scale=1.0, **kw)
        with capture_launches() as launches:
            out = call(*args)
        if tuple(out.shape) != out_shape:
            findings.append(Finding(
                "kernel-plan-matrix", path, line,
                f"{where}: output shape {tuple(out.shape)} != expected "
                f"{out_shape}"))
        if not launches:
            findings.append(Finding(
                "kernel-plan-matrix", path, line,
                f"{where}: no pallas_call launch was captured"))
            continue
        require_tile = plan.padded or plan.mode == "compiled"
        for launch in launches:
            findings.extend(check_launch(
                launch, require_tile=require_tile, path=path, line=line,
                where=where))
        # abstract-eval the unpatched kernel: traces the real kernel
        # body with block-shaped avals, catching in-body mismatches
        try:
            jax.eval_shape(call, *args)
        except Exception as e:  # pragma: no cover - defect path
            findings.append(Finding(
                "kernel-plan-matrix", path, line,
                f"{where}: kernel failed abstract evaluation: "
                f"{type(e).__name__}: {e}"))
    # the documented fallback: padding disabled + compiled + sub-tile
    plan = pa.plan_exec(4, 40, 40, interpret=False, pad=False)
    if plan.mode != "interpret" or plan.padded:
        findings.append(Finding(
            "kernel-plan-matrix", path, 1,
            "plan_exec(subtile, interpret=False, pad=False) must fall "
            f"back to interpret mode, got ({plan.mode}, "
            f"padded={plan.padded})"))
    return findings


# (shape kind, interpret, require_tile): the aligned shape uses the
# production 128-tiles (scratch must hold the (8, 128) tile), the
# subtile shape exercises the clamped small-tile path trainers/tests
# run on CPU; both are checked compiled AND interpret — capture never
# lowers, so the compiled specs are checkable on a CPU host
_BLOCK_DIFF_MATRIX = [
    ("aligned", True, True),
    ("aligned", False, True),
    ("subtile", True, False),
    ("subtile", False, False),
]

# every kernel body the differentiable attention must launch: the
# (lse-emitting) forward plus the dQ / dKV backward pair
_BLOCK_DIFF_KERNELS = ("_kernel", "_dq_kernel", "_dkv_kernel")


def _block_diff_args(*, aligned: bool):
    if aligned:
        B, L, H, Hkv, D, Dv, t = 1, 256, 2, 1, 128, 128, 128
    else:
        B, L, H, Hkv, D, Dv, t = 1, 64, 4, 2, 32, 24, 16
    args = (
        jnp.zeros((B, L, H, D), jnp.float32),
        jnp.zeros((B, L, Hkv, D), jnp.float32),
        jnp.zeros((B, L, Hkv, Dv), jnp.float32),
        jnp.zeros((B, L, 4), jnp.int32),
        jnp.zeros((B, L, 4), jnp.int32),
        jnp.ones((B, L // t, L // t), jnp.int32),
    )
    return args, t, (B, L, H, Dv)


def _check_block_diff() -> list[Finding]:
    from ..kernels import block_diff_attn as bd
    findings: list[Finding] = []
    path = str(Path(bd.__file__))
    line = bd.block_diff_attention.__code__.co_firstlineno
    for shape_kind, interpret, require_tile in _BLOCK_DIFF_MATRIX:
        args, t, out_shape = _block_diff_args(
            aligned=shape_kind == "aligned")
        q, k, v, qm, km, tm = args
        where = f"block_diff_attention[{shape_kind}, " \
            f"interpret={interpret}]"
        call = functools.partial(bd.block_diff_attention, tq=t, tk=t,
                                 interpret=interpret)

        # differentiate through the kernel so ONE capture records the
        # lse-emitting forward plus both backward launches
        def grad_call(q, k, v):
            return jax.grad(
                lambda *a: jnp.sum(call(*a, qm, km, tm)
                                   .astype(jnp.float32)),
                argnums=(0, 1, 2))(q, k, v)

        with capture_launches() as launches:
            out = call(*args)          # inference forward (no lse)
            grad_call(q, k, v)         # training fwd + dQ + dKV
        if tuple(out.shape) != out_shape:
            findings.append(Finding(
                "kernel-plan-matrix", path, line,
                f"{where}: output shape {tuple(out.shape)} != expected "
                f"{out_shape}"))
        seen = {launch.name for launch in launches}
        for kern in _BLOCK_DIFF_KERNELS:
            if kern not in seen:
                findings.append(Finding(
                    "kernel-plan-matrix", path, line,
                    f"{where}: differentiating never launched {kern} "
                    f"(captured: {sorted(seen)})"))
        for launch in launches:
            findings.extend(check_launch(
                launch, require_tile=require_tile, path=path, line=line,
                where=f"{where}:{launch.name}"))
        # abstract-eval the unpatched forward AND backward bodies
        try:
            jax.eval_shape(call, *args)
            jax.eval_shape(grad_call, q, k, v)
        except Exception as e:  # pragma: no cover - defect path
            findings.append(Finding(
                "kernel-plan-matrix", path, line,
                f"{where}: failed abstract evaluation: "
                f"{type(e).__name__}: {e}"))
    return findings


def check_kernels() -> list[Finding]:
    """All capture/abstract-eval checks for the kernel family."""
    findings = _check_paged_kernel(_decode_args, "paged_decode_attention")
    findings += _check_paged_kernel(_prefill_args,
                                    "paged_prefill_attention")
    findings += _check_block_diff()
    return findings


# ---------------------------------------------------------------------------
# parity-test coverage of the masking contract
# ---------------------------------------------------------------------------

# feature -> regex over a test function's *effective* source (its own
# body + decorators + directly-called module-level helpers)
_DECODE_FEATURES = {
    "null page (table -1 holes)": r"table.{0,80}-\s*1|-\s*1.{0,80}table",
    "pos = -1 slot holes": r"pos.{0,60}-\s*1",
    "cache_limit edges": r"cache_limit",
    "sliding window": r"window.{0,80}\d",
    "MLA latent shape": r"\bmla\b",
}
_PREFILL_FEATURES = {
    "stale/unmapped pool rows": r"stale|poison",
    "pos = -1 slot holes": r"pos.{0,60}-\s*1",
    "sliding window": r"window.{0,80}\d",
    "MLA latent shape": r"\bmla\b",
}
_DECODE_USE = re.compile(r"block_table|paged_decode_attention")
_PREFILL_USE = re.compile(r"context_table|paged_prefill_attention")

# gradient-parity coverage of the differentiable training kernels
# (tests/test_kernels.py): the custom-VJP backward must stay pinned
# against autodiff across the mask-feature grid
_TRAIN_DEFAULT_TESTS = Path(__file__).resolve().parents[3] / "tests" / \
    "test_kernels.py"
_TRAIN_FEATURES = {
    "gradient parity (VJP vs autodiff)": r"jax\.grad|value_and_grad",
    "grouped heads (GQA/MQA/MLA)": r"\bHkv\b",
    "sliding window": r"window.{0,80}\d",
    "softcap tanh chain rule": r"softcap.{0,80}\d",
    "strict packed layout": r"packed|strict",
    "zero grads at INVALID_COPY padding": r"invalid|INVALID_COPY",
}
_TRAIN_USE = re.compile(
    r"[\"']pallas(_interpret)?[\"']|block_diff_attention")


def _effective_sources(source: str) -> dict[str, str]:
    """Test name -> its source expanded with called top-level helpers."""
    tree = ast.parse(source)
    helpers: dict[str, str] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            helpers[node.name] = ast.get_source_segment(source, node) or ""
    out: dict[str, str] = {}
    for node in tree.body:
        if not (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name.startswith("test")):
            continue
        parts = [ast.get_source_segment(source, d) or ""
                 for d in node.decorator_list]
        parts.append(helpers[node.name])
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id in helpers and \
                    n.id != node.name:
                parts.append(helpers[n.id])
        out[node.name] = "\n".join(parts)
    return out


def _coverage_of(path: Path, kernels) -> list[Finding]:
    if not path.exists():
        return [Finding("kernel-parity-coverage", str(path), 1,
                        "parity test file is missing")]
    sources = _effective_sources(path.read_text())
    findings: list[Finding] = []
    for kernel, use_re, features in kernels:
        relevant = [s for s in sources.values() if use_re.search(s)]
        if not relevant:
            findings.append(Finding(
                "kernel-parity-coverage", str(path), 1,
                f"no parity test exercises {kernel} at all"))
            continue
        for feature, rx in features.items():
            if not any(re.search(rx, s, re.S) for s in relevant):
                findings.append(Finding(
                    "kernel-parity-coverage", str(path), 1,
                    f"masking-contract feature `{feature}` of {kernel} "
                    "is not exercised by any parity test"))
    return findings


def check_parity_coverage(tests_path=None,
                          train_tests_path=None) -> list[Finding]:
    serve = Path(tests_path) if tests_path else _DEFAULT_TESTS
    train = Path(train_tests_path) if train_tests_path \
        else _TRAIN_DEFAULT_TESTS
    findings = _coverage_of(serve, (
        ("paged_decode_attention", _DECODE_USE, _DECODE_FEATURES),
        ("paged_prefill_attention", _PREFILL_USE, _PREFILL_FEATURES)))
    findings += _coverage_of(train, (
        ("block_diff_attention (training VJP)", _TRAIN_USE,
         _TRAIN_FEATURES),))
    return findings


def run(project=None, tests_path=None) -> list[Finding]:
    return check_kernels() + check_parity_coverage(tests_path)
