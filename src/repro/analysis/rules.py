"""dirlint rule registry, findings, and suppression pragmas.

Every contract the analyzer enforces is one ``Rule`` subclass with a
stable ``id`` — the string that appears in reports, in suppression
pragmas, and in ROADMAP's "standing contracts" table.  Passes emit
``Finding`` records tagged with a rule id; the registry is the single
place a new contract is declared, so adding one is: subclass ``Rule``
(anywhere that gets imported), emit findings with its id.

Suppression: a comment ``# dirlint: ok(rule-id)`` — on the flagged line
or the line directly above it — marks a finding as deliberate.  Several
ids may be listed: ``# dirlint: ok(hot-sync, trace-host-pull)``.
Suppressed findings are still collected (``--verbose`` shows them) but
never fail ``--strict``.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["Finding", "Rule", "RULES", "register", "scan_pragmas",
           "apply_pragmas"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or deliberate, pragma'd exception)."""
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


RULES: dict[str, type["Rule"]] = {}


def register(cls: type["Rule"]) -> type["Rule"]:
    assert cls.id and cls.id not in RULES, cls
    RULES[cls.id] = cls
    return cls


class Rule:
    """Base class: one enforced contract.  Subclasses set ``id`` (the
    stable kebab-case identifier) and ``doc`` (one-line contract
    statement shown by ``--list-rules``)."""
    id: str = ""
    doc: str = ""


# --------------------------------------------------------------------------
# pass 1: trace hygiene (analysis.trace_lint)
# --------------------------------------------------------------------------


@register
class TraceBranchRule(Rule):
    id = "trace-branch"
    doc = ("no Python-level if/while/for/assert on a traced value inside "
           "jit-reachable code (retraces per value, or leaks a tracer)")


@register
class TraceHostPullRule(Rule):
    id = "trace-host-pull"
    doc = ("no .item()/.tolist()/float()/int()/bool()/np.asarray on a "
           "traced value inside jit-reachable code (host round-trip "
           "breaks tracing)")


@register
class HotSyncRule(Rule):
    id = "hot-sync"
    doc = ("no jax.block_until_ready/jax.device_get in per-tick serving "
           "or per-step training hot paths (serializes dispatch)")


@register
class ObsInTraceRule(Rule):
    id = "obs-in-trace"
    doc = ("no obs.metrics / obs.trace call reachable inside a jitted "
           "body — instrumentation is host-side bookkeeping between "
           "dispatches; inside a trace it records trace-time garbage "
           "(or leaks a tracer into the span/metric)")


# --------------------------------------------------------------------------
# pass 2: donation safety (analysis.donation)
# --------------------------------------------------------------------------


@register
class PostDonationReadRule(Rule):
    id = "post-donation-read"
    doc = ("an argument donated to a jit call (donate_argnums) must not "
           "be read afterwards in the enclosing scope unless the call "
           "statement rebinds it")


# --------------------------------------------------------------------------
# pass 3: Pallas kernel contracts (analysis.kernel_contracts)
# --------------------------------------------------------------------------


@register
class KernelOOBIndexRule(Rule):
    id = "kernel-oob-index"
    doc = ("every BlockSpec index map must stay within the operand's "
           "bounds at every grid point (block tables included: -1 holes "
           "redirect to the null page, never out of the pool)")


@register
class KernelScratchTileRule(Rule):
    id = "kernel-scratch-tile"
    doc = ("kernel scratch shapes must be (8, 128)-tile-aligned exactly "
           "when KernelPlan.padded promises tile alignment (and always "
           "in compiled mode)")


@register
class KernelPlanMatrixRule(Rule):
    id = "kernel-plan-matrix"
    doc = ("plan_exec must resolve every (interpret, pad) combination to "
           "the documented mode, and the kernel must abstract-eval "
           "cleanly under each")


@register
class KernelParityCoverageRule(Rule):
    id = "kernel-parity-coverage"
    doc = ("each masking-contract feature (null page, pos=-1 holes, "
           "cache_limit, SWA window, MLA) must be exercised by >= 1 "
           "parity test per kernel in tests/test_paged_attn.py")


# --------------------------------------------------------------------------
# pragmas
# --------------------------------------------------------------------------

_PRAGMA_RE = re.compile(r"#\s*dirlint:\s*ok\(([^)]*)\)")


def scan_pragmas(source: str) -> dict[int, set[str]]:
    """Line number (1-based) -> set of rule ids suppressed there."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
            out[i] = ids
    return out


def apply_pragmas(findings: list[Finding],
                  pragmas: dict[str, dict[int, set[str]]]) -> list[Finding]:
    """Mark findings suppressed when a matching pragma sits on the
    flagged line or the line directly above it."""
    out = []
    for f in findings:
        per_file = pragmas.get(f.path, {})
        ids = per_file.get(f.line, set()) | per_file.get(f.line - 1, set())
        if f.rule in ids:
            f = dataclasses.replace(f, suppressed=True)
        out.append(f)
    return out
