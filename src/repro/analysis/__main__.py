"""dirlint CLI: ``python -m repro.analysis [--strict] [...]``.

Exit status: 0 when clean (or only suppressed findings), 1 under
``--strict`` when any unsuppressed finding remains, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import json
import sys

from . import RULES, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="dirlint: trace hygiene, donation safety, and "
                    "Pallas kernel contract checks")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--root", default=None,
                    help="package source root (default: installed repro)")
    ap.add_argument("--tests", default=None,
                    help="parity test file for coverage checks")
    ap.add_argument("--no-kernel-check", action="store_true",
                    help="skip the Pallas kernel capture pass")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every rule id and contract, then exit")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON lines")
    ap.add_argument("--verbose", action="store_true",
                    help="also show pragma-suppressed findings")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(rid) for rid in RULES)
        for rid, cls in sorted(RULES.items()):
            print(f"{rid.ljust(width)}  {cls.doc}")
        return 0

    findings = run_all(root=args.root, tests_path=args.tests,
                       kernel_check=not args.no_kernel_check)
    active = [f for f in findings if not f.suppressed]
    shown = findings if args.verbose else active

    for f in shown:
        if args.json:
            print(json.dumps({"rule": f.rule, "path": f.path,
                              "line": f.line, "message": f.message,
                              "suppressed": f.suppressed}))
        else:
            print(f.format())
    n_sup = sum(1 for f in findings if f.suppressed)
    if not args.json:
        print(f"dirlint: {len(active)} finding(s), "
              f"{n_sup} suppressed", file=sys.stderr)
    return 1 if (args.strict and active) else 0


if __name__ == "__main__":
    sys.exit(main())
