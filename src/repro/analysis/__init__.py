"""dirlint — contract-checking static analysis for the DiRL repro.

Three cooperating, CPU-runnable passes guard the contracts the test
suite can't state directly:

1. **Trace hygiene** (``trace_lint``): walks everything reachable from
   the repo's ``jax.jit``/``TraceGuard`` sites (serving scheduler &
   engine ticks, RL/SFT train steps, the launch loop) and flags Python
   control flow on traced values (``trace-branch``), host pulls of
   tracers — ``.item()``/``float()``/``np.asarray`` —
   (``trace-host-pull``), and ``jax.block_until_ready`` /
   ``jax.device_get`` inside per-tick hot paths (``hot-sync``).
2. **Donation safety** (``donation``): tracks every jit object created
   with ``donate_argnums`` — including ``self._x`` handles declared in
   one method and called from another — and flags reads of a donated
   buffer after the call (``post-donation-read``), loop wrap-arounds
   included.
3. **Pallas kernel contracts** (``kernel_contracts``): monkeypatch-
   captures ``pl.pallas_call`` launches from the real kernels, then
   bounds-checks every BlockSpec index map over the full grid with the
   real block tables (``kernel-oob-index``), checks (8, 128) scratch
   tiling whenever the plan promises tile alignment
   (``kernel-scratch-tile``), exercises the whole
   ``plan_exec`` (interpret x pad) matrix plus an abstract eval of each
   kernel body (``kernel-plan-matrix``), and cross-references
   ``tests/test_paged_attn.py`` for masking-contract coverage
   (``kernel-parity-coverage``).

Deliberate exceptions carry a pragma on the flagged line or the line
above: ``# dirlint: ok(rule-id)`` (comma-separate several ids).  The
CLI is ``python -m repro.analysis``; ``--strict`` exits non-zero on any
unsuppressed finding and is wired into CI ahead of the test jobs.

``guards.TraceGuard`` is the runtime companion: a jitted callable that
counts its own compilations (the zero-retrace witness the scheduler
and trainers expose through their stats) and can optionally run under
``jax.transfer_guard``.
"""

from __future__ import annotations

from pathlib import Path

from . import donation, kernel_contracts, trace_lint
from .astutils import Project
from .guards import TraceGuard
from .rules import RULES, Finding, apply_pragmas, scan_pragmas

__all__ = ["Finding", "RULES", "TraceGuard", "Project", "run_all"]

_SRC_ROOT = Path(__file__).resolve().parents[1]


def run_all(root=None, tests_path=None, *,
            kernel_check: bool = True) -> list[Finding]:
    """Run every pass; return findings with pragmas applied.

    ``root`` is the package source root (defaults to the installed
    ``src/repro``); ``tests_path`` overrides the parity-test file;
    ``kernel_check=False`` skips the (slower) kernel capture pass.
    """
    project = Project(Path(root) if root else _SRC_ROOT)
    findings = trace_lint.run(project)
    findings += donation.run(project)
    if kernel_check:
        findings += kernel_contracts.run(project, tests_path)

    pragmas: dict[str, dict[int, set[str]]] = {}
    for f in findings:
        if f.path not in pragmas:
            try:
                src = Path(f.path).read_text()
            except OSError:
                src = ""
            pragmas[f.path] = scan_pragmas(src)
    findings = apply_pragmas(findings, pragmas)
    # passes can rediscover one defect through several call paths
    seen, unique = set(), []
    for f in findings:
        key = (f.rule, f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    return unique
