"""Shared AST project model for the dirlint passes.

Loads every module under ``src/repro`` into a light call-resolution
index: dotted module names, import aliases, all (possibly nested)
function definitions with qualified names, and enough name resolution
to follow the repo's own call edges — plain calls, ``self.method``,
``module.function`` through import aliases, ``functools.partial``
targets, and factory functions that return a nested def (the
``make_train_step`` pattern).  External calls (jnp ops, stdlib) resolve
to ``None`` and are treated as opaque.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

__all__ = ["FunctionInfo", "Module", "Project", "attr_path"]


@dataclasses.dataclass
class FunctionInfo:
    qualname: str                  # dotted scope path within the module
    module: "Module"
    node: ast.AST                  # FunctionDef | AsyncFunctionDef
    cls_name: str | None           # directly-enclosing class, if any

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def params(self) -> list[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if names and names[0] in ("self", "cls"):
            names = names[1:]
        return names

    @property
    def all_params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]

    def __repr__(self):
        return f"<fn {self.module.name}:{self.qualname}>"


class _Collector(ast.NodeVisitor):
    def __init__(self, module: "Module"):
        self.module = module
        self.scope: list[str] = []
        self.cls: list[str | None] = [None]

    def _qual(self, name: str) -> str:
        return ".".join(self.scope + [name])

    def visit_ClassDef(self, node: ast.ClassDef):
        self.scope.append(node.name)
        self.cls.append(node.name)
        self.generic_visit(node)
        self.cls.pop()
        self.scope.pop()

    def _visit_fn(self, node):
        qual = self._qual(node.name)
        info = FunctionInfo(qual, self.module, node, self.cls[-1])
        self.module.functions[qual] = info
        scope_key = ".".join(self.scope)
        self.module.scoped.setdefault(scope_key, {})[node.name] = info
        self.scope.append(node.name)
        self.cls.append(None)
        self.generic_visit(node)
        self.cls.pop()
        self.scope.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn


class Module:
    def __init__(self, name: str, path: Path, source: str):
        self.name = name                    # e.g. "repro.serving.scheduler"
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        # import alias -> absolute dotted module ("jnp" -> "jax.numpy")
        self.import_aliases: dict[str, str] = {}
        # from-import local name -> (absolute module, attr)
        self.from_imports: dict[str, tuple[str, str]] = {}
        self.functions: dict[str, FunctionInfo] = {}
        # scope qualname ("" = module level) -> {name: FunctionInfo}
        self.scoped: dict[str, dict[str, FunctionInfo]] = {}
        # field names declared static via dataclasses.field(
        # metadata={"static": True}) — the jax.tree_util
        # register_dataclass convention: loads of these attributes are
        # host values even on traced pytrees
        self.static_fields: set[str] = _collect_static_fields(self.tree)
        self._collect_imports()
        _Collector(self).visit(self.tree)

    def _collect_imports(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_aliases[a.asname or
                                        a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:                     # relative import
                    parts = self.name.split(".")[:-node.level]
                    base = ".".join(parts + ([node.module]
                                             if node.module else []))
                for a in node.names:
                    self.from_imports[a.asname or a.name] = (base, a.name)


class Project:
    """All modules under one package root, plus cross-module resolution."""

    def __init__(self, root: Path, pkg: str = "repro"):
        self.root = Path(root)
        self.pkg = pkg
        self.modules: dict[str, Module] = {}
        for path in sorted(self.root.rglob("*.py")):
            rel = path.relative_to(self.root).with_suffix("")
            parts = list(rel.parts)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            name = ".".join([pkg] + parts)
            try:
                self.modules[name] = Module(name, path,
                                            path.read_text())
            except SyntaxError:
                pass
        self.static_fields: set[str] = set()
        for m in self.modules.values():
            self.static_fields |= m.static_fields

    # ------------------------------------------------------- resolution
    def module_of_alias(self, module: Module, name: str) -> Module | None:
        """A local name that denotes a repro module, if any."""
        tgt = module.import_aliases.get(name)
        if tgt and tgt in self.modules:
            return self.modules[tgt]
        fi = module.from_imports.get(name)
        if fi:
            dotted = f"{fi[0]}.{fi[1]}" if fi[0] else fi[1]
            if dotted in self.modules:
                return self.modules[dotted]
        return None

    def resolve_name(self, module: Module, scope: str,
                     name: str) -> FunctionInfo | None:
        """A bare name in ``scope`` (function qualname or "")."""
        parts = scope.split(".") if scope else []
        while True:
            key = ".".join(parts)
            hit = module.scoped.get(key, {}).get(name)
            if hit is not None:
                return hit
            if not parts:
                break
            parts.pop()
        fi = module.from_imports.get(name)
        if fi and fi[0] in self.modules:
            return self.modules[fi[0]].functions.get(fi[1])
        return None

    def resolve_callable(self, ctx_module: Module, ctx_scope: str,
                         ctx_cls: str | None,
                         node: ast.expr) -> FunctionInfo | None:
        """Resolve a call's func expression to a repo function, else
        None.  ``ctx_scope`` is the enclosing function's qualname ("" at
        module level); ``ctx_cls`` its class for ``self.X`` calls."""
        if isinstance(node, ast.Name):
            return self.resolve_name(ctx_module, ctx_scope, node.id)
        if isinstance(node, ast.Attribute):
            v = node.value
            if isinstance(v, ast.Name):
                if v.id in ("self", "cls") and ctx_cls:
                    return ctx_module.functions.get(
                        f"{ctx_cls}.{node.attr}")
                mod = self.module_of_alias(ctx_module, v.id)
                if mod is not None:
                    return mod.functions.get(node.attr)
            # dotted module alias: repro.core.decoding.advance_block
            path = attr_path(node.value)
            if path:
                dotted = path if path.startswith(self.pkg + ".") else None
                if dotted and dotted in self.modules:
                    return self.modules[dotted].functions.get(node.attr)
        return None

    def resolve_factory_return(self, fi: FunctionInfo) \
            -> FunctionInfo | None:
        """``def make_x(...): def x(...): ...; return x`` -> info(x)."""
        for stmt in ast.walk(fi.node):
            if isinstance(stmt, ast.Return) and \
                    isinstance(stmt.value, ast.Name):
                inner = fi.module.scoped.get(fi.qualname, {}) \
                    .get(stmt.value.id)
                if inner is not None:
                    return inner
        return None


def _collect_static_fields(tree) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and isinstance(stmt.value, ast.Call)):
                continue
            f = stmt.value.func
            is_field = (isinstance(f, ast.Name) and f.id == "field") or \
                (isinstance(f, ast.Attribute) and f.attr == "field")
            if not is_field:
                continue
            for kw in stmt.value.keywords:
                if kw.arg != "metadata" or \
                        not isinstance(kw.value, ast.Dict):
                    continue
                for k, v in zip(kw.value.keys, kw.value.values):
                    if isinstance(k, ast.Constant) and \
                            k.value == "static" and \
                            isinstance(v, ast.Constant) and v.value:
                        out.add(stmt.target.id)
    return out


def attr_path(node: ast.expr) -> str | None:
    """Dotted path of a Name/Attribute chain ("self._state.caches"),
    None for anything else (calls, subscripts...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
