"""Synthetic verifiable math tasks.

Stands in for OpenR1-Math (SFT) and Big-Math (RL) in the offline
container: problems have a canonical reasoning chain and an exactly
checkable integer answer (the math-verify role).  Format mirrors the
open-math convention the paper trains on:

    Q: 37+18*2=?
    A: 18*2=36. 37+36=73. #### 73

The reward checker parses the text after '####'.
"""

from __future__ import annotations

import dataclasses
import random


@dataclasses.dataclass
class MathProblem:
    question: str
    reasoning: str
    answer: int

    @property
    def prompt(self) -> str:
        return f"Q: {self.question}\nA:"

    @property
    def full(self) -> str:
        return f"{self.prompt} {self.reasoning} #### {self.answer}"


def _gen_add_small(rng: random.Random) -> MathProblem:
    """Level 0: single-digit sums — learnable by tiny CPU demo models."""
    a, b = rng.randint(1, 9), rng.randint(1, 9)
    return MathProblem(f"{a}+{b}=?", f"{a}+{b}={a + b}.", a + b)


def _gen_add(rng: random.Random) -> MathProblem:
    a, b = rng.randint(10, 999), rng.randint(10, 999)
    return MathProblem(f"{a}+{b}=?", f"{a}+{b}={a + b}.", a + b)


def _gen_sub(rng: random.Random) -> MathProblem:
    a, b = rng.randint(10, 999), rng.randint(10, 999)
    a, b = max(a, b), min(a, b)
    return MathProblem(f"{a}-{b}=?", f"{a}-{b}={a - b}.", a - b)


def _gen_mul(rng: random.Random) -> MathProblem:
    a, b = rng.randint(2, 99), rng.randint(2, 9)
    return MathProblem(f"{a}*{b}=?", f"{a}*{b}={a * b}.", a * b)


def _gen_mix(rng: random.Random) -> MathProblem:
    a, b, c = rng.randint(2, 99), rng.randint(2, 20), rng.randint(2, 9)
    mid = b * c
    ans = a + mid
    return MathProblem(f"{a}+{b}*{c}=?",
                       f"{b}*{c}={mid}. {a}+{mid}={ans}.", ans)


def _gen_linear(rng: random.Random) -> MathProblem:
    x = rng.randint(2, 30)
    a = rng.randint(2, 9)
    b = rng.randint(1, 50)
    c = a * x + b
    return MathProblem(f"{a}x+{b}={c}, x=?",
                       f"{a}x={c}-{b}={c - b}. x={c - b}//{a}={x}.", x)


GENERATORS = [_gen_add_small, _gen_add, _gen_sub, _gen_mul, _gen_mix,
              _gen_linear]


def sample_problem(rng: random.Random, level: int | None = None
                   ) -> MathProblem:
    gens = GENERATORS if level is None else GENERATORS[:level + 1]
    return rng.choice(gens)(rng)


def parse_answer(text: str) -> int | None:
    """Extract the '#### <int>' answer; None if absent/garbled."""
    if "####" not in text:
        return None
    tail = text.rsplit("####", 1)[1].strip()
    tok = tail.split()[0] if tail.split() else ""
    tok = tok.rstrip(".,;!")
    try:
        return int(tok)
    except ValueError:
        return None


def check_answer(text: str, expected: int) -> bool:
    return parse_answer(text) == expected
