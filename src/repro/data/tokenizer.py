"""Byte-level tokenizer with the special tokens the framework needs.

ids 0..3 are specials, bytes live at 4..259.  [MASK] is NOT part of the
tokenizer: each model config reserves its own mask id (vocab_size - 1 by
default), matching how dLLM checkpoints ship a dedicated mask embedding.
"""

from __future__ import annotations

PAD_ID = 0
EOS_ID = 1
BOS_ID = 2
SEP_ID = 3
BYTE_OFFSET = 4
VOCAB_SIZE = 260  # minimum model vocab that can host the tokenizer


class ByteTokenizer:
    pad_id = PAD_ID
    eos_id = EOS_ID
    bos_id = BOS_ID
    sep_id = SEP_ID
    vocab_size = VOCAB_SIZE

    def encode(self, text: str, *, bos: bool = False,
               eos: bool = False) -> list[int]:
        ids = [BYTE_OFFSET + b for b in text.encode("utf-8")]
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids) -> str:
        out = bytearray()
        for i in ids:
            i = int(i)
            if i == EOS_ID:
                break
            if i >= BYTE_OFFSET and i < BYTE_OFFSET + 256:
                out.append(i - BYTE_OFFSET)
        return out.decode("utf-8", errors="replace")
