"""Batching pipeline: tokenised, block-aligned batches for SFT and RL.

Framework convention (shared by training and the serving engine): prompts
are right-padded with PAD *up to the next block boundary*, so every
sequence's generation starts at a block boundary and the attention/SSM
block algebra never straddles a ragged prompt edge.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Iterator

import numpy as np

from .math_tasks import MathProblem, sample_problem
from .tokenizer import ByteTokenizer


def pad_to_block(ids: list[int], block_size: int, pad_id: int) -> list[int]:
    r = len(ids) % block_size
    return ids + [pad_id] * (block_size - r) if r else ids


@dataclasses.dataclass
class SFTBatch:
    tokens: np.ndarray       # (B, L) int32
    prompt_mask: np.ndarray  # (B, L) bool
    valid: np.ndarray        # (B, L) bool

    def asdict(self):
        return {"tokens": self.tokens, "prompt_mask": self.prompt_mask,
                "valid": self.valid}


@dataclasses.dataclass
class PromptBatch:
    prompt_tokens: np.ndarray  # (B, Lp) int32, block aligned
    prompt_blocks: np.ndarray  # (B,) int32
    answers: np.ndarray        # (B,) int64
    texts: list[str]


class MathTaskDataset:
    """Deterministic synthetic stream of math problems."""

    def __init__(self, tokenizer: ByteTokenizer, block_size: int,
                 seq_len: int, seed: int = 0, level: int | None = None):
        self.tok = tokenizer
        self.block_size = block_size
        self.seq_len = seq_len
        self.rng = random.Random(seed)
        self.level = level

    def _encode_example(self, p: MathProblem
                        ) -> tuple[list[int], int] | None:
        prompt_ids = pad_to_block(
            self.tok.encode(p.prompt, bos=True), self.block_size,
            self.tok.pad_id)
        body = self.tok.encode(f" {p.reasoning} #### {p.answer}", eos=True)
        full = prompt_ids + body
        if len(full) > self.seq_len:
            return None
        return full, len(prompt_ids)

    def sft_batches(self, batch_size: int) -> Iterator[SFTBatch]:
        while True:
            toks = np.zeros((batch_size, self.seq_len), np.int32)
            pmask = np.zeros((batch_size, self.seq_len), bool)
            valid = np.zeros((batch_size, self.seq_len), bool)
            for b in range(batch_size):
                enc = None
                while enc is None:
                    enc = self._encode_example(
                        sample_problem(self.rng, self.level))
                full, plen = enc
                # valid region padded to block boundary (with PAD ids)
                vlen = len(pad_to_block(full, self.block_size,
                                        self.tok.pad_id))
                toks[b, :len(full)] = full
                pmask[b, :plen] = True
                valid[b, :vlen] = True
            yield SFTBatch(toks, pmask, valid)

    def prompt_batches(self, batch_size: int) -> Iterator[PromptBatch]:
        """RL prompt stream; all prompts padded to the batch max blocks."""
        while True:
            probs = [sample_problem(self.rng, self.level)
                     for _ in range(batch_size)]
            encs = [pad_to_block(self.tok.encode(p.prompt, bos=True),
                                 self.block_size, self.tok.pad_id)
                    for p in probs]
            lp = max(len(e) for e in encs)
            toks = np.zeros((batch_size, lp), np.int32)
            blocks = np.zeros((batch_size,), np.int32)
            for b, e in enumerate(encs):
                toks[b, :len(e)] = e
                blocks[b] = len(e) // self.block_size
            yield PromptBatch(toks, blocks,
                              np.array([p.answer for p in probs]),
                              [p.prompt for p in probs])
