"""Span tracer: bounded ring buffer of host-wall-clock spans.

The substrate of the request-lifecycle and scheduler-tick views that
``obs.export`` turns into Chrome trace-event JSON.  Two recording
styles:

``span(name, ...)``
    Context manager for code the caller brackets directly (a scheduler
    tick phase, an engine drain, a trainer phase).  It *always* measures
    — the yielded ``Span`` carries real ``t0``/``t1`` even when the
    tracer is disabled — and only *records* into the ring buffer when
    enabled.  Callers that need the duration for their own stats
    (``EngineStats.wall_seconds``, trainer phase timings) therefore read
    it off the span instead of keeping a parallel
    ``time.perf_counter()`` pair, and the measurement is defined
    identically whether or not tracing is on.

``begin(key) / end(key)``
    Open-span bookkeeping for lifecycles that start and finish in
    different calls — a request's *queued* span opens at ``submit()``
    and closes at admission; its *decode* span opens at admission and
    closes at harvest.  Keys are caller-chosen hashables
    (``("queued", uid)``); ``end`` merges final labels (finish reason,
    token counts) into the span's args and records it.

Timing contract: timestamps are ``time.perf_counter()`` taken **around
jit dispatch, never after a device sync** — a span covering
``advance_block`` measures Python-side dispatch plus whatever the
async runtime happened to overlap, not device latency.  That keeps the
tracer legal on per-tick hot paths (the dirlint ``hot-sync`` and
``obs-in-trace`` contracts); honest device timing is
``GenerationConfig.sync_each_tick`` or a real ``obs.profile`` capture.

The buffer is a ``deque(maxlen=capacity)``: a long-lived server evicts
the oldest spans instead of growing without bound, and ``dropped``
counts evictions so exporters can say the window is partial.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from contextlib import contextmanager

__all__ = ["Span", "Tracer"]


@dataclasses.dataclass
class Span:
    """One completed (or still-open: ``t1 < 0``) measured interval.

    ``track`` names the display lane the exporters map to a Chrome
    trace thread — ``"scheduler"``, ``"queue"``, ``"slot 3"``,
    ``"trainer"`` — so Perfetto shows one swim-lane per decode slot and
    one per subsystem.  ``args`` are the labels (slot id, prefix-hit
    blocks, kernel mode, finish reason...).
    """
    name: str
    cat: str                    # request | scheduler | engine | trainer
    track: str
    t0: float
    t1: float = -1.0
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        """Wall seconds (0 for instants and still-open spans)."""
        return max(self.t1 - self.t0, 0.0)


class Tracer:
    """Bounded span recorder; disabled instances still time spans.

    One tracer instance is shared down a stack (engine → scheduler →
    trainer phases) so a single export holds every track.  All methods
    are cheap host-side operations — a disabled tracer costs two
    ``perf_counter`` calls and one small object per ``span`` block, and
    nothing at all for ``begin``/``end``/``instant``.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.spans: deque[Span] = deque(maxlen=capacity)
        self.dropped = 0          # spans evicted by the ring buffer
        self._open: dict[object, Span] = {}
        self._clock = clock

    # ------------------------------------------------------------ record
    def _record(self, span: Span) -> None:
        if len(self.spans) == self.spans.maxlen:
            self.dropped += 1
        self.spans.append(span)

    @contextmanager
    def span(self, name: str, cat: str = "span", track: str | None = None,
             **args):
        """Measure the block; record it iff the tracer is enabled.

        Always yields a ``Span`` whose ``dur`` is valid after the block
        exits, so callers can feed stats from the same measurement that
        lands in the trace.
        """
        sp = Span(name, cat, track or cat, self._clock(), args=args)
        try:
            yield sp
        finally:
            sp.t1 = self._clock()
            if self.enabled:
                self._record(sp)

    def begin(self, key, name: str, cat: str = "span",
              track: str | None = None, **args) -> None:
        """Open a lifecycle span under ``key`` (no-op when disabled).
        Re-opening a live key silently replaces the orphan."""
        if not self.enabled:
            return
        self._open[key] = Span(name, cat, track or cat, self._clock(),
                               args=args)

    def end(self, key, **args) -> Span | None:
        """Close and record the open span under ``key``, merging
        ``args`` into its labels.  Unknown keys (tracer disabled at
        ``begin`` time, or evicted bookkeeping) are ignored."""
        sp = self._open.pop(key, None)
        if sp is None:
            return None
        sp.t1 = self._clock()
        sp.args.update(args)
        self._record(sp)
        return sp

    def amend(self, key, **args) -> None:
        """Merge labels into a still-open span (no-op if unknown)."""
        sp = self._open.get(key)
        if sp is not None:
            sp.args.update(args)

    def instant(self, name: str, cat: str = "event",
                track: str | None = None, **args) -> None:
        """Record a zero-duration marker (deferral, weight push)."""
        if not self.enabled:
            return
        t = self._clock()
        self._record(Span(name, cat, track or cat, t, t, args))

    # ----------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self.spans)

    @property
    def n_open(self) -> int:
        return len(self._open)

    def snapshot(self) -> list[Span]:
        """The recorded spans, oldest first (open spans excluded)."""
        return list(self.spans)

    def clear(self) -> None:
        self.spans.clear()
        self._open.clear()
        self.dropped = 0
