"""Typed metrics registry: Counter / Gauge / Histogram / Info.

Design constraints, in order:

* **Cheap host-side updates.**  Every instrument is a plain Python
  object whose hot operation (``inc`` / ``set`` / ``observe``) is one
  attribute read and one write — no locks, no string formatting, no
  timestamping.  The serving scheduler updates these once per *tick*
  (and mostly keeps mutating its stats dataclass directly, see below),
  so instrumentation cost is noise against a jit dispatch.

* **Legacy stats surfaces stay intact.**  ``SchedulerStats`` /
  ``EngineStats`` predate the registry and are mutated as plain
  dataclass attributes all over the serving stack (``stats.ticks += 1``)
  and reset wholesale (``sched.stats = SchedulerStats()``).  Rather
  than funnel every call site through instrument methods, an instrument
  can be *bound* to an object attribute (``bind=(obj, attr)``): the
  dataclass field becomes the instrument's storage, so the field and
  the registry are two views of one value — attribute writes show up in
  ``collect()``, instrument ``inc()`` shows up in the field, and no
  call site changes.  Unbound instruments (trainer phase timings, span
  histograms) own their storage.

* **Monotonic vs resettable is explicit.**  ``Counter`` only goes up
  (``inc`` rejects negative deltas) and survives ``registry.reset()``;
  ``Gauge`` / ``Histogram`` are resettable.  ``counter.reset()`` exists
  for the process-restart analogue (a fresh stats object) but must be
  asked for by name.

Label sets follow the Prometheus model: constructing an instrument with
``labelnames`` yields a *family*; ``family.labels(phase="rollout")``
returns (creating on first use) the child instrument for that label
value combination.  ``registry.collect()`` flattens everything into
``Sample`` records the exporters consume.

Naming convention: registries carry a ``namespace`` prefix
(``dirl_scheduler`` / ``dirl_engine`` / ``dirl_trainer``), instruments
use snake_case unit-suffixed names (``_seconds``, ``_bytes``,
``_total`` implied for counters) — the exported name is
``<namespace>_<name>``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Info", "MetricsRegistry",
           "Sample"]


@dataclasses.dataclass(frozen=True)
class Sample:
    """One exported measurement: a flattened (name, labels, value)."""
    name: str                 # full name incl. registry namespace
    kind: str                 # counter | gauge | histogram | info
    labels: tuple             # sorted (key, value) pairs
    value: object             # number, str (info), or dict (histogram)
    help: str = ""


class _Storage:
    """Value cell: either owned, or a view over ``(obj, attr)``."""

    __slots__ = ("_obj", "_attr", "_value")

    def __init__(self, bind=None, initial=0):
        if bind is None:
            self._obj = None
            self._value = initial
        else:
            self._obj, self._attr = bind

    def get(self):
        if self._obj is None:
            return self._value
        return getattr(self._obj, self._attr)

    def set(self, v):
        if self._obj is None:
            self._value = v
        else:
            setattr(self._obj, self._attr, v)


class _Instrument:
    """Base: name, help, storage, and the kind tag exporters switch on."""

    kind = ""
    resettable = True

    def __init__(self, name: str, help: str = "", *, bind=None,
                 labels: tuple = ()):
        self.name = name
        self.help = help
        self.label_pairs = labels          # sorted (key, value) tuple
        self._cell = _Storage(bind=bind, initial=self._initial())

    @staticmethod
    def _initial():
        return 0

    @property
    def value(self):
        return self._cell.get()

    def reset(self):
        self._cell.set(self._initial())

    def samples(self, prefix: str) -> Iterator[Sample]:
        yield Sample(prefix + self.name, self.kind, self.label_pairs,
                     self.value, self.help)


class Counter(_Instrument):
    """Monotonically increasing count.  ``inc`` rejects negative deltas;
    ``registry.reset()`` leaves counters alone (monotonic semantics —
    a counter restarts only with a fresh stats object or an explicit
    ``counter.reset()``)."""

    kind = "counter"
    resettable = False

    def inc(self, n=1):
        if n < 0:
            raise ValueError(
                f"counter {self.name!r} cannot decrease (inc({n}))")
        self._cell.set(self._cell.get() + n)


class Gauge(_Instrument):
    """A value that can go up and down (pool occupancy, peak trackers)."""

    kind = "gauge"

    def set(self, v):
        self._cell.set(v)

    def add(self, n):
        self._cell.set(self._cell.get() + n)

    def max(self, v):
        """Peak tracker: keep the running maximum."""
        cur = self._cell.get()
        if v > cur:
            self._cell.set(v)


class Info(_Instrument):
    """A small string annotation (kernel exec mode, cache layout)."""

    kind = "info"

    @staticmethod
    def _initial():
        return ""

    def set(self, v: str):
        self._cell.set(v)


class Histogram(_Instrument):
    """Distribution instrument with a *bounded* reservoir.

    Keeps a ``deque(maxlen=reservoir)`` of recent observations for
    percentile queries plus unbounded-safe cumulative ``count``/``sum``
    — memory stays O(reservoir) no matter how long the server runs.
    Percentiles are computed over the reservoir (the recent window),
    which is exactly the SLO-relevant view for a long-lived server.

    Quacks enough like the deque it replaced (``append`` / ``__iter__``
    / ``__len__`` / ``__bool__`` / ``maxlen``) that
    ``EngineStats.latencies`` call sites did not have to change:
    ``append`` is an alias of ``observe``.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *, reservoir: int = 4096,
                 labels: tuple = ()):
        super().__init__(name, help, labels=labels)
        self._window: deque = deque(maxlen=reservoir)
        self.count = 0
        self.sum = 0.0

    def observe(self, v):
        self._window.append(v)
        self.count += 1
        self.sum += v

    # deque-compatible view (EngineStats.latencies legacy surface)
    append = observe

    def __iter__(self):
        return iter(self._window)

    def __len__(self):
        return len(self._window)

    def __bool__(self):
        return bool(self._window)

    def __eq__(self, other):
        if isinstance(other, Histogram):
            return list(self._window) == list(other._window) \
                and self.count == other.count
        return NotImplemented

    @property
    def maxlen(self) -> int:
        return self._window.maxlen

    def percentile(self, q: float) -> float:
        """q-th percentile over the bounded recent window (0 if empty)."""
        if not self._window:
            return 0.0
        return float(np.percentile(np.asarray(self._window), q))

    def reset(self):
        self._window.clear()
        self.count = 0
        self.sum = 0.0

    def samples(self, prefix: str) -> Iterator[Sample]:
        yield Sample(prefix + self.name, self.kind, self.label_pairs,
                     {"count": self.count, "sum": self.sum,
                      "p50": self.percentile(50),
                      "p95": self.percentile(95),
                      "p99": self.percentile(99)}, self.help)


class _Family:
    """A labeled instrument family: ``labels(**kv)`` returns the child
    for that label-value combination, creating it on first use."""

    def __init__(self, cls, name, help, labelnames, kwargs):
        self._cls = cls
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._kwargs = kwargs
        self._children: dict[tuple, _Instrument] = {}

    def labels(self, **kv) -> _Instrument:
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(kv)}")
        key = tuple(sorted(kv.items()))
        child = self._children.get(key)
        if child is None:
            child = self._cls(self.name, self.help, labels=key,
                              **self._kwargs)
            self._children[key] = child
        return child

    def reset(self):
        for c in self._children.values():
            if c.resettable:
                c.reset()

    def samples(self, prefix: str) -> Iterator[Sample]:
        for key in sorted(self._children):
            yield from self._children[key].samples(prefix)


class MetricsRegistry:
    """One namespace of instruments; the unit exporters consume.

    Each stats surface owns its registry (``SchedulerStats.registry``,
    ``EngineStats.registry``, trainer ``metrics``) — resetting stats by
    constructing a fresh object therefore also resets the exported view,
    which is exactly the legacy warmup pattern's expectation.
    """

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self._instruments: dict[str, object] = {}

    # ------------------------------------------------------ constructors
    def _make(self, cls, name, help, labelnames, **kwargs):
        if name in self._instruments:
            existing = self._instruments[name]
            if isinstance(existing, (_Family, cls)):
                return existing
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(existing).__name__}")
        if labelnames:
            inst = _Family(cls, name, help, labelnames, kwargs)
        else:
            inst = cls(name, help, **kwargs)
        self._instruments[name] = inst
        return inst

    def counter(self, name, help="", labelnames=(), *, bind=None):
        return self._make(Counter, name, help, labelnames, bind=bind)

    def gauge(self, name, help="", labelnames=(), *, bind=None):
        return self._make(Gauge, name, help, labelnames, bind=bind)

    def info(self, name, help="", *, bind=None):
        return self._make(Info, name, help, (), bind=bind)

    def histogram(self, name, help="", labelnames=(), *,
                  reservoir: int = 4096):
        return self._make(Histogram, name, help, labelnames,
                          reservoir=reservoir)

    def adopt(self, name: str, instrument) -> None:
        """Register an externally constructed instrument (e.g. the
        ``Histogram`` living as a dataclass field)."""
        assert name not in self._instruments, name
        instrument.name = name
        self._instruments[name] = instrument

    # ------------------------------------------------------------ access
    def get(self, name: str):
        return self._instruments[name]

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def names(self) -> list[str]:
        return sorted(self._instruments)

    # ---------------------------------------------------------- lifecycle
    def reset(self) -> None:
        """Zero every *resettable* instrument (gauges, histograms,
        info).  Counters are monotonic and keep their value."""
        for inst in self._instruments.values():
            if isinstance(inst, _Family):
                inst.reset()
            elif inst.resettable:
                inst.reset()

    def collect(self) -> list[Sample]:
        """Flatten every instrument (label children included) into
        ``Sample`` records, full-named with the registry namespace."""
        prefix = f"{self.namespace}_" if self.namespace else ""
        out: list[Sample] = []
        for name in sorted(self._instruments):
            out.extend(self._instruments[name].samples(prefix))
        return out
