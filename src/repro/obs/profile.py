"""Profiler hooks: named XLA scopes and opt-in device trace capture.

The host-side tracer (``obs.trace``) deliberately never syncs the
device, so its spans measure dispatch, not device latency.  When device
time is the question, this module is the answer:

``annotate(name)``
    A ``jax.profiler.TraceAnnotation`` context — a named scope that
    shows up in XLA profiler timelines (TensorBoard / Perfetto) nested
    under the launching op.  The serving scheduler wraps
    ``advance_block`` and the suffix-prefill dispatches; trainers wrap
    their fused step.  When no profiler session is active these scopes
    cost a few hundred nanoseconds, so they stay on permanently.

``capture(logdir)``
    A real profiler session (``jax.profiler.start_trace`` /
    ``stop_trace``) bracketing a region; artifacts land under
    ``logdir`` and open in TensorBoard's profile plugin or Perfetto.
    Wired to ``launch.serve --profile-dir``.  ``logdir=None`` is a
    no-op, so call sites can pass the CLI flag straight through.

Both degrade to no-ops when ``jax.profiler`` is unavailable (the
``available()`` probe), keeping the obs package importable on stripped
builds.
"""

from __future__ import annotations

from contextlib import contextmanager, nullcontext

try:                                        # pragma: no cover - import guard
    from jax import profiler as _jprof
except Exception:                           # pragma: no cover
    _jprof = None

__all__ = ["annotate", "available", "capture"]


def available() -> bool:
    """True when ``jax.profiler`` annotation/trace APIs are present."""
    return _jprof is not None and hasattr(_jprof, "TraceAnnotation")


def annotate(name: str):
    """Named profiler scope (no-op context if jax.profiler is absent)."""
    if not available():
        return nullcontext()
    return _jprof.TraceAnnotation(name)


@contextmanager
def capture(logdir: str | None):
    """Run the body under an XLA profiler trace written to ``logdir``.

    ``None`` (flag unset) or a missing profiler degrade to a plain
    pass-through so callers need no conditional.
    """
    if logdir is None or not available():
        yield False
        return
    _jprof.start_trace(str(logdir))
    try:
        yield True
    finally:
        _jprof.stop_trace()
