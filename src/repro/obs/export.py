"""Exporters: Chrome trace-event JSON, Prometheus text, metrics JSON, JSONL.

The tracer and registries are in-memory substrates; this module turns
them into artifacts:

* ``write_chrome_trace(path, spans, ...)`` — the Trace Event Format
  consumed by Perfetto and ``chrome://tracing``.  Spans become ``"X"``
  (complete) events, instants become ``"i"``; each distinct span
  ``track`` becomes one display thread (named via ``"M"`` metadata
  events), so a serving trace renders as one swim-lane per decode slot
  plus one for the scheduler tick phases and one per trainer phase.
  Perfetto nests overlapping events on a track by time containment, so
  tick sub-spans (admit / advance / harvest) appear inside their tick.

* ``prometheus_text(...)`` — the text exposition format
  (``# HELP`` / ``# TYPE`` / ``name{labels} value``); histograms export
  their ``_count`` / ``_sum`` plus quantile gauges from the bounded
  window.

* ``write_metrics_json(path, ...)`` — a flat JSON envelope of
  ``Sample`` records, the machine-readable sibling used by serve_bench
  artifacts and CI schema checks.

* ``write_jsonl(path, spans)`` — raw span dump, one JSON object per
  line, for ad-hoc analysis without the Chrome schema.

Each write_* has a validate_* counterpart that re-reads the artifact
and checks structural invariants; CI's bench-smoke job runs those on
the uploaded artifacts so a format regression fails the build rather
than a later Perfetto session.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable

from .metrics import MetricsRegistry, Sample
from .trace import Span

__all__ = [
    "chrome_trace_events", "write_chrome_trace", "validate_chrome_trace",
    "prometheus_text", "write_prometheus",
    "metrics_payload", "write_metrics_json", "validate_metrics_json",
    "write_jsonl",
]

TRACE_SCHEMA_VERSION = 1


# --------------------------------------------------------------------------
# Chrome trace-event JSON
# --------------------------------------------------------------------------

def _json_safe(v):
    """Chrome trace args must be JSON — stringify anything exotic."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def chrome_trace_events(spans: Iterable[Span], *, pid: int = 1) -> list[dict]:
    """Lower spans to trace-event dicts (ts/dur in integer microseconds).

    Tracks are assigned tids in first-seen order; a ``thread_name``
    metadata event labels each so Perfetto shows the track name, and a
    ``process_name`` event labels the single process.
    """
    tids: dict[str, int] = {}
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": "dirl"},
    }]

    def tid_of(track: str) -> int:
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": track}})
        return tid

    for sp in spans:
        ts = round(sp.t0 * 1e6)
        ev = {
            "name": sp.name, "cat": sp.cat, "pid": pid,
            "tid": tid_of(sp.track), "ts": ts,
            "args": {k: _json_safe(v) for k, v in sp.args.items()},
        }
        if sp.t1 >= sp.t0 and sp.t1 > sp.t0:
            ev["ph"] = "X"
            ev["dur"] = max(round(sp.t1 * 1e6) - ts, 1)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"           # instant scoped to its thread/track
        events.append(ev)
    return events


def write_chrome_trace(path, spans: Iterable[Span], *,
                       metadata: dict | None = None) -> dict:
    """Write a Perfetto-loadable trace file; returns the payload."""
    payload = {
        "traceEvents": chrome_trace_events(spans),
        "displayTimeUnit": "ms",
        "otherData": {"schema_version": TRACE_SCHEMA_VERSION,
                      **(metadata or {})},
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def validate_chrome_trace(path) -> dict:
    """Re-read a trace artifact and check trace-event invariants.

    Raises ``ValueError`` on the first violation; returns the payload
    so callers can assert content (span names, labels) on top.
    """
    with open(path) as f:
        payload = json.load(f)
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError(f"{path}: not a trace-event JSON object")
    events = payload["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError(f"{path}: empty traceEvents")
    tids_named = set()
    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"{path}: event {i} missing {k!r}")
        ph = ev["ph"]
        if ph == "M":
            if ev["name"] == "thread_name":
                tids_named.add(ev["tid"])
            continue
        if ph not in ("X", "i"):
            raise ValueError(f"{path}: event {i} has unknown ph {ph!r}")
        if not isinstance(ev.get("ts"), int) or ev["ts"] < 0:
            raise ValueError(f"{path}: event {i} bad ts")
        if ph == "X" and (not isinstance(ev.get("dur"), int)
                          or ev["dur"] <= 0):
            raise ValueError(f"{path}: event {i} bad dur")
        if ev["tid"] not in tids_named:
            raise ValueError(
                f"{path}: event {i} on unnamed track tid={ev['tid']}")
    return payload


# --------------------------------------------------------------------------
# Prometheus text exposition
# --------------------------------------------------------------------------

def _label_str(pairs: tuple) -> str:
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


def prometheus_text(*registries: MetricsRegistry) -> str:
    """Render registries in the Prometheus text exposition format.

    Histogram samples expand into ``_count`` / ``_sum`` counters plus
    ``{quantile=...}`` gauges over the bounded window; ``info``
    instruments follow the ``_info{...} 1`` convention.
    """
    lines: list[str] = []
    seen_headers: set[str] = set()

    def header(name: str, kind: str, help: str):
        if name not in seen_headers:
            seen_headers.add(name)
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")

    for reg in registries:
        for s in reg.collect():
            if s.kind == "histogram":
                header(s.name, "summary", s.help)
                ls = _label_str(s.labels)
                lines.append(f"{s.name}_count{ls} {s.value['count']}")
                lines.append(f"{s.name}_sum{ls} {s.value['sum']}")
                for q, key in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
                    qls = _label_str(s.labels + (("quantile", q),))
                    lines.append(f"{s.name}{qls} {s.value[key]}")
            elif s.kind == "info":
                header(s.name + "_info", "gauge", s.help)
                ls = _label_str(s.labels + (("value", s.value),))
                lines.append(f"{s.name}_info{ls} 1")
            else:
                header(s.name, s.kind, s.help)
                lines.append(f"{s.name}{_label_str(s.labels)} {s.value}")
    return "\n".join(lines) + "\n"


def write_prometheus(path, *registries: MetricsRegistry) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(*registries))


# --------------------------------------------------------------------------
# Metrics JSON (machine-readable envelope for bench artifacts / CI)
# --------------------------------------------------------------------------

def metrics_payload(*registries: MetricsRegistry) -> dict:
    return {
        "schema_version": TRACE_SCHEMA_VERSION,
        "metrics": [dataclasses.asdict(s)
                    for reg in registries for s in reg.collect()],
    }


def write_metrics_json(path, *registries: MetricsRegistry) -> dict:
    payload = metrics_payload(*registries)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return payload


def validate_metrics_json(path) -> dict:
    """Schema check for the metrics envelope; raises ``ValueError``."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema_version") != TRACE_SCHEMA_VERSION:
        raise ValueError(f"{path}: bad schema_version")
    metrics = payload.get("metrics")
    if not isinstance(metrics, list):
        raise ValueError(f"{path}: metrics must be a list")
    kinds = set(Sample.__dataclass_fields__)  # field names, reused as check
    for i, m in enumerate(metrics):
        if not isinstance(m, dict) or not kinds.issuperset(m) \
                or "name" not in m or "kind" not in m:
            raise ValueError(f"{path}: metric {i} malformed: {m!r}")
        if m["kind"] not in ("counter", "gauge", "histogram", "info"):
            raise ValueError(f"{path}: metric {i} unknown kind {m['kind']!r}")
    return payload


# --------------------------------------------------------------------------
# Raw span dump
# --------------------------------------------------------------------------

def write_jsonl(path, spans: Iterable[Span]) -> int:
    """One JSON object per span per line; returns the line count."""
    n = 0
    with open(path, "w") as f:
        for sp in spans:
            rec = {"name": sp.name, "cat": sp.cat, "track": sp.track,
                   "t0": sp.t0, "t1": sp.t1, "dur": sp.dur,
                   "args": {k: _json_safe(v) for k, v in sp.args.items()}}
            f.write(json.dumps(rec) + "\n")
            n += 1
    return n
