"""obs — the unified observability layer for the serving + training stack.

Three cooperating pieces, all host-side and allocation-light so they can
sit on per-tick serving paths without touching the device:

1. **Metrics** (``obs.metrics``): a typed registry of Counter / Gauge /
   Histogram / Info instruments with optional label sets and explicit
   monotonic-vs-resettable semantics.  ``SchedulerStats`` and
   ``EngineStats`` are rebuilt on top of it — every numeric stats field
   is *bound storage* for a registry instrument, so the legacy attribute
   surface (``stats.ticks += 1``, ``sched.stats = SchedulerStats()``)
   keeps working unchanged while exporters read the same values through
   the registry.  Trainer timings register under the same ``dirl_*``
   namespace convention.

2. **Tracing** (``obs.trace``): a span tracer with a bounded ring
   buffer.  The scheduler records per-request lifecycle spans (submit →
   queued → admit → decode → harvest, labeled with prefix-hit counts,
   slot id, kernel mode, finish reason) and per-tick sub-spans (admit /
   advance / harvest).  Timestamps are host wall-clock taken *around*
   jit dispatch — spans never call ``block_until_ready``, so the
   ``hot-sync`` dirlint contract holds by construction and a span's
   duration is dispatch + host bookkeeping, not device time.  Honest
   device timing stays behind ``GenerationConfig.sync_each_tick`` or a
   real profiler capture (below).

3. **Profiler hooks** (``obs.profile``): thin wrappers over
   ``jax.profiler`` — ``annotate(name)`` puts named
   ``TraceAnnotation`` scopes around ``advance_block`` / suffix
   prefill / trainer steps (visible in XLA profiler traces), and
   ``capture(dir)`` brackets a region with a real
   ``start_trace``/``stop_trace`` profiler session
   (``launch.serve --profile-dir``).

Exporters (``obs.export``) turn both substrates into artifacts: Chrome
trace-event JSON (open in Perfetto / ``chrome://tracing`` — one track
per decode slot, one for the scheduler tick phases, one per trainer
phase), Prometheus-style text exposition, a flat metrics JSON envelope,
and JSONL span dumps.

The matching static contract is the dirlint rule ``obs-in-trace``: no
``obs`` call may be reachable from inside a jitted body —
instrumentation stays host-side, between dispatches, never traced.
"""

from __future__ import annotations

from .metrics import Counter, Gauge, Histogram, Info, MetricsRegistry
from .trace import Span, Tracer
from . import export, profile

__all__ = ["Counter", "Gauge", "Histogram", "Info", "MetricsRegistry",
           "Span", "Tracer", "export", "profile"]
