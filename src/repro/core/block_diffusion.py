"""Block-diffusion SFT objective (paper Eq. 3) on the fused dup layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .masks import dirl_layout, sample_sft_noise, tracer_layout


def token_cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """logits (..., V) f32, targets (...) int.  Returns CE (...)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def sft_loss(model, params, batch: dict, rng: jax.Array, *,
             layout: str = "dirl") -> tuple[jax.Array, dict]:
    """Conditional NELBO over blocks (Eq. 3), estimated with one sampled
    noise level per block and the fused duplicated-sequence forward.

    batch: {"tokens" (B,L), "prompt_mask" (B,L) bool, "valid" (B,L) bool}.
    ``layout`` selects the DiRL mask (Fig. 4b) or the TraceRL baseline
    (Fig. 4a) — both give identical losses; they differ in the attention
    work the kernel does (benchmarked in fig7).
    """
    cfg = model.cfg
    tokens = batch["tokens"]
    B, L = tokens.shape
    prompt_mask = batch["prompt_mask"]
    valid = batch["valid"]

    steps, weight, _ = sample_sft_noise(rng, tokens, prompt_mask, valid,
                                        block_size=cfg.block_size)
    mask_tok = cfg.resolved_mask_token
    if layout == "dirl":
        ids, meta, _ = dirl_layout(tokens, steps, valid,
                                   block_size=cfg.block_size,
                                   mask_token=mask_tok, noised=True)
        b_start = L
    else:  # TraceRL-style: only the output region duplicated
        prompt_len = int(batch["prompt_len_static"])
        noised = jnp.where(steps > 0, mask_tok, tokens)
        ids, meta, _ = tracer_layout(tokens, jnp.zeros_like(steps), valid,
                                     block_size=cfg.block_size,
                                     mask_token=mask_tok,
                                     prompt_len=prompt_len)
        ids = ids.at[:, L:].set(noised[:, prompt_len:])
        b_start = L

    logits_b, aux = model.forward_masked(
        params, ids, meta, dup_len=L if layout == "dirl" else None,
        memory=batch.get("memory"), memory_valid=batch.get("memory_valid"),
        logits_from=b_start)

    if layout == "dirl":
        tgt, w = tokens, weight
    else:
        prompt_len = int(batch["prompt_len_static"])
        tgt, w = tokens[:, prompt_len:], weight[:, prompt_len:]

    ce = token_cross_entropy(logits_b, tgt)
    denom = jnp.maximum(jnp.sum(valid & ~prompt_mask), 1)
    nelbo = jnp.sum(ce * w) / denom
    loss = nelbo + aux["aux_loss"]

    n_masked = jnp.maximum(jnp.sum(w > 0), 1)
    metrics = {
        "nelbo": nelbo,
        "moe_aux": aux["aux_loss"],
        "masked_ce": jnp.sum(ce * (w > 0)) / n_masked,
        "masked_frac": (w > 0).mean(),
    }
    return loss, metrics
