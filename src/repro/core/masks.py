"""Block-diffusion attention-mask algebra (the paper's §4.1 / Fig. 4).

The central object is a *duplicated sequence*::

    [ copy A : clean tokens (prompt + output), length L ]
    [ copy B : all-[MASK] "query row" over the same positions ]

with per-position metadata (copy, block, step, pos, valid).  ``step`` is the
denoise step at which the token at that position was revealed:

* SFT: a sampled binary map — 0 for tokens kept visible at the sampled
  noise level, 1 for tokens that were masked (the loss positions).
* RL: the *actual decode trajectory* recorded by the rollout engine
  (token j was revealed at step ``s_j`` of its block).

The visibility predicate reproduces, for every copy-B query at position j
(block k, step s_j), exactly the input the inference denoiser saw at step
``s_j``:

* copy-A keys: committed blocks ``blk < k`` — plus same-block tokens
  revealed strictly before (``step < s_j``);
* copy-B keys: same-block positions still masked at that step
  (``step >= s_j``), including j itself — their value stream is the [MASK]
  embedding with the correct positional encoding, exactly as at inference.

Copy-A queries use plain block-causal attention (full bidirectional inside
the block), matching the KV-cache semantics of committed blocks.

One forward pass over the 2L sequence therefore yields *unbiased* logits
for every output token at its own decode step — the property DiPO needs
(paper Eq. 6) and the SFT NELBO needs (paper Eq. 3).  The same predicate
family expresses the TraceRL baseline mask (Fig. 4a: only the output is
duplicated) via a different layout builder.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

MASK_TOKEN_STEP_SENTINEL = jnp.iinfo(jnp.int32).max // 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SeqMeta:
    """Per-position metadata of a packed (possibly duplicated) sequence.

    All fields are int32/bool arrays of shape (..., T) where T is the packed
    length.  ``copy``: 0 = clean copy A, 1 = mask-row copy B.  ``block``:
    diffusion-block index (``pos // block_size``).  ``step``: reveal step of
    the token at that position.  ``pos``: absolute position id (drives RoPE
    and sliding windows).  ``valid``: padding flag.
    """

    copy: jax.Array
    block: jax.Array
    step: jax.Array
    pos: jax.Array
    valid: jax.Array

    @property
    def length(self) -> int:
        return self.copy.shape[-1]

    def slice_t(self, start: int, size: int) -> "SeqMeta":
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, size, axis=-1)
        return SeqMeta(*(sl(getattr(self, f.name))
                         for f in dataclasses.fields(self)))


def visibility(q: SeqMeta, k: SeqMeta, *, window: int | None = None,
               strict: bool = False) -> jax.Array:
    """Dense visibility mask, shape (..., Tq, Tk) bool.

    This is the oracle form of the predicate; the Pallas kernel evaluates
    the same algebra per tile (see ``repro/kernels/block_diff_attn.py``).

    ``strict=False`` (mask-row semantics): copy-B queries see same-block
    copy-A keys revealed strictly before their step, and copy-B keys still
    masked at it.  One all-[MASK] row gives every token a conditional at
    its own reveal step, with revealed intra-block keys taken from the
    *clean* stream (a committed-KV approximation of the sequential
    engine — see trajectory.py for the exactness discussion).

    ``strict=True`` (per-copy semantics): copy-B queries see strictly
    previous copy-A blocks plus *exactly* their own copy (same block id
    AND same step id).  Used by the noised SFT layout (steps all 0) and
    the packed per-step RL layout, both of which carry the historical
    block inputs inside copy B itself — bit-exact vs. the inference
    engine.
    """
    qc, kc = q.copy[..., :, None], k.copy[..., None, :]
    qb, kb = q.block[..., :, None], k.block[..., None, :]
    qs, ks = q.step[..., :, None], k.step[..., None, :]
    qp, kp = q.pos[..., :, None], k.pos[..., None, :]

    k_is_a = kc == 0
    k_is_b = kc == 1

    # copy-A queries: block-causal over copy A (full inside own block).
    vis_a_query = k_is_a & (kb <= qb)

    # copy-B queries (the unbiased-logit rows).
    if strict:
        ctx = k_is_a & (kb < qb)
        own = k_is_b & (kb == qb) & (ks == qs)
    else:
        ctx = k_is_a & ((kb < qb) | ((kb == qb) & (ks < qs)))
        own = k_is_b & (kb == qb) & (ks >= qs)
    vis_b_query = ctx | own

    vis = jnp.where(qc[..., :, :] == 0, vis_a_query, vis_b_query)

    if window is not None:
        vis = vis & ((qp - kp) < window)

    vis = vis & q.valid[..., :, None] & k.valid[..., None, :]
    return vis


def block_causal_visibility(q: SeqMeta, k: SeqMeta, *,
                            window: int | None = None) -> jax.Array:
    """Plain committed-context mask (prefill / KV commit pass)."""
    vis = k.block[..., None, :] <= q.block[..., :, None]
    if window is not None:
        vis = vis & ((q.pos[..., :, None] - k.pos[..., None, :]) < window)
    return vis & q.valid[..., :, None] & k.valid[..., None, :]


# ---------------------------------------------------------------------------
# Layout builders
# ---------------------------------------------------------------------------


def _base_meta(L: int, block_size: int, valid: jax.Array,
               step: jax.Array, copy_id: int) -> SeqMeta:
    pos = jnp.arange(L, dtype=jnp.int32)
    blk = pos // block_size
    return SeqMeta(copy=jnp.full((L,), copy_id, jnp.int32),
                   block=blk, step=step.astype(jnp.int32),
                   pos=pos, valid=valid)


def _bcast(meta: SeqMeta, batch_shape) -> SeqMeta:
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, batch_shape + a.shape), meta)


def dirl_layout(tokens: jax.Array, steps: jax.Array, valid: jax.Array,
                *, block_size: int, mask_token: int, noised: bool = False
                ) -> tuple[jax.Array, SeqMeta, jax.Array]:
    """Paper Fig. 4b — prompt AND output duplicated blockwise.

    Two flavours:

    * ``noised=False`` (mask-row): copy B is all-[MASK]; per-position
      ``steps`` drive intra-block visibility, giving every token its
      exact own-decode-step conditional (DiPO / RL logits).  Attention
      backbones only.
    * ``noised=True``: copy B carries the *noised* tokens (real where
      ``steps == 0``, [MASK] where masked) and intra-block visibility is
      total (steps zeroed).  This is the literal Fig. 4b SFT layout and is
      exact for SSM/hybrid backbones too (revealed tokens enter through
      the recurrence input, not through attention).

    tokens/steps/valid: (B, L).  Returns (input_ids (B, 2L), meta (B, 2L),
    b_row_index (L,) mapping original position -> index of its copy-B slot).
    """
    B, L = tokens.shape
    ids_a = tokens
    if noised:
        ids_b = jnp.where(steps > 0, mask_token, tokens)
        meta_steps = jnp.zeros_like(steps)
    else:
        ids_b = jnp.full_like(tokens, mask_token)
        meta_steps = steps
    input_ids = jnp.concatenate([ids_a, ids_b], axis=-1)

    pos = jnp.arange(L, dtype=jnp.int32)
    blk = pos // block_size
    mk = lambda c: SeqMeta(
        copy=jnp.broadcast_to(jnp.full((L,), c, jnp.int32), (B, L)),
        block=jnp.broadcast_to(blk, (B, L)),
        step=meta_steps.astype(jnp.int32),
        pos=jnp.broadcast_to(pos, (B, L)),
        valid=valid)
    meta = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=-1),
                        mk(0), mk(1))
    return input_ids, meta, jnp.arange(L, dtype=jnp.int32) + L


def tracer_layout(tokens: jax.Array, steps: jax.Array, valid: jax.Array,
                  *, block_size: int, mask_token: int, prompt_len: int
                  ) -> tuple[jax.Array, SeqMeta, jax.Array]:
    """TraceRL baseline (Fig. 4a) — only the output region is duplicated.

    ``prompt_len`` must be a static int (the layout shape depends on it);
    ragged prompts are handled by rounding prompts up to block boundaries
    and padding, as the serving engine does.
    """
    B, L = tokens.shape
    Lo = L - prompt_len
    ids_b = jnp.full((B, Lo), mask_token, tokens.dtype)
    input_ids = jnp.concatenate([tokens, ids_b], axis=-1)

    pos = jnp.arange(L, dtype=jnp.int32)
    blk = pos // block_size
    meta_a = SeqMeta(
        copy=jnp.broadcast_to(jnp.zeros((L,), jnp.int32), (B, L)),
        block=jnp.broadcast_to(blk, (B, L)),
        step=steps.astype(jnp.int32),
        pos=jnp.broadcast_to(pos, (B, L)),
        valid=valid)
    meta_b = SeqMeta(
        copy=jnp.broadcast_to(jnp.ones((Lo,), jnp.int32), (B, Lo)),
        block=jnp.broadcast_to(blk[prompt_len:], (B, Lo)),
        step=steps[:, prompt_len:].astype(jnp.int32),
        pos=jnp.broadcast_to(pos[prompt_len:], (B, Lo)),
        valid=valid[:, prompt_len:])
    meta = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=-1),
                        meta_a, meta_b)
    b_index = jnp.arange(Lo, dtype=jnp.int32) + L
    return input_ids, meta, b_index


def plain_layout(tokens: jax.Array, valid: jax.Array, *, block_size: int
                 ) -> SeqMeta:
    """Committed-context layout (prefill / cache commit), copy A only."""
    B, L = tokens.shape
    pos = jnp.arange(L, dtype=jnp.int32)
    return SeqMeta(
        copy=jnp.zeros((B, L), jnp.int32),
        block=jnp.broadcast_to(pos // block_size, (B, L)),
        step=jnp.zeros((B, L), jnp.int32),
        pos=jnp.broadcast_to(pos, (B, L)),
        valid=valid)


def packed_layout(tokens: jax.Array, steps: jax.Array, valid: jax.Array,
                  *, block_size: int, mask_token: int, s_max: int
                  ) -> tuple[jax.Array, SeqMeta, jax.Array, jax.Array]:
    """Exact per-step RL layout: clean copy + one noised copy of every
    block *per denoise step*, packed into a single sequence.

    Layout: [A(0:L) ; copy(k=0,s=0) ; copy(0,1) ; ... ; copy(K-1,s_max-1)],
    total L * (1 + s_max).  Copy (k, s) carries the block's historical
    input at step s (tokens revealed strictly before s, [MASK] elsewhere);
    under the ``strict`` predicate it attends only blocks < k of copy A
    plus itself — exactly the inference denoiser input of that step.
    Equivalent to replay, in ONE attention-friendly forward.

    Returns (input_ids (B, L(1+s_max)), meta, sel (B, K, s_max, bsz) bool
    marking each token's own-step slot, blk_tok (B, K, s_max, bsz) target
    ids broadcast per step).
    """
    B, L = tokens.shape
    K = L // block_size
    blk_tok = tokens.reshape(B, K, 1, block_size)
    blk_tok = jnp.broadcast_to(blk_tok, (B, K, s_max, block_size))
    blk_steps = steps.reshape(B, K, 1, block_size)
    blk_steps = jnp.broadcast_to(blk_steps, (B, K, s_max, block_size))
    s_grid = jnp.arange(s_max, dtype=jnp.int32)[None, None, :, None]
    ids_copies = jnp.where(blk_steps >= s_grid, mask_token, blk_tok)
    sel = blk_steps == s_grid

    input_ids = jnp.concatenate(
        [tokens, ids_copies.reshape(B, K * s_max * block_size)], axis=-1)

    pos = jnp.arange(L, dtype=jnp.int32)
    blkid = pos // block_size
    meta_a = SeqMeta(copy=jnp.zeros((B, L), jnp.int32),
                     block=jnp.broadcast_to(blkid, (B, L)),
                     step=steps.astype(jnp.int32),
                     pos=jnp.broadcast_to(pos, (B, L)),
                     valid=valid)
    cop_block = jnp.broadcast_to(
        jnp.arange(K, dtype=jnp.int32)[:, None, None],
        (K, s_max, block_size)).reshape(-1)
    cop_step = jnp.broadcast_to(
        jnp.arange(s_max, dtype=jnp.int32)[None, :, None],
        (K, s_max, block_size)).reshape(-1)
    cop_pos = jnp.broadcast_to(
        pos.reshape(K, 1, block_size), (K, s_max, block_size)).reshape(-1)
    blk_valid = valid.reshape(B, K, 1, block_size)
    cop_valid = jnp.broadcast_to(blk_valid,
                                 (B, K, s_max, block_size)).reshape(B, -1)
    Tc = K * s_max * block_size
    meta_b = SeqMeta(copy=jnp.ones((B, Tc), jnp.int32),
                     block=jnp.broadcast_to(cop_block, (B, Tc)),
                     step=jnp.broadcast_to(cop_step, (B, Tc)),
                     pos=jnp.broadcast_to(cop_pos, (B, Tc)),
                     valid=cop_valid)
    meta = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=-1),
                        meta_a, meta_b)
    return input_ids, meta, sel, blk_tok


# ---------------------------------------------------------------------------
# SFT noising (forward process, paper §2.1)
# ---------------------------------------------------------------------------


def sample_sft_noise(key: jax.Array, tokens: jax.Array, prompt_mask: jax.Array,
                     valid: jax.Array, *, block_size: int
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sample the masked-diffusion forward process blockwise.

    Per block, draw t ~ U(0,1]; each *output* token in the block is masked
    independently with probability t (linear schedule alpha_t = 1 - t).
    Returns (steps (B,L) int32 in {0,1}, loss_weight (B,L) f32 = 1/t on
    masked output tokens else 0, t_per_block (B,K)).

    Guarantees >= masking of at least one token per block is NOT enforced;
    the NELBO estimator stays unbiased either way.
    """
    B, L = tokens.shape
    K = L // block_size
    kt, km = jax.random.split(key)
    t_blk = jax.random.uniform(kt, (B, K), minval=1e-3, maxval=1.0)
    t_tok = jnp.repeat(t_blk, block_size, axis=-1)
    u = jax.random.uniform(km, (B, L))
    maskable = valid & ~prompt_mask
    masked = (u < t_tok) & maskable
    steps = masked.astype(jnp.int32)
    weight = jnp.where(masked, 1.0 / t_tok, 0.0)
    return steps, weight, t_blk
