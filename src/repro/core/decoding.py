"""Blockwise semi-autoregressive decoding (static & dynamic-threshold).

The generation loop is built from one reusable, jit-compatible primitive:
``advance_block`` advances every sequence of a ``GenState`` by exactly one
block — denoise (``denoise_block``), freeze finished rows, commit the
block into the caches, and move the per-sequence cursors.  The one-shot
``generate`` wraps it in a ``fori_loop``; the continuous-batching
``serving.scheduler.SlotScheduler`` calls the same primitive once per
scheduler tick with admissions in between.  Because every row of the
state advances independently (per-row caches, per-row rng streams), the
two drivers produce token-identical outputs and step maps for the same
per-sequence rng keys — the property the RL trainer relies on for
DiPO-exact rollouts.

Every revealed token's step index is recorded — that step map is exactly
what DiPO's unbiased logit computation consumes (trajectory.py).

Dynamic decoding (paper §4.4/§5.1): at each denoise step, reveal every
still-masked position whose top-1 probability exceeds tau (at least one —
the best-confidence position — is always revealed).  Static decoding:
reveal a fixed number of highest-confidence positions per step.

Per-row sampling parameters: every decode knob a request may set —
``tau``, ``temperature``, static-mode ``n_steps``, the dynamic/static
mode itself, and the stop token — lives in **per-sequence vectors on
``GenState``** and is read per row inside the jitted step (the two
reveal policies are computed side by side and selected with a per-row
``jnp.where``).  Nothing about a request's parameters is a jit static,
so one compiled ``advance_block`` serves arbitrarily mixed
configurations; the single remaining static is ``s_max``, the global
denoise-loop bound (it fixes compiled loop structure, not data — rows
whose policy finishes earlier just stop revealing).  A row decoded in a
mixed batch is bit-identical to the same row in a homogeneous batch:
every per-row branch selects between values computed from that row's
own parameters only.

RNG discipline: the state carries one rng key **per sequence** (shape
(B, 2)); each denoise step splits every row's key independently, so a
sequence's sample stream depends only on its own key — never on batch
composition.  ``generate`` accepts either a single key (split across the
batch) or a precomputed (B, 2) key array.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .masks import plain_layout


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GenState:
    tokens: jax.Array      # (B, L_max)
    steps: jax.Array       # (B, L_max) reveal-step map
    caches: dict
    blk: jax.Array         # (B,) next block index per sequence
    done: jax.Array        # (B,)
    rng: jax.Array         # (B, 2) per-sequence rng keys
    limit: jax.Array       # (B,) exclusive block cursor cap per sequence
    n_denoise: jax.Array   # (B,) cumulative denoise steps actually used
    # per-row sampling parameters (traced data, never jit statics — one
    # compiled advance serves mixed configurations without retracing)
    tau: jax.Array         # (B,) f32 dynamic-mode reveal threshold
    temperature: jax.Array  # (B,) f32; 0 = greedy argmax
    n_steps: jax.Array     # (B,) i32 static-mode denoise-step budget
    dynamic: jax.Array     # (B,) bool: dynamic vs static reveal policy
    eos: jax.Array         # (B,) i32 stop token (-1 disables EOS stop)
    # paged caches only: (B, L_max // block_size) block -> page id, -1 =
    # no page (None when the caches are dense per-sequence regions)
    table: jax.Array | None = None


def _per_seq_keys(rng, batch: int) -> jax.Array:
    """Accept a single key or a (B, 2) batch of keys."""
    rng = jnp.asarray(rng)
    if rng.ndim == 2:
        return rng
    return jax.random.split(rng, batch)


def sampling_vectors(batch: int, *, tau=0.9, temperature=0.0, n_steps=8,
                     mode="dynamic", eos_id=1) -> dict:
    """Broadcast scalar-or-per-row sampling fields to (B,) vectors.

    ``mode`` is either a string applied to every row or a (B,) bool
    array (True = dynamic); the numeric fields accept scalars or (B,)
    arrays.  Returns the ``GenState`` sampling-field dict.
    """
    if isinstance(mode, str):
        if mode not in ("dynamic", "static"):
            raise ValueError(f"mode must be dynamic|static, got {mode!r}")
        dynamic = jnp.full((batch,), mode == "dynamic")
    else:
        dynamic = jnp.broadcast_to(jnp.asarray(mode, bool), (batch,))
    return {
        "tau": jnp.broadcast_to(
            jnp.asarray(tau, jnp.float32), (batch,)),
        "temperature": jnp.broadcast_to(
            jnp.asarray(temperature, jnp.float32), (batch,)),
        "n_steps": jnp.broadcast_to(
            jnp.asarray(n_steps, jnp.int32), (batch,)),
        "dynamic": dynamic,
        "eos": jnp.broadcast_to(
            jnp.asarray(eos_id, jnp.int32), (batch,)),
    }


def _select_boundary(caches, bounds, prompt_blocks):
    """Per-sequence SSM state at each sequence's own prompt boundary."""
    B = prompt_blocks.shape[0]
    rows = jnp.arange(B)

    def merge_layer(cache, bd, grouped):
        if bd is None or cache is None:
            return cache
        new = dict(cache)
        for skey, arr in bd.items():
            if grouped:  # (G, K, B, ...)
                new[skey] = arr[:, prompt_blocks, rows]
            else:        # (K, B, ...)
                new[skey] = arr[prompt_blocks, rows]
        return new

    out = {"prefix": {}, "groups": {}}
    for lk, cache in caches["prefix"].items():
        out["prefix"][lk] = merge_layer(cache, bounds["prefix"].get(lk),
                                        grouped=False)
    for lk, cache in caches["groups"].items():
        out["groups"][lk] = merge_layer(cache, bounds["groups"].get(lk),
                                        grouped=True)
    return out


def prefill(model, params, prompt_tokens, prompt_blocks, max_len: int, *,
            ring: bool = True, memory=None, memory_valid=None):
    """Run the committed pass over (block-aligned, right-padded) prompts.

    prompt_tokens (B, Lp) with Lp a block multiple; prompt_blocks (B,) the
    per-sequence true prompt length in blocks.  Returns caches sized for
    ``max_len`` with every prompt position written (positions beyond a
    sequence's true prompt are masked at decode time via cache_limit and
    overwritten on commit).  ``ring=False`` keeps sliding-window layers'
    buffers full-length (needed when the rows are re-scattered into a
    paged pool block-by-block).
    """
    cfg = model.cfg
    B, Lp = prompt_tokens.shape
    valid = jnp.ones((B, Lp), bool)
    meta = plain_layout(prompt_tokens, valid, block_size=cfg.block_size)
    caches = model.make_caches(B, max_len, ring=ring)
    want_b = bool(cfg.ssm_kind)
    _, out = model.forward_masked(params, prompt_tokens, meta,
                                  caches=caches, want_boundaries=want_b,
                                  memory=memory, memory_valid=memory_valid)
    caches = out["caches"]
    if want_b:
        caches = _select_boundary(caches, out["boundaries"], prompt_blocks)
    return caches


def prefill_suffix(model, params, suffix_tokens, start_block: jax.Array,
                   caches, context_table, write_pages,
                   kv_kernel: str = "ref"):
    """Suffix-only prefill: commit prompt blocks [start_block, ...) while
    reading the shared-prefix KV through ``context_table`` pages.

    The shared-prefix admission path (``serving.prefix_cache``): when the
    first ``start_block`` blocks of a prompt are already cached, only the
    suffix needs a committed pass.  ``suffix_tokens`` (B, Ls) with Ls a
    block multiple; ``context_table`` (B, Kp) page ids of the cached
    prefix (Kp == start_block, no -1 padding); ``write_pages``
    (B, Ls // block_size) freshly allocated pages that receive the
    suffix KV.  Returns the updated (paged) caches.

    Bitwise contract: the combined key array (prefix pages ++ suffix
    self-KV) has exactly the full prompt's key layout, and the attention
    over it is row- and length-invariant, so the committed suffix KV is
    *byte-identical* to the same blocks of a full ``prefill`` — the
    property the scheduler's prefix-cache on/off token-parity guarantee
    rests on.  This holds on both prefill KV layouts (``kv_kernel="ref"``
    gathers the prefix pages into a dense-width copy; ``"pallas"``
    streams them in place via ``paged_prefill_attention``, which replays
    the reference chunk walk over a compact scratch copy of the same key
    layout) and when the cache dtype equals the activation dtype (fp32
    default); lower-precision caches would round the prefix context
    where the full pass attends pre-rounding.
    """
    cfg = model.cfg
    B, Ls = suffix_tokens.shape
    assert Ls % cfg.block_size == 0 and Ls > 0
    pos = jnp.asarray(start_block, jnp.int32) * cfg.block_size \
        + jnp.arange(Ls, dtype=jnp.int32)
    meta = plain_layout(suffix_tokens, jnp.ones((B, Ls), bool),
                        block_size=cfg.block_size)
    pos = jnp.broadcast_to(pos, (B, Ls))
    meta = dataclasses.replace(meta, pos=pos,
                               block=pos // cfg.block_size)
    return model.prefill_suffix(params, suffix_tokens, meta, caches,
                                context_table=context_table,
                                write_pages=write_pages,
                                kv_kernel=kv_kernel)


def denoise_block(model, params, caches, blk, rng, *, tau, temperature,
                  n_steps, dynamic, s_max: int, table=None,
                  kv_kernel: str = "ref",
                  memory=None, memory_valid=None):
    """Denoise one block for every sequence.

    ``rng`` is a (B, 2) batch of per-sequence keys; every row's stream is
    split independently so sampling is invariant to batch composition.
    ``tau`` / ``temperature`` / ``n_steps`` / ``dynamic`` are (B,)
    per-row vectors (see ``sampling_vectors``): both reveal policies are
    evaluated and a per-row ``jnp.where`` selects, so rows with
    different parameters share one compiled step.  Only ``s_max`` — the
    loop bound — is static.

    Returns (ids, step_map, pos, rng, steps_used) where ``steps_used``
    (B,) is the number of denoise steps that actually revealed tokens for
    each sequence (``step_map.max() + 1``) — in dynamic-threshold mode
    this is typically well below ``s_max`` and is what a production
    early-exit loop would execute; the engine's throughput stats consume
    it instead of assuming the worst-case budget.
    """
    cfg = model.cfg
    bsz = cfg.block_size
    MASK = cfg.resolved_mask_token
    B = blk.shape[0]
    pos = blk[:, None] * bsz + jnp.arange(bsz, dtype=jnp.int32)[None, :]
    cache_limit = blk * bsz
    # static mode reveals ceil(bsz / n_steps) positions per step
    ns = jnp.maximum(n_steps, 1)
    n_per_step = jnp.maximum(1, (bsz + ns - 1) // ns)        # (B,)
    sample = temperature > 0
    # rows with temperature 0 take the argmax branch; the divisor only
    # has to be finite for them, the sampled candidate is discarded
    safe_temp = jnp.where(sample, temperature, 1.0)

    def body(s, carry):
        ids, step_map, rng = carry
        logits, _ = model.decode_step(params, ids, pos, caches,
                                      cache_limit=cache_limit,
                                      block_table=table,
                                      kv_kernel=kv_kernel,
                                      memory=memory,
                                      memory_valid=memory_valid)
        lf = logits.astype(jnp.float32)
        # the [MASK] token is an input symbol, never an output
        lf = lf.at[..., MASK].set(-jnp.inf)
        ks = jax.vmap(jax.random.split)(rng)     # (B, 2, 2)
        rng, kr = ks[:, 0], ks[:, 1]
        # Gumbel-max categorical with the noise zeroed on greedy rows:
        # bit-identical to jax.random.categorical(kr, lf/temp) where
        # temperature > 0 (categorical IS argmax(logits + gumbel)) and
        # to argmax(lf) where not (safe_temp = 1, noise = 0), for the
        # cost of ONE vocab argmax instead of a per-policy pair
        noise = jax.vmap(
            lambda k: jax.random.gumbel(k, lf.shape[1:], lf.dtype))(kr)
        cand = jnp.argmax(
            lf / safe_temp[:, None, None]
            + jnp.where(sample[:, None, None], noise, 0.0), axis=-1)
        probs = jax.nn.softmax(lf, axis=-1)
        conf = jnp.take_along_axis(probs, cand[..., None], axis=-1)[..., 0]

        masked = ids == MASK
        score = jnp.where(masked, conf, -1.0)
        # dynamic: threshold reveal, and always at least the
        # best-confidence masked position
        rev_dyn = masked & (conf >= tau[:, None])
        best = jnp.argmax(score, axis=-1)
        force = jax.nn.one_hot(best, bsz, dtype=bool) & masked
        rev_dyn = rev_dyn | (force & ~rev_dyn.any(-1, keepdims=True))
        # static: the row's n_per_step highest-confidence positions
        thr = jnp.take_along_axis(jnp.sort(score, axis=-1),
                                  (bsz - n_per_step)[:, None], axis=-1)
        rev_st = masked & (score >= thr)
        reveal = jnp.where(dynamic[:, None], rev_dyn, rev_st)
        # last step: flush everything still masked
        reveal = jnp.where(s >= s_max - 1, masked, reveal)

        ids = jnp.where(reveal, cand.astype(ids.dtype), ids)
        step_map = jnp.where(reveal, s, step_map)
        return ids, step_map, rng

    ids0 = jnp.full((B, bsz), MASK, jnp.int32)
    steps0 = jnp.zeros((B, bsz), jnp.int32)
    ids, step_map, rng = jax.lax.fori_loop(0, s_max, body,
                                           (ids0, steps0, rng))
    steps_used = step_map.max(axis=-1) + 1
    return ids, step_map, pos, rng, steps_used


def advance_block(model, params, st: GenState, *, s_max: int,
                  kv_kernel: str = "ref",
                  memory=None, memory_valid=None) -> GenState:
    """Advance every sequence of ``st`` by exactly one block (jittable).

    The single-block step shared by the one-shot ``generate`` loop and
    the continuous-batching scheduler: denoise the block at each row's
    cursor, freeze rows already ``done`` (they re-commit their existing
    block — idempotent, so inactive scheduler slots are harmless),
    commit the block into the caches, scatter tokens/step-map, then
    update cursors / done flags / actual-denoise-step counters.  A row
    is done when its block hits its own stop token (``st.eos``) or its
    cursor reaches ``st.limit``.

    All sampling parameters come from the state's per-row vectors —
    ``s_max`` is the one static, so a single compiled instance serves
    every mix of request configurations a pool can hold.  ``kv_kernel``
    selects the decode KV layout (``"ref"`` = concat/gather fallback,
    ``"pallas"`` = in-place page-aware kernel on paged caches); it is a
    pool-level static like ``s_max``, never per-request data, so the
    zero-retrace mixed-``SamplingParams`` invariant is untouched.
    """
    bsz = model.cfg.block_size
    B, L = st.tokens.shape
    n_blocks_total = L // bsz
    rows = jnp.arange(B)[:, None]

    blk = jnp.minimum(st.blk, n_blocks_total - 1)
    ids, step_map, pos, rng, steps_used = denoise_block(
        model, params, st.caches, blk, st.rng, tau=st.tau,
        temperature=st.temperature, n_steps=st.n_steps,
        dynamic=st.dynamic, s_max=s_max,
        table=st.table, kv_kernel=kv_kernel,
        memory=memory, memory_valid=memory_valid)
    # frozen sequences re-commit their existing block (idempotent)
    old_ids = jnp.take_along_axis(st.tokens, pos, axis=1)
    old_steps = jnp.take_along_axis(st.steps, pos, axis=1)
    ids = jnp.where(st.done[:, None], old_ids, ids)
    step_map = jnp.where(st.done[:, None], old_steps, step_map)

    _, caches = model.decode_step(params, ids, pos, st.caches,
                                  cache_limit=blk * bsz,
                                  block_table=st.table, write=True,
                                  kv_kernel=kv_kernel,
                                  memory=memory,
                                  memory_valid=memory_valid)
    tokens = st.tokens.at[rows, pos].set(ids)
    steps = st.steps.at[rows, pos].set(step_map)
    hit_eos = (ids == st.eos[:, None]).any(axis=-1)
    done = st.done | hit_eos
    new_blk = jnp.where(st.done, st.blk,
                        jnp.minimum(st.blk + 1, st.limit))
    done = done | (new_blk >= st.limit)
    n_denoise = st.n_denoise + jnp.where(st.done, 0, steps_used)
    return GenState(tokens=tokens, steps=steps, caches=caches,
                    blk=new_blk, done=done, rng=rng, limit=st.limit,
                    n_denoise=n_denoise, tau=st.tau,
                    temperature=st.temperature, n_steps=st.n_steps,
                    dynamic=st.dynamic, eos=st.eos, table=st.table)


def init_state(model, params, prompt_tokens, prompt_blocks, rng, *,
               max_len: int, limit=None, mode="dynamic", tau=0.9,
               n_steps=8, temperature=0.0, eos_id=1,
               memory=None, memory_valid=None) -> GenState:
    """Prefill prompts and build the GenState ``advance_block`` consumes.

    ``limit``: per-sequence exclusive block cap (defaults to the full
    cache capacity ``max_len // block_size``).  The sampling fields
    accept scalars (applied to every row) or (B,) per-row arrays — see
    ``sampling_vectors``.
    """
    cfg = model.cfg
    bsz = cfg.block_size
    B, Lp = prompt_tokens.shape
    n_blocks_total = max_len // bsz
    MASK = cfg.resolved_mask_token
    caches = prefill(model, params, prompt_tokens, prompt_blocks, max_len,
                     memory=memory, memory_valid=memory_valid)
    tokens = jnp.concatenate(
        [prompt_tokens,
         jnp.full((B, max_len - Lp), MASK, prompt_tokens.dtype)], axis=1)
    if limit is None:
        limit = jnp.full((B,), n_blocks_total, jnp.int32)
    limit = jnp.asarray(limit, jnp.int32)
    blk = prompt_blocks.astype(jnp.int32)
    # rows with no block budget (prompt fills the cache / limit <=
    # prompt) start frozen: advance_block would otherwise denoise-commit
    # over their last prompt block
    return GenState(tokens=tokens.astype(jnp.int32),
                    steps=jnp.zeros((B, max_len), jnp.int32),
                    caches=caches, blk=blk,
                    done=blk >= limit,
                    rng=_per_seq_keys(rng, B),
                    limit=limit,
                    n_denoise=jnp.zeros((B,), jnp.int32),
                    **sampling_vectors(B, tau=tau,
                                       temperature=temperature,
                                       n_steps=n_steps, mode=mode,
                                       eos_id=eos_id))


def generate(model, params, prompt_tokens, prompt_blocks, rng, *,
             max_len: int, s_max: int, mode="dynamic",
             tau=0.9, n_steps=8, temperature=0.0, eos_id=1,
             limit=None, memory=None, memory_valid=None) -> dict:
    """Full blockwise generation (jit-compatible; all shapes static).

    Returns {"tokens" (B, L_max), "steps" (B, L_max), "gen_blocks" (B,),
    "prompt_blocks" (B,), "done" (B,), "denoise_steps" (B,)} — everything
    RolloutBatch and the engine stats need.

    Sampling parameters accept scalars or (B,) per-row arrays (``mode``:
    a string or a (B,) bool array, True = dynamic), so a mixed-config
    batch runs in one jitted call — the per-row contract the serving
    stack's ``SamplingParams`` rides on.  ``limit`` optionally caps each
    row's exclusive block cursor (None = cache capacity).

    The loop runs until every row is done (EOS or its own block budget),
    NOT for a trip count derived from the padded prompt width: in a
    ragged batch a row whose true prompt is shorter than the padding has
    more blocks of budget than ``(max_len - Lp) // bsz``, and cutting it
    off there silently truncated it without EOS (diverging from the
    continuous-batching scheduler, which runs each slot to its limit).
    """
    n_blocks_total = max_len // model.cfg.block_size

    st = init_state(model, params, prompt_tokens, prompt_blocks, rng,
                    max_len=max_len, limit=limit, mode=mode, tau=tau,
                    n_steps=n_steps, temperature=temperature,
                    eos_id=eos_id, memory=memory,
                    memory_valid=memory_valid)
    step = functools.partial(advance_block, model, params, s_max=s_max,
                             memory=memory, memory_valid=memory_valid)
    # every live row advances its cursor each trip, so n_blocks_total
    # trips is a hard ceiling; the counter is belt-and-braces
    _, st = jax.lax.while_loop(
        lambda c: (c[0] < n_blocks_total) & ~c[1].done.all(),
        lambda c: (c[0] + 1, step(st=c[1])),
        (jnp.int32(0), st))
    return {
        "tokens": st.tokens,
        "steps": st.steps,
        "gen_blocks": st.blk - prompt_blocks,
        "prompt_blocks": prompt_blocks,
        # zero-budget rows never decoded: report them not-done, matching
        # the scheduler's empty completions
        "done": st.done & (st.blk > prompt_blocks),
        "denoise_steps": st.n_denoise,
    }


def count_gen_tokens(tokens, prompt_blocks, gen_blocks, *, eos_id,
                     block_size: int) -> np.ndarray:
    """Per-sequence generated-token count, cut at the first EOS.

    Counts tokens in the generated region up to and *including* the
    first EOS (the whole region when no EOS landed) — the honest
    tokens/sec numerator: when EOS lands mid-block the rest of that
    block is padding the consumer trims, not served output.  ``eos_id``
    is a scalar or a (B,) per-row array (mixed ``SamplingParams``
    batches stop on per-request tokens; -1 disables).
    """
    tokens = np.asarray(tokens)
    pb = np.asarray(prompt_blocks).astype(np.int64)
    gb = np.asarray(gen_blocks).astype(np.int64)
    eos_id = np.broadcast_to(np.asarray(eos_id), (tokens.shape[0],))
    out = np.zeros((tokens.shape[0],), np.int64)
    for i in range(tokens.shape[0]):
        lo, hi = pb[i] * block_size, (pb[i] + gb[i]) * block_size
        region = tokens[i, lo:hi]
        eos = np.flatnonzero(region == eos_id[i])
        out[i] = eos[0] + 1 if eos.size else hi - lo
    return out


def rollout_to_batch(gen: dict, rewards, group, block_size: int):
    """Package a ``generate`` output dict into a RolloutBatch."""
    from .trajectory import RolloutBatch
    B, L = gen["tokens"].shape
    pos_blk = jnp.arange(L, dtype=jnp.int32)[None, :] // block_size
    prompt_mask = pos_blk < gen["prompt_blocks"][:, None]
    valid = pos_blk < (gen["prompt_blocks"] + gen["gen_blocks"])[:, None]
    if not isinstance(gen["gen_blocks"], jax.core.Tracer):
        gb = np.asarray(gen["gen_blocks"])
        assert (gb >= 0).all(), "negative gen_blocks in rollout"
        # an empty rollout must be explicitly all-prompt: a step map
        # claiming reveals on a gen_blocks == 0 row would relabel prompt
        # tokens as revealed-at-step-0 generation in the DiPO replay
        empty = gb == 0
        if empty.any() and not isinstance(gen["steps"], jax.core.Tracer):
            assert (np.asarray(gen["steps"])[empty] == 0).all(), \
                "gen_blocks == 0 row carries a nonzero reveal-step map"
    return RolloutBatch(tokens=gen["tokens"], steps=gen["steps"],
                        prompt_mask=prompt_mask, valid=valid,
                        rewards=rewards, group=group)
