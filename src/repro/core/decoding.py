"""Blockwise semi-autoregressive decoding (static & dynamic-threshold).

The full generation loop is one jitted function: an outer fori over blocks
(each sequence tracks its own block cursor, so ragged prompts decode in
lock-step), an inner fori over denoise steps.  Every revealed token's step
index is recorded — that step map is exactly what DiPO's unbiased logit
computation consumes (trajectory.py).

Dynamic decoding (paper §4.4/§5.1): at each denoise step, reveal every
still-masked position whose top-1 probability exceeds tau (at least one —
the best-confidence position — is always revealed).  Static decoding:
reveal a fixed number of highest-confidence positions per step.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .masks import plain_layout


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GenState:
    tokens: jax.Array      # (B, L_max)
    steps: jax.Array       # (B, L_max)
    caches: dict
    blk: jax.Array         # (B,) next block index per sequence
    done: jax.Array        # (B,)
    rng: jax.Array


def _select_boundary(caches, bounds, prompt_blocks):
    """Per-sequence SSM state at each sequence's own prompt boundary."""
    B = prompt_blocks.shape[0]
    rows = jnp.arange(B)

    def merge_layer(cache, bd, grouped):
        if bd is None or cache is None:
            return cache
        new = dict(cache)
        for skey, arr in bd.items():
            if grouped:  # (G, K, B, ...)
                new[skey] = arr[:, prompt_blocks, rows]
            else:        # (K, B, ...)
                new[skey] = arr[prompt_blocks, rows]
        return new

    out = {"prefix": {}, "groups": {}}
    for lk, cache in caches["prefix"].items():
        out["prefix"][lk] = merge_layer(cache, bounds["prefix"].get(lk),
                                        grouped=False)
    for lk, cache in caches["groups"].items():
        out["groups"][lk] = merge_layer(cache, bounds["groups"].get(lk),
                                        grouped=True)
    return out


def prefill(model, params, prompt_tokens, prompt_blocks, max_len: int, *,
            memory=None, memory_valid=None):
    """Run the committed pass over (block-aligned, right-padded) prompts.

    prompt_tokens (B, Lp) with Lp a block multiple; prompt_blocks (B,) the
    per-sequence true prompt length in blocks.  Returns caches sized for
    ``max_len`` with every prompt position written (positions beyond a
    sequence's true prompt are masked at decode time via cache_limit and
    overwritten on commit).
    """
    cfg = model.cfg
    B, Lp = prompt_tokens.shape
    valid = jnp.ones((B, Lp), bool)
    meta = plain_layout(prompt_tokens, valid, block_size=cfg.block_size)
    caches = model.make_caches(B, max_len)
    want_b = bool(cfg.ssm_kind)
    _, out = model.forward_masked(params, prompt_tokens, meta,
                                  caches=caches, want_boundaries=want_b,
                                  memory=memory, memory_valid=memory_valid)
    caches = out["caches"]
    if want_b:
        caches = _select_boundary(caches, out["boundaries"], prompt_blocks)
    return caches


def denoise_block(model, params, caches, blk, rng, *,
                  mode: str, tau: float, n_steps: int,
                  temperature: float, s_max: int,
                  memory=None, memory_valid=None):
    """Denoise one block for every sequence.  Returns (ids, step_map, rng)."""
    cfg = model.cfg
    bsz = cfg.block_size
    MASK = cfg.resolved_mask_token
    B = blk.shape[0]
    pos = blk[:, None] * bsz + jnp.arange(bsz, dtype=jnp.int32)[None, :]
    cache_limit = blk * bsz
    n_per_step = max(1, -(-bsz // max(n_steps, 1)))

    def body(s, carry):
        ids, step_map, rng = carry
        logits, _ = model.decode_step(params, ids, pos, caches,
                                      cache_limit=cache_limit,
                                      memory=memory,
                                      memory_valid=memory_valid)
        lf = logits.astype(jnp.float32)
        # the [MASK] token is an input symbol, never an output
        lf = lf.at[..., MASK].set(-jnp.inf)
        rng, kr = jax.random.split(rng)
        if temperature > 0:
            cand = jax.random.categorical(kr, lf / temperature, axis=-1)
        else:
            cand = jnp.argmax(lf, axis=-1)
        probs = jax.nn.softmax(lf, axis=-1)
        conf = jnp.take_along_axis(probs, cand[..., None], axis=-1)[..., 0]

        masked = ids == MASK
        score = jnp.where(masked, conf, -1.0)
        if mode == "dynamic":
            reveal = masked & (conf >= tau)
            # always reveal at least the best-confidence masked position
            best = jnp.argmax(score, axis=-1)
            force = jax.nn.one_hot(best, bsz, dtype=bool) & masked
            reveal = reveal | (force & ~reveal.any(-1, keepdims=True))
        else:
            thr = jnp.sort(score, axis=-1)[:, -n_per_step][:, None]
            reveal = masked & (score >= thr)
        # last step: flush everything still masked
        reveal = jnp.where(s >= s_max - 1, masked, reveal)

        ids = jnp.where(reveal, cand.astype(ids.dtype), ids)
        step_map = jnp.where(reveal, s, step_map)
        return ids, step_map, rng

    ids0 = jnp.full((B, bsz), MASK, jnp.int32)
    steps0 = jnp.zeros((B, bsz), jnp.int32)
    ids, step_map, rng = jax.lax.fori_loop(0, s_max, body,
                                           (ids0, steps0, rng))
    return ids, step_map, pos, rng


def generate(model, params, prompt_tokens, prompt_blocks, rng, *,
             max_len: int, s_max: int, mode: str = "dynamic",
             tau: float = 0.9, n_steps: int = 8,
             temperature: float = 0.0, eos_id: int = 1,
             memory=None, memory_valid=None) -> dict:
    """Full blockwise generation (jit-compatible; all shapes static).

    Returns {"tokens" (B, L_max), "steps" (B, L_max), "gen_blocks" (B,),
    "prompt_blocks" (B,), "done" (B,)} — everything RolloutBatch needs.
    """
    cfg = model.cfg
    bsz = cfg.block_size
    B, Lp = prompt_tokens.shape
    n_blocks_total = max_len // bsz
    max_new_blocks = n_blocks_total - Lp // bsz
    MASK = cfg.resolved_mask_token

    caches = prefill(model, params, prompt_tokens, prompt_blocks, max_len,
                     memory=memory, memory_valid=memory_valid)
    tokens = jnp.concatenate(
        [prompt_tokens,
         jnp.full((B, max_len - Lp), MASK, prompt_tokens.dtype)], axis=1)
    st = GenState(tokens=tokens.astype(jnp.int32),
                  steps=jnp.zeros((B, max_len), jnp.int32),
                  caches=caches, blk=prompt_blocks.astype(jnp.int32),
                  done=jnp.zeros((B,), bool), rng=rng)
    rows = jnp.arange(B)[:, None]

    def outer(_, st: GenState):
        blk = jnp.minimum(st.blk, n_blocks_total - 1)
        ids, step_map, pos, rng = denoise_block(
            model, params, st.caches, blk, st.rng, mode=mode, tau=tau,
            n_steps=n_steps, temperature=temperature, s_max=s_max,
            memory=memory, memory_valid=memory_valid)
        # frozen sequences re-commit their existing block (idempotent)
        old_ids = jnp.take_along_axis(st.tokens, pos, axis=1)
        old_steps = jnp.take_along_axis(st.steps, pos, axis=1)
        ids = jnp.where(st.done[:, None], old_ids, ids)
        step_map = jnp.where(st.done[:, None], old_steps, step_map)

        _, caches = model.decode_step(params, ids, pos, st.caches,
                                      cache_limit=blk * bsz, write=True,
                                      memory=memory,
                                      memory_valid=memory_valid)
        tokens = st.tokens.at[rows, pos].set(ids)
        steps = st.steps.at[rows, pos].set(step_map)
        hit_eos = (ids == eos_id).any(axis=-1)
        done = st.done | hit_eos
        new_blk = jnp.where(st.done, st.blk,
                            jnp.minimum(st.blk + 1, n_blocks_total))
        done = done | (new_blk >= n_blocks_total)
        return GenState(tokens=tokens, steps=steps, caches=caches,
                        blk=new_blk, done=done, rng=rng)

    st = jax.lax.fori_loop(0, max_new_blocks, outer, st)
    return {
        "tokens": st.tokens,
        "steps": st.steps,
        "gen_blocks": st.blk - prompt_blocks,
        "prompt_blocks": prompt_blocks,
        "done": st.done,
    }


def rollout_to_batch(gen: dict, rewards, group, block_size: int):
    """Package a ``generate`` output dict into a RolloutBatch."""
    from .trajectory import RolloutBatch
    B, L = gen["tokens"].shape
    pos_blk = jnp.arange(L, dtype=jnp.int32)[None, :] // block_size
    prompt_mask = pos_blk < gen["prompt_blocks"][:, None]
    valid = pos_blk < (gen["prompt_blocks"] + gen["gen_blocks"])[:, None]
    return RolloutBatch(tokens=gen["tokens"], steps=gen["steps"],
                        prompt_mask=prompt_mask, valid=valid,
                        rewards=rewards, group=group)
