"""Unbiased trajectory log-probabilities (the heart of DiPO, paper §3.2).

The rollout engine records, for every generated token, the denoise step at
which it was revealed.  DiPO needs  log pi(o_k | tau(1:t-1))  — the token's
probability under *exactly* the inputs the denoiser saw at its own reveal
step.  Two equivalent computations:

* ``fused``  — ONE forward over the duplicated mask-row layout.  Copy B is
  all-[MASK]; the step-comparison mask reconstructs, for every query, the
  precise mix of revealed (copy-A) and still-masked (copy-B) same-block
  keys of its reveal step.  O(2L) tokens total.  Exact for attention
  mixers (information flows only through attention).

* ``replay`` — literal re-execution: prefill the clean sequence (caches +
  SSM block-boundary states), then for every (block, step) run one
  decode_step with the historical block inputs.  O(L * S_max) tokens.
  Required for SSM/hybrid backbones (revealed tokens enter through the
  recurrence input stream, which one fused pass cannot represent for
  more than one step per block) — and doubles as the oracle the fused
  path is tested against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .masks import SeqMeta, dirl_layout, plain_layout


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RolloutBatch:
    """Flattened group rollouts (P prompts x G samples = B rows).

    tokens   (B, L)  full sequences (prompt ++ generation, padded)
    steps    (B, L)  int32 reveal step of each token within its block
    prompt_mask (B, L) bool  True on prompt (and pad-to-block) positions
    valid    (B, L)  bool    False beyond each sequence's end
    rewards  (B,)    f32
    group    (B,)    int32   prompt index (for group-relative advantages)
    """

    tokens: jax.Array
    steps: jax.Array
    prompt_mask: jax.Array
    valid: jax.Array
    rewards: jax.Array
    group: jax.Array

    @property
    def loss_mask(self) -> jax.Array:
        return self.valid & ~self.prompt_mask


def gather_logp(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# fused path (attention backbones)
# ---------------------------------------------------------------------------


def trajectory_logprobs_fused(model, params, roll: RolloutBatch, *,
                              memory=None, memory_valid=None) -> jax.Array:
    """(B, L) log-prob of every token at its own reveal step, one forward."""
    cfg = model.cfg
    L = roll.tokens.shape[1]
    ids, meta, _ = dirl_layout(
        roll.tokens, roll.steps, roll.valid, block_size=cfg.block_size,
        mask_token=cfg.resolved_mask_token, noised=False)
    logits_b, _ = model.forward_masked(params, ids, meta, dup_len=L,
                                       memory=memory,
                                       memory_valid=memory_valid,
                                       logits_from=L)
    return gather_logp(logits_b, roll.tokens)


# ---------------------------------------------------------------------------
# packed path: exact AND one forward (attention backbones)
# ---------------------------------------------------------------------------


def trajectory_logprobs_packed(model, params, roll: RolloutBatch, *,
                               s_max: int, memory=None,
                               memory_valid=None) -> jax.Array:
    """(B, L) exact per-step log-probs in ONE forward.

    Packs the clean sequence plus s_max noised copies of every block into a
    single layout under the strict predicate (masks.packed_layout).  Each
    copy reproduces the literal inference input of its step, so this is
    bit-equivalent to replay for attention backbones — at one kernel
    launch instead of K*s_max sequential decode calls.  This goes beyond
    the paper's Fig. 4b (which is exact for the SFT single-noise-level
    case); see DESIGN.md §7.
    """
    from .masks import packed_layout
    cfg = model.cfg
    B, L = roll.tokens.shape
    bsz = cfg.block_size
    K = L // bsz
    ids, meta, sel, blk_tok = packed_layout(
        roll.tokens, roll.steps, roll.valid, block_size=bsz,
        mask_token=cfg.resolved_mask_token, s_max=s_max)
    logits_b, _ = model.forward_masked(params, ids, meta, strict=True,
                                       memory=memory,
                                       memory_valid=memory_valid,
                                       logits_from=L)
    lg_copies = logits_b.reshape(B, K, s_max, bsz, -1)
    lp = gather_logp(lg_copies, blk_tok)              # (B, K, s_max, bsz)
    lp = jnp.where(sel, lp, 0.0).sum(axis=2)          # own-step slot only
    return lp.reshape(B, L)


# ---------------------------------------------------------------------------
# replay path (SSM / hybrid backbones; also the oracle)
# ---------------------------------------------------------------------------


def _merge_boundary_states(caches, bounds, k):
    """Replace SSM state entries in ``caches`` with the block-k boundary
    states collected during prefill.  groups bounds have leading (G, K),
    prefix bounds leading (K,)."""
    def merge_layer(cache, bd, grouped):
        if bd is None or cache is None:
            return cache
        new = dict(cache)
        for skey, arr in bd.items():
            new[skey] = arr[:, k] if grouped else arr[k]
        return new

    out = {"prefix": {}, "groups": {}}
    for lk, cache in caches["prefix"].items():
        out["prefix"][lk] = merge_layer(cache, bounds["prefix"].get(lk),
                                        grouped=False)
    for lk, cache in caches["groups"].items():
        out["groups"][lk] = merge_layer(cache, bounds["groups"].get(lk),
                                        grouped=True)
    return out


def trajectory_logprobs_replay(model, params, roll: RolloutBatch, *,
                               s_max: int, memory=None, memory_valid=None
                               ) -> jax.Array:
    """(B, L) log-probs via literal per-step decode replay.

    ``s_max`` = max denoise steps per block used by the rollout (static).
    """
    cfg = model.cfg
    B, L = roll.tokens.shape
    bsz = cfg.block_size
    K = L // bsz
    MASK = cfg.resolved_mask_token

    meta_p = plain_layout(roll.tokens, roll.valid, block_size=bsz)
    # ring=False: replay revisits every block, so sliding-window layers
    # need the full-length buffer (the serving ring would have evicted
    # early blocks' keys)
    caches = model.make_caches(B, L, ring=False)
    _, out = model.forward_masked(params, roll.tokens, meta_p,
                                  caches=caches, want_boundaries=True,
                                  memory=memory, memory_valid=memory_valid)
    caches_full, bounds = out["caches"], out["boundaries"]

    tok_blk = roll.tokens.reshape(B, K, bsz)
    step_blk = roll.steps.reshape(B, K, bsz)
    base_pos = jnp.arange(bsz, dtype=jnp.int32)

    def one(ks):
        k, s = ks // s_max, ks % s_max
        tk = tok_blk[:, k]                       # (B, bsz)
        sk = step_blk[:, k]
        blk_ids = jnp.where(sk >= s, MASK, tk)   # revealed strictly before s
        pos = jnp.broadcast_to(k * bsz + base_pos, (B, bsz))
        cc = _merge_boundary_states(caches_full, bounds, k)
        lg, _ = model.decode_step(params, blk_ids, pos, cc,
                                  cache_limit=k * bsz, memory=memory,
                                  memory_valid=memory_valid)
        lp = gather_logp(lg, tk)
        return jnp.where(sk == s, lp, 0.0)       # (B, bsz)

    parts = jax.lax.map(one, jnp.arange(K * s_max, dtype=jnp.int32))
    logp = parts.reshape(K, s_max, B, bsz).sum(axis=1)   # one s per token
    return logp.transpose(1, 0, 2).reshape(B, L)


def trajectory_logprobs(model, params, roll: RolloutBatch, *,
                        s_max: int, scheme: str = "auto", **kw) -> jax.Array:
    """Dispatch.

    scheme: "packed" (exact, one forward — attention backbones),
    "replay" (exact, sequential — any backbone), "fused_approx" (one
    2L forward, committed-KV approximation), or "auto" (packed for
    attention, replay for SSM/hybrid).
    """
    if scheme == "auto":
        scheme = "replay" if model.cfg.ssm_kind else "packed"
    if scheme == "packed":
        return trajectory_logprobs_packed(model, params, roll,
                                          s_max=s_max, **kw)
    if scheme == "replay":
        return trajectory_logprobs_replay(model, params, roll,
                                          s_max=s_max, **kw)
    if scheme == "fused_approx":
        return trajectory_logprobs_fused(model, params, roll, **kw)
    raise ValueError(scheme)
