"""DiPO — unbiased GRPO for blockwise dLLMs (paper §3.2, Eq. 6-8).

Built on trajectory-exact log-probs (trajectory.py).  Supports:

* Eq. 6 — sequence-normalised clipped surrogate with explicit old policy;
* Eq. 7 — online variant: pi_old = stop_gradient(pi_theta) (fresh rollouts
  every step, the DiRL server loop);
* Eq. 8 — DAPO token-level aggregation (global 1/sum|tau| normaliser);
* reverse-KL penalty to a *fixed reference* policy (k3 estimator).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .trajectory import RolloutBatch


def group_advantages(rewards: jax.Array, group: jax.Array,
                     n_groups: int, *, normalize_std: bool = False
                     ) -> jax.Array:
    """A_i = r_i - mean(group)  (optionally /std, GRPO-style).

    rewards (B,), group (B,) int in [0, n_groups)."""
    ones = jnp.ones_like(rewards)
    gsum = jnp.zeros((n_groups,), rewards.dtype).at[group].add(rewards)
    gcnt = jnp.zeros((n_groups,), rewards.dtype).at[group].add(ones)
    gmean = gsum / jnp.maximum(gcnt, 1.0)
    adv = rewards - gmean[group]
    if normalize_std:
        gsq = jnp.zeros((n_groups,), rewards.dtype).at[group].add(
            jnp.square(rewards))
        gvar = gsq / jnp.maximum(gcnt, 1.0) - jnp.square(gmean)
        adv = adv / jnp.sqrt(jnp.maximum(gvar[group], 1e-6))
    return adv


def _clip_surrogate(ratio, adv, eps):
    return jnp.minimum(ratio * adv, jnp.clip(ratio, 1 - eps, 1 + eps) * adv)


def dipo_loss(logp: jax.Array, roll: RolloutBatch, *,
              old_logp: jax.Array | None = None,
              ref_logp: jax.Array | None = None,
              n_groups: int,
              eps: float = 0.2, beta: float = 0.0,
              aggregate: str = "token",
              normalize_std: bool = False) -> tuple[jax.Array, dict]:
    """Policy loss from trajectory-exact log-probs.

    logp (B, L): current-policy per-token log-probs at their reveal steps.
    old_logp: behaviour policy; None -> online Eq. 7 (stop-gradient).
      The async pipeline (`rl.pipeline`) supplies this from its replay
      queue — behaviour log-probs sealed onto rollout groups that
      crossed a weight-update boundary — making the ratio the exact
      pi_theta/pi_theta_old off-policy correction for stale rollouts.
    ref_logp: fixed reference for the KL penalty (None -> no penalty).
    aggregate: "token" (Eq. 8 / DAPO) or "seq" (Eq. 6).
    Returns (scalar loss to *minimise*, metrics).
    """
    mask = roll.loss_mask.astype(jnp.float32)             # (B, L)
    adv = group_advantages(roll.rewards, roll.group, n_groups,
                           normalize_std=normalize_std)   # (B,)

    if old_logp is None:
        old_logp = jax.lax.stop_gradient(logp)
    ratio = jnp.exp(logp - old_logp)
    surr = _clip_surrogate(ratio, adv[:, None], eps) * mask

    if aggregate == "token":
        denom = jnp.maximum(mask.sum(), 1.0)
        obj = surr.sum() / denom
    elif aggregate == "seq":
        per_seq = surr.sum(axis=1) / jnp.maximum(mask.sum(axis=1), 1.0)
        obj = per_seq.mean()
    else:
        raise ValueError(aggregate)

    kl = jnp.zeros((), jnp.float32)
    if ref_logp is not None and beta:
        # k3 estimator of KL(pi || ref) on sampled tokens
        lr = ref_logp - logp
        k3 = (jnp.exp(lr) - lr - 1.0) * mask
        kl = k3.sum() / jnp.maximum(mask.sum(), 1.0)

    loss = -(obj - beta * kl)

    clipped = ((ratio > 1 + eps) | (ratio < 1 - eps)).astype(jnp.float32)
    metrics = {
        "policy_obj": obj,
        "kl_ref": kl,
        "adv_mean": adv.mean(),
        "adv_std": adv.std(),
        "ratio_mean": (ratio * mask).sum() / jnp.maximum(mask.sum(), 1.0),
        "clip_frac": (clipped * mask).sum() / jnp.maximum(mask.sum(), 1.0),
        "reward_mean": roll.rewards.mean(),
    }
    return loss, metrics
