"""Dispatcher for block-diffusion attention.

Three implementations of the same contract:

* ``ref``        — dense-mask oracle (O((2L)^2) scores).  This is what a
                   framework *without* the paper's FlexAttention trick pays
                   (the TraceRL-era baseline).
* ``structured`` — pure-jnp decomposition exploiting the mask algebra:
                   copy-A queries run block-causal over copy A; copy-B
                   queries run (i) a strictly-previous-context pass over
                   copy A and (ii) a block-diagonal pass over copy B, the
                   two merged with flash-style (m, l) statistics.  Cuts the
                   score work from 4L^2 to ~2L^2 + L*Bsz and is fully
                   XLA-analysable — this is the path the multi-pod dry-run
                   lowers.
* ``pallas`` / ``pallas_interpret`` — the TPU kernel family
                   (``block_diff_attn.py``), tile-skipping via
                   ``build_tile_map`` (~L^2-ish visited area, the
                   FlexAttention-equivalent fast path).  Fully
                   differentiable: a ``custom_vjp`` pairs the forward
                   with dQ/dKV flash backward kernels that reuse the
                   same tile map, so SFT/DiPO training skips the same
                   empty tiles three times per step.  ``impl="pallas"``
                   auto-selects interpret mode off-TPU (CI runs the
                   real kernel bodies on CPU); ``pallas_interpret``
                   forces it.

All take (q, k, v) in (B, L, H/Hkv, D) layout plus ``SeqMeta``.
Tile sizes are clamped to divisors of the sequence lengths, so the
pallas path works at any block-aligned length without caller padding.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.masks import SeqMeta, visibility
from . import ref as _ref
from .block_diff_attn import (INVALID_COPY, block_diff_attention,
                              default_interpret)

NEG_INF = _ref.NEG_INF


@dataclasses.dataclass(frozen=True)
class TrainExecPlan:
    """How a training attention impl will execute — startup print fodder
    (the training analogue of ``paged_attn.KernelPlan``)."""

    impl: str
    mode: str      # "compiled" | "interpret" | "xla"
    reason: str


def train_exec_plan(impl: str) -> TrainExecPlan:
    """Resolve ``impl`` to its execution mode on the current backend."""
    if impl in ("pallas", "pallas_interpret"):
        if impl == "pallas_interpret" or default_interpret():
            return TrainExecPlan(impl, "interpret",
                                 "pallas kernels on non-TPU backend "
                                 "(interpret mode)")
        return TrainExecPlan(impl, "compiled", "pallas kernels on TPU")
    return TrainExecPlan(impl, "xla", f"pure-jnp {impl} path (XLA)")


# ---------------------------------------------------------------------------
# meta packing & tile maps
# ---------------------------------------------------------------------------


def pack_meta(meta: SeqMeta) -> jax.Array:
    """SeqMeta -> (B, L, 4) int32; invalid positions get copy=INVALID_COPY."""
    copy = jnp.where(meta.valid, meta.copy, INVALID_COPY)
    return jnp.stack(
        [copy, meta.block, meta.step, meta.pos], axis=-1).astype(jnp.int32)


def build_tile_map(q_meta: jax.Array, k_meta: jax.Array, tq: int, tk: int,
                   *, window: int | None = None) -> jax.Array:
    """Conservative block-sparse map, (B, Lq//tq, Lk//tk) int32.

    0 = provably empty (kernel skips), 1 = partial, 2 = provably full.
    Decided from per-tile channel min/max only — never materialises the
    dense mask.  This is the TPU analogue of FlexAttention's BlockMask.
    """
    B, Lq, _ = q_meta.shape
    Lk = k_meta.shape[1]
    qm = q_meta.reshape(B, Lq // tq, tq, 4)
    km = k_meta.reshape(B, Lk // tk, tk, 4)
    qmin, qmax = qm.min(axis=2), qm.max(axis=2)      # (B, nq, 4)
    kmin, kmax = km.min(axis=2), km.max(axis=2)      # (B, nk, 4)

    def ch(a, i):
        return a[..., i]

    # broadcast (B, nq, 1) vs (B, 1, nk)
    def q_(a, i):
        return ch(a, i)[:, :, None]

    def k_(a, i):
        return ch(a, i)[:, None, :]

    COPY, BLOCK, STEP, POS = 0, 1, 2, 3
    any_a_q = q_(qmin, COPY) <= 0
    any_b_q = (q_(qmin, COPY) <= 1) & (q_(qmax, COPY) >= 1)
    any_a_k = k_(kmin, COPY) <= 0
    any_b_k = (k_(kmin, COPY) <= 1) & (k_(kmax, COPY) >= 1)

    c1 = any_a_q & any_a_k & (k_(kmin, BLOCK) <= q_(qmax, BLOCK))
    c2 = any_b_q & any_a_k & (k_(kmin, BLOCK) <= q_(qmax, BLOCK))
    c3 = (any_b_q & any_b_k
          & (k_(kmin, BLOCK) <= q_(qmax, BLOCK))
          & (k_(kmax, BLOCK) >= q_(qmin, BLOCK))
          & (k_(kmax, STEP) >= q_(qmin, STEP)))
    needed = c1 | c2 | c3
    if window is not None:
        needed = needed & ((q_(qmin, POS) - k_(kmax, POS)) < window)

    all_a_q = q_(qmax, COPY) == 0
    all_b_q = (q_(qmin, COPY) == 1) & (q_(qmax, COPY) == 1)
    all_a_k = k_(kmax, COPY) == 0
    full_aa = all_a_q & all_a_k & (k_(kmax, BLOCK) <= q_(qmin, BLOCK))
    full_ba = all_b_q & all_a_k & (k_(kmax, BLOCK) < q_(qmin, BLOCK))
    full = full_aa | full_ba
    if window is not None:
        full = full & ((q_(qmax, POS) - k_(kmin, POS)) < window)

    return (needed.astype(jnp.int32) + (needed & full).astype(jnp.int32))


def tile_map_stats(tile_map: jax.Array) -> dict:
    """Fraction of visited / partial / full tiles — feeds the roofline
    notes and the trainer/scheduler ``obs`` gauges."""
    total = tile_map.size
    visited = int((tile_map > 0).sum())
    full = int((tile_map == 2).sum())
    denom = max(total, 1)
    return {"tiles_total": total, "tiles_visited": visited,
            "tiles_full": full, "visit_fraction": visited / denom,
            "partial_fraction": (visited - full) / denom,
            "full_fraction": full / denom}


def layout_tile_stats(meta: SeqMeta, *, tq: int = 128, tk: int = 128,
                      window: int | None = None) -> dict:
    """Host-side tile stats for a layout's self-attention (the sparsity
    the pallas kernels exploit), with the same tile-size clamping as the
    ``attention`` dispatcher."""
    pm = pack_meta(meta)
    L = pm.shape[1]
    tq = _pick_chunk(L, tq)
    tk = _pick_chunk(L, tk)
    return tile_map_stats(build_tile_map(pm, pm, tq, tk, window=window))


# ---------------------------------------------------------------------------
# structured jnp path (flash-style two-part merge, no Pallas)
# ---------------------------------------------------------------------------


def _part_scores(q, k, mask, *, scale, softcap):
    """Unnormalised flash stats for one key segment.

    q: (B, Lq, H, D), k: (B, Lk, Hkv, D), mask: (B, Lq, Lk).
    Returns (p (B,H,Lq,Lk) exp-shifted, m (B,H,Lq,1), l (B,H,Lq,1)).
    """
    B, Lq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qf = q.reshape(B, Lq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                   preferred_element_type=jnp.float32) * scale
    s = s.reshape(B, H, Lq, -1)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[:, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # avoid -inf rows
    p = jnp.exp(s - m) * mask[:, None]
    l = jnp.sum(p, axis=-1, keepdims=True)
    return p, m, l


def _part_out(p, v):
    B, H, Lq, Lk = p.shape
    Hkv = v.shape[2]
    g = H // Hkv
    pv = p.reshape(B, Hkv, g, Lq, Lk).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", pv, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, Lq, -1)


def _merge(parts):
    """Merge [(o_unnorm, m, l), ...] flash statistics."""
    m = parts[0][1]
    for _, mi, _ in parts[1:]:
        m = jnp.maximum(m, mi)
    o = 0.0
    l = 0.0
    for oi, mi, li in parts:
        a = jnp.exp(mi - m)
        o = o + oi * a
        l = l + li * a
    l = jnp.where(l == 0.0, 1.0, l)
    return o / l


# ---------------------------------------------------------------------------
# chunked (memory-bounded flash-in-jnp) path
# ---------------------------------------------------------------------------


def _pick_chunk(length: int, target: int) -> int:
    """Largest divisor of ``length`` that is <= target."""
    c = min(target, length)
    while length % c:
        c -= 1
    return c


def chunked_masked_attention(q, k, v, q_meta: SeqMeta, k_meta: SeqMeta, *,
                             scale=None, softcap=None, window=None,
                             strict: bool = False,
                             q_chunk: int = 512, k_chunk: int = 1024,
                             return_stats: bool = False):
    """Flash-style attention in pure jnp: scan over q/kv chunks with running
    (m, l) statistics; never materialises more than (q_chunk, k_chunk)
    scores per head.  The mask predicate is evaluated per chunk pair from
    ``SeqMeta`` — this is the same algorithm the Pallas kernel runs, in
    XLA-lowerable form (the multi-pod dry-run lowers this path).

    Returns (B, Lq, H, Dv), or unnormalised ((B,H,Lq,Dv), m, l) stats if
    ``return_stats`` (used by the structured decomposition to merge parts).
    """
    B, Lq, H, D = q.shape
    _, Lk, Hkv, Dv = v.shape
    g = H // Hkv
    if scale is None:
        scale = D ** -0.5
    qc = _pick_chunk(Lq, q_chunk)
    kc = _pick_chunk(Lk, k_chunk)
    nq, nk = Lq // qc, Lk // kc

    qh = q.reshape(B, Lq, Hkv, g, D)
    kh, vh = k, v

    def q_step(qi):
        qs = jax.lax.dynamic_slice_in_dim(qh, qi * qc, qc, axis=1)
        qm = q_meta.slice_t(qi * qc, qc)

        def kv_step(carry, ki):
            acc, m, l = carry
            ks = jax.lax.dynamic_slice_in_dim(kh, ki * kc, kc, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vh, ki * kc, kc, axis=1)
            km = k_meta.slice_t(ki * kc, kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qs, ks,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            vis = visibility(qm, km, window=window, strict=strict)
            s = jnp.where(vis[:, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new) * vis[:, None, None]
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        init = (jnp.zeros((B, Hkv, g, qc, Dv), jnp.float32),
                jnp.full((B, Hkv, g, qc, 1), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, g, qc, 1), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(kv_step, init,
                                      jnp.arange(nk, dtype=jnp.int32))
        return acc, m, l

    acc, m, l = jax.lax.map(q_step, jnp.arange(nq, dtype=jnp.int32))
    # (nq, B, Hkv, g, qc, X) -> (B, H, Lq, X)
    def fold(x):
        x = jnp.moveaxis(x, 0, 3)                        # B,Hkv,g,nq,qc,X
        return x.reshape(B, H, Lq, x.shape[-1])

    acc, m, l = fold(acc), fold(m), fold(l)
    if return_stats:
        return acc, m, l
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).astype(q.dtype)                      # (B, H, Lq, Dv)
    return out.transpose(0, 2, 1, 3)


def structured_dup_attention(q, k, v, meta: SeqMeta, L: int,
                             block_size: int, *, scale=None, softcap=None,
                             window=None, strict: bool = False,
                             q_chunk: int = 512, k_chunk: int = 1024):
    """Memory-bounded structured evaluation of the DiRL duplicated layout.

    copy-A queries: block-causal over copy A (chunked).
    copy-B queries: chunked context pass over copy A, merged with the small
    block-diagonal pass over copy B.  Total score work ~2L^2 + L*block_size
    instead of the oracle's 4L^2.
    """
    B, T, H, D = q.shape
    Dv = v.shape[-1]
    assert T == 2 * L and L % block_size == 0
    if scale is None:
        scale = D ** -0.5
    K = L // block_size
    mA, mB = meta.slice_t(0, L), meta.slice_t(L, L)
    qA, qB = q[:, :L], q[:, L:]
    kA, vA = k[:, :L], v[:, :L]
    kB, vB = k[:, L:], v[:, L:]

    oA = chunked_masked_attention(qA, kA, vA, mA, mA, scale=scale,
                                  softcap=softcap, window=window,
                                  strict=strict, q_chunk=q_chunk,
                                  k_chunk=k_chunk)

    acc1, m1, l1 = chunked_masked_attention(
        qB, kA, vA, mB, mA, scale=scale, softcap=softcap, window=window,
        strict=strict, q_chunk=q_chunk, k_chunk=k_chunk, return_stats=True)

    def blockify(x):
        return x.reshape(B * K, block_size, *x.shape[2:])

    mBb = jax.tree.map(lambda a: a.reshape(B * K, block_size), mB)
    visBB = visibility(mBb, mBb, window=window, strict=strict)
    p2, m2, l2 = _part_scores(blockify(qB), blockify(kB), visBB,
                              scale=scale, softcap=softcap)
    o2 = _part_out(p2, blockify(vB))

    def unblock(x):  # (B*K, H, bsz, X) -> (B, H, L, X)
        return x.reshape(B, K, H, block_size, -1).transpose(
            0, 2, 1, 3, 4).reshape(B, H, L, -1)

    oB = _merge([(unblock(o2), unblock(m2), unblock(l2)), (acc1, m1, l1)])
    oB = oB.transpose(0, 2, 1, 3).astype(q.dtype)
    return jnp.concatenate([oA.astype(q.dtype), oB], axis=1)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------


def attention(q, k, v, q_meta: SeqMeta, k_meta: SeqMeta, *,
              impl: str = "structured",
              scale: float | None = None,
              softcap: float | None = None,
              window: int | None = None,
              strict: bool = False,
              dup_len: int | None = None,
              block_size: int | None = None,
              tq: int = 128, tk: int = 128) -> jax.Array:
    """Block-diffusion attention with selectable backend.

    ``dup_len``/``block_size`` enable the structured fast path when the
    layout is the DiRL duplicated layout (copy A = first ``dup_len``
    positions).  ``pallas`` clamps ``tq``/``tk`` to divisors of Lq/Lk
    (framework layouts are block-aligned, so this always succeeds) and
    is differentiable — the custom-VJP backward kernels skip the same
    empty tiles as the forward — so it is valid under ``jax.grad`` and
    ``jax.checkpoint`` in the trainers.
    """
    if impl == "ref":
        vis = visibility(q_meta, k_meta, window=window, strict=strict)
        return _ref.mha_reference(q, k, v, vis, scale=scale, softcap=softcap)
    if impl == "chunked" or (impl == "structured" and dup_len is None):
        return chunked_masked_attention(
            q, k, v, q_meta, k_meta, scale=scale, softcap=softcap,
            window=window, strict=strict)
    if impl == "structured":
        assert block_size is not None
        return structured_dup_attention(
            q, k, v, q_meta, dup_len, block_size,
            scale=scale, softcap=softcap, window=window, strict=strict)
    if impl in ("pallas", "pallas_interpret"):
        # clamp tiles to divisors so model-layer defaults (128) work at
        # any block-aligned length; interpret off-TPU (CI runs the real
        # kernel bodies on CPU, mirroring paged_attn.plan_exec)
        tq = _pick_chunk(q.shape[1], tq)
        tk = _pick_chunk(k.shape[1], tk)
        qm = pack_meta(q_meta)
        km = pack_meta(k_meta)
        tile_map = build_tile_map(qm, km, tq, tk, window=window)
        return block_diff_attention(
            q, k, v, qm, km, tile_map, scale=scale, softcap=softcap,
            window=window, strict=strict, tq=tq, tk=tk,
            interpret=(impl == "pallas_interpret") or default_interpret())
    raise ValueError(f"unknown attention impl: {impl}")
