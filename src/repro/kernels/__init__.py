# Compute hot-spot kernels (the paper's FlexAttention role on TPU):
#   block_diff_attn.py — masked-pass flash attention under the
#       block-diffusion visibility predicate (tile-skipping via ops.
#       build_tile_map); validated against ref.mha_reference.
#   paged_attn.py      — decode-mode paged attention that reads the
#       serving KV page pool in place through the per-slot block table
#       (scalar-prefetch gather); validated against the gathered
#       fallback in models.attention (tests/test_paged_attn.py).
# Both auto-run interpret=True off-TPU so CPU CI exercises the real
# kernel paths.  ops.py dispatches the masked-pass implementations.
