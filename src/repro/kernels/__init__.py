# Compute hot-spot kernels (the paper's FlexAttention role on TPU):
#   block_diff_attn.py — flash attention under the block-diffusion
#       visibility predicate, *differentiable*: one forward kernel plus
#       a dQ/dKV backward kernel pair wired through jax.custom_vjp, all
#       three skipping provably-empty tiles via the same precomputed
#       ops.build_tile_map (the BlockMask analogue).  This is the
#       training hot path — SFT/DiPO run it under remat — as well as
#       the training-shaped forward.  Forward validated bitwise against
#       ref.mha_reference; gradients tolerance-checked against autodiff
#       through the structured/ref paths (tests/test_kernels.py).
#   paged_attn.py      — the paged-kernel family: decode attention and
#       plain-mode suffix prefill, both reading the serving KV page
#       pool in place through scalar-prefetched block tables (zero
#       transient gather); sub-tile shapes are zero-padded to the
#       (8, 128) tile so they stay compiled-eligible on TPU.  plan_exec
#       reports the chosen execution mode.  Validated against the
#       gathered fallback in models.attention (tests/test_paged_attn.py).
# Both auto-run interpret=True off-TPU so CPU CI exercises the real
# kernel paths.  ops.py dispatches the masked-pass implementations and
# reports the training execution mode via train_exec_plan.
