# Compute hot-spot kernels (the paper's FlexAttention role on TPU):
#   block_diff_attn.py — masked-pass flash attention under the
#       block-diffusion visibility predicate (tile-skipping via ops.
#       build_tile_map); validated against ref.mha_reference.
#   paged_attn.py      — the paged-kernel family: decode attention and
#       plain-mode suffix prefill, both reading the serving KV page
#       pool in place through scalar-prefetched block tables (zero
#       transient gather); sub-tile shapes are zero-padded to the
#       (8, 128) tile so they stay compiled-eligible on TPU.  plan_exec
#       reports the chosen execution mode.  Validated against the
#       gathered fallback in models.attention (tests/test_paged_attn.py).
# Both auto-run interpret=True off-TPU so CPU CI exercises the real
# kernel paths.  ops.py dispatches the masked-pass implementations.
