"""Pure-jnp oracle attention for the block-diffusion mask.

This is the reference every kernel run is checked against (dense O(T^2)
mask materialisation).  Scores accumulate in f32 via
``preferred_element_type`` — inputs are never cast up-front, so bf16
caches are not duplicated in f32 (XLA would hoist such casts out of the
layer scan and hold every layer's copy live at once).

Layout convention throughout the kernels package:

    q        : (B, Lq, H, D)
    k, v     : (B, Lk, Hkv, Dv)     (GQA: H % Hkv == 0)
    mask     : (B, Lq, Lk) bool     (True = visible)
    returns  : (B, Lq, H, Dv)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array | None, *,
                  scale: float | None = None,
                  softcap: float | None = None) -> jax.Array:
    B, Lq, H, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[3]
    assert H % Hkv == 0, (H, Hkv)
    g = H // Hkv
    if scale is None:
        scale = D ** -0.5

    qh = q.reshape(B, Lq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if mask is not None:
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    # rows with no visible key: make them uniform (output is garbage but
    # finite; callers mask the loss).
    p = jax.nn.softmax(s, axis=-1)
    if mask is not None:
        allmasked = ~jnp.any(mask, axis=-1)  # (B, Lq)
        p = jnp.where(allmasked[:, None, None, :, None], 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Lq, H, Dv).astype(q.dtype)
