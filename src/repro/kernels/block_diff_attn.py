"""Differentiable Pallas TPU flash attention for the block-diffusion mask.

This is the TPU-native adaptation of the paper's FlexAttention usage
(§4.1), now covering *training*, not just inference forwards: the
block-diffusion visibility predicate is evaluated *as code* per
(128 x 128) tile from per-position metadata, and tiles that are provably
empty are skipped via a precomputed block-sparse ``tile_map`` (the
analogue of FlexAttention's BlockMask) — in the forward pass AND in both
halves of the backward pass.  The duplicated-sequence SFT mask attends
only ~1/4 of the dense (2L)^2 score matrix; skipping empty tiles
recovers that factor on the MXU three times per training step.

The grids are *tile-map-sparse*: ``_compact_tiles`` sorts the visited
(b, q_tile, kv_tile) triples into a scalar-prefetched list and the grid
is ``(heads, n_visited)`` with a **dynamic** trailing bound, so skipped
tiles cost no grid steps at all — not on the MXU, and not in the
sequential interpret-mode loop CI runs (where a dense grid would pay
per-iteration overhead even for gated-off tiles).  Rows with no visible
tile carry one gated dummy entry so their output block still
initializes to zero.  Per row the kv tiles stay in ascending order, so
the online-softmax accumulation order — and hence the forward results —
are bitwise identical to the dense-grid kernel.

Kernels (one ``pallas_call`` each, all gated by the same ``tile_map``
and the same ``_tile_visibility`` predicate):

``_kernel``      forward: online-softmax flash attention over the
                 q-major visited-tile list, accumulating (acc, m, l)
                 statistics in f32 VMEM scratch between a row's start
                 and end entries.  Under differentiation it
                 additionally emits the per-row logsumexp
                 ``lse = m + log(l)`` (lane-broadcast, the standard
                 flash residual) — the plain inference path is bit
                 identical to the pre-VJP kernel.
``_dq_kernel``   backward dQ: same q-major list/order as the forward;
                 each visited tile recomputes p = exp(s - lse), forms
                 ds = p * (dp - delta) (softcap's tanh handled via
                 1 - (s_capped/c)^2; the window term only ever enters
                 through the mask), and accumulates dq in scratch.
``_dkv_kernel``  backward dKV: the kv-major visited-tile list —
                 accumulating dk/dv per query head in scratch across a
                 kv row's q tiles; grouped (GQA/MQA/MLA) heads are
                 reduced to the Hkv axis outside the kernel.

``block_diff_attention`` wires the three through ``jax.custom_vjp`` with
the standard recomputation residuals (o, per-row lse): primal calls that
are never differentiated run the original two-output-free forward, so
inference callers pay nothing.  Gradients for the integer operands
(meta, tile_map) are symbolic zeros (float0).

Memory plan (per grid step): VMEM q/k/v/do tiles, meta tiles
(TQ|TK, 4) int32, SMEM visited-tile table (5, n_candidates) int32, f32
scratch accumulators plus (TQ, 128)-lane running statistics / residual
tiles.  Validated under ``interpret=True`` on CPU against
``ref.mha_reference`` (forward, bitwise vs the seed kernel) and against
autodiff through the ``structured``/``ref`` paths (gradients,
tolerance-based) — ``default_interpret()`` auto-selects interpret mode
off-TPU so CI runs these real kernel bodies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

DEFAULT_TQ = 128
DEFAULT_TK = 128
_LANES = 128

# meta column indices
COPY, BLOCK, STEP, POS = 0, 1, 2, 3
INVALID_COPY = 2  # matches no predicate clause -> never visible

# _compact_tiles table row indices
TM_B, TM_QI, TM_KI, TM_START, TM_END = 0, 1, 2, 3, 4


def default_interpret() -> bool:
    """Run compiled on TPU, interpreted everywhere else (CPU CI)."""
    return jax.default_backend() != "tpu"


def _compact_tiles(tile_map: jax.Array, *, kv_major: bool = False
                   ) -> tuple[jax.Array, jax.Array]:
    """Sort the visited tiles of ``tile_map`` into a dense worklist.

    Returns ``(tmeta, nv)``: ``tmeta`` is a ``(5, n_candidates)`` int32
    table with rows ``[b, q_tile, kv_tile, row_start, row_end]``, sorted
    by (b, major row, minor column) — q-major for the forward/dQ grids,
    kv-major for dKV — and ``nv`` is the (traced) number of live
    entries, which becomes the dynamic grid bound.  Entries past ``nv``
    are never executed.

    Every major row with *no* visible tile contributes one dummy entry
    pointing at its column-0 tile (provably invisible, so the kernel's
    ``tile_map > 0`` gate skips its compute) — the row's output block is
    still initialized and written, keeping empty rows exactly zero.
    Within a row, minor columns stay ascending: the flash accumulation
    order is identical to a dense grid's, so results are bitwise equal.
    """
    B, nq, nk = tile_map.shape
    vis = tile_map > 0
    if kv_major:
        vis = vis.transpose(0, 2, 1)
    R, C = vis.shape[1], vis.shape[2]
    rows = B * R
    visf = vis.reshape(-1)
    idx = jnp.arange(rows * C, dtype=jnp.int32)
    row_id, col_id = idx // C, idx % C
    big = jnp.int32(np.iinfo(np.int32).max)
    # live tiles sort by flat (row, col); dead tiles land in the +inf
    # bucket past nv.  One dummy candidate per row sorts after the
    # row's real tiles and goes live only when the row is empty.
    key_real = jnp.where(visf, row_id * (C + 1) + col_id, big)
    rid = jnp.arange(rows, dtype=jnp.int32)
    row_empty = ~jnp.any(vis.reshape(rows, C), axis=1)
    key_dummy = jnp.where(row_empty, rid * (C + 1) + C, big)
    keys = jnp.concatenate([key_real, key_dummy])
    cand_row = jnp.concatenate([row_id, rid])
    cand_col = jnp.concatenate([col_id, jnp.zeros_like(rid)])
    order = jnp.argsort(keys)
    skey = keys[order]
    live = skey < big
    srow = jnp.where(live, cand_row[order], -1)
    scol = jnp.where(live, cand_col[order], 0)
    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), srow[:-1]])
    nxt = jnp.concatenate([srow[1:], jnp.full((1,), -2, jnp.int32)])
    start = (srow != prev).astype(jnp.int32)
    end = (srow != nxt).astype(jnp.int32)
    b_of = jnp.where(live, srow // R, 0)
    major = jnp.where(live, srow % R, 0)
    qi_of, ki_of = (scol, major) if kv_major else (major, scol)
    tmeta = jnp.stack([b_of, qi_of, ki_of, start, end]).astype(jnp.int32)
    return tmeta, jnp.sum(live.astype(jnp.int32))


def _tile_visibility(qm, km, window: int | None, strict: bool):
    """Evaluate the mask predicate on a (TQ, TK) tile.

    qm: (TQ, 4) int32, km: (TK, 4) int32.  Uses 2D slices only (TPU-safe:
    no 1D vectors inside the kernel).
    """
    qc = qm[:, COPY:COPY + 1]          # (TQ, 1)
    qb = qm[:, BLOCK:BLOCK + 1]
    qs = qm[:, STEP:STEP + 1]
    qp = qm[:, POS:POS + 1]
    kc = km[:, COPY:COPY + 1].T        # (1, TK)
    kb = km[:, BLOCK:BLOCK + 1].T
    ks = km[:, STEP:STEP + 1].T
    kp = km[:, POS:POS + 1].T

    k_is_a = kc == 0
    k_is_b = kc == 1

    vis_a_query = k_is_a & (kb <= qb)
    if strict:
        ctx = k_is_a & (kb < qb)
        own = k_is_b & (kb == qb) & (ks == qs)
    else:
        ctx = k_is_a & ((kb < qb) | ((kb == qb) & (ks < qs)))
        own = k_is_b & (kb == qb) & (ks >= qs)
    vis = jnp.where(qc == 0, vis_a_query, ctx | own)
    # invalid (padding) queries match nothing, mirroring the oracle's
    # q.valid gate — so their rows are empty and their grads exactly 0
    vis = vis & (qc != INVALID_COPY)
    if window is not None:
        vis = vis & ((qp - kp) < window)
    return vis


def _kernel(tmeta_ref, tile_map_ref, qm_ref, km_ref, q_ref, k_ref, v_ref,
            o_ref, *rest, scale: float, softcap: float | None,
            window: int | None, strict: bool, emit_lse: bool = False):
    if emit_lse:
        lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        (acc_ref, m_ref, l_ref), lse_ref = rest, None
    t = pl.program_id(1)

    @pl.when(tmeta_ref[TM_START, t] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    needed = tile_map_ref[0, 0, 0] > 0

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (TQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (TK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (TQ, TK)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        vis = _tile_visibility(qm_ref[0], km_ref[0], window, strict)
        s = jnp.where(vis, s, NEG_INF)

        m_prev = m_ref[:, :1]                        # (TQ, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)   # (TQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)              # rescale old stats
        p = jnp.exp(s - m_new)                       # (TQ, TK)
        p = jnp.where(vis, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(tmeta_ref[TM_END, t] == 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        if emit_lse:
            # empty rows: m = NEG_INF, log(l->1) = 0, so lse = NEG_INF
            # and the backward's exp(NEG_INF - NEG_INF) = 1 is masked off
            lse_ref[0, 0] = m_ref[...] + jnp.log(
                jnp.broadcast_to(l, m_ref.shape))


def _tile_probs(q, k, qm, km, lse, *, scale, softcap, window, strict):
    """Recompute (p, s_capped) for one tile from the lse residual."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    vis = _tile_visibility(qm, km, window, strict)
    p = jnp.exp(jnp.where(vis, s, NEG_INF) - lse)
    p = jnp.where(vis, p, 0.0)
    return p, s


def _tile_dscore(p, s_capped, do, v, delta, *, softcap):
    """d(pre-softcap score) for one tile: the score-gradient chain rule.

    Masked entries have p = 0, so ds = 0 there — the window term and the
    visibility predicate enter the backward only through the mask.
    """
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (TQ, TK)
    ds = p * (dp - delta)
    if softcap is not None:
        # s_capped = c * tanh(s/c)  =>  d s = ds_capped * (1 - tanh^2)
        ds = ds * (1.0 - (s_capped / softcap) ** 2)
    return ds


def _dq_kernel(tmeta_ref, tile_map_ref, qm_ref, km_ref, q_ref, k_ref,
               v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc_ref, *,
               scale: float, softcap: float | None, window: int | None,
               strict: bool):
    t = pl.program_id(1)

    @pl.when(tmeta_ref[TM_START, t] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(tile_map_ref[0, 0, 0] > 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, :1]                   # (TQ, 1)
        delta = delta_ref[0, 0][:, :1]
        p, s = _tile_probs(q, k, qm_ref[0], km_ref[0], lse, scale=scale,
                           softcap=softcap, window=window, strict=strict)
        ds = _tile_dscore(p, s, do, v, delta, softcap=softcap)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(tmeta_ref[TM_END, t] == 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...]


def _dkv_kernel(tmeta_ref, tile_map_ref, qm_ref, km_ref, q_ref, k_ref,
                v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                dk_acc, dv_acc, *, scale: float, softcap: float | None,
                window: int | None, strict: bool):
    t = pl.program_id(1)

    @pl.when(tmeta_ref[TM_START, t] == 1)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(tile_map_ref[0, 0, 0] > 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, :1]
        delta = delta_ref[0, 0][:, :1]
        p, s = _tile_probs(q, k, qm_ref[0], km_ref[0], lse, scale=scale,
                           softcap=softcap, window=window, strict=strict)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (TK, Dv)
        ds = _tile_dscore(p, s, do, v, delta, softcap=softcap)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (TK, D)

    @pl.when(tmeta_ref[TM_END, t] == 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...]
        dv_ref[0, 0] = dv_acc[...]


def _specs(H, group, tq, tk, D, Dv, *, out_axis: str):
    """Block specs shared by the three launches.

    Index maps route through the scalar-prefetched tile table: grid is
    ``(H, n_visited)``, and entry ``t`` names (b, q_tile, kv_tile).
    ``out_axis`` selects which tile axis the per-head f32 output block
    follows ("q" for o/lse/dq, "k" for dk/dv).
    """
    def qmap(h, t, tm):
        return (tm[TM_B, t], h, tm[TM_QI, t], 0)

    def kmap(h, t, tm):
        return (tm[TM_B, t], h // group, tm[TM_KI, t], 0)

    def qm_map(h, t, tm):
        return (tm[TM_B, t], tm[TM_QI, t], 0)

    def km_map(h, t, tm):
        return (tm[TM_B, t], tm[TM_KI, t], 0)

    def tm_map(h, t, tm):
        return (tm[TM_B, t], tm[TM_QI, t], tm[TM_KI, t])

    def kout(h, t, tm):
        return (tm[TM_B, t], h, tm[TM_KI, t], 0)

    in_specs = [
        pl.BlockSpec((1, 1, 1), tm_map),
        pl.BlockSpec((1, tq, 4), qm_map),
        pl.BlockSpec((1, tk, 4), km_map),
        pl.BlockSpec((1, 1, tq, D), qmap),
        pl.BlockSpec((1, 1, tk, D), kmap),
        pl.BlockSpec((1, 1, tk, Dv), kmap),
    ]
    out_map = qmap if out_axis == "q" else kout
    return in_specs, qmap, out_map


def _forward(q, k, v, q_meta, k_meta, tile_map, *, scale, softcap, window,
             strict, tq, tk, interpret, emit_lse):
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    Dv = v.shape[3]
    group = H // Hkv

    # kernel-internal layout: (B, H, L, D)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    tm = tile_map.astype(jnp.int32)
    tmeta, nv = _compact_tiles(tm)

    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, strict=strict,
                             emit_lse=emit_lse)
    in_specs, qmap, out_map = _specs(H, group, tq, tk, D, Dv,
                                     out_axis="q")

    out_specs = pl.BlockSpec((1, 1, tq, Dv), out_map)
    out_shape = jax.ShapeDtypeStruct((B, H, Lq, Dv), q.dtype)
    if emit_lse:
        out_specs = [out_specs, pl.BlockSpec((1, 1, tq, _LANES), qmap)]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((B, H, Lq, _LANES), jnp.float32)]

    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(H, nv),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((tq, Dv), jnp.float32),
                pltpu.VMEM((tq, _LANES), jnp.float32),
                pltpu.VMEM((tq, _LANES), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(tmeta, tm, q_meta, k_meta, qh, kh, vh)

    if emit_lse:
        o, lse = res
        return o.transpose(0, 2, 1, 3), lse
    return res.transpose(0, 2, 1, 3)


def _backward(q, k, v, q_meta, k_meta, tile_map, o, lse, do, *, scale,
              softcap, window, strict, tq, tk, interpret):
    """The dQ and dKV kernel launches plus the cheap jnp glue around
    them (delta precompute, grouped-head reduction, dtype restore)."""
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    Dv = v.shape[3]
    group = H // Hkv

    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    doh = do.transpose(0, 2, 1, 3)
    oh = o.transpose(0, 2, 1, 3)
    # delta_i = sum_d do_id * o_id, lane-broadcast like lse
    delta = jnp.sum(oh.astype(jnp.float32) * doh.astype(jnp.float32),
                    axis=-1, keepdims=True)          # (B, H, Lq, 1)
    delta = jnp.broadcast_to(delta, (B, H, Lq, _LANES))
    tm = tile_map.astype(jnp.int32)
    kw = dict(scale=scale, softcap=softcap, window=window, strict=strict)

    in_specs, qmap, _ = _specs(H, group, tq, tk, D, Dv, out_axis="q")
    res_specs = [
        pl.BlockSpec((1, 1, tq, Dv), qmap),          # do
        pl.BlockSpec((1, 1, tq, _LANES), qmap),      # lse
        pl.BlockSpec((1, 1, tq, _LANES), qmap),      # delta
    ]

    # dQ walks the same q-major visited list as the forward
    tmeta_q, nv_q = _compact_tiles(tm)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **kw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(H, nv_q),
            in_specs=in_specs + res_specs,
            out_specs=pl.BlockSpec((1, 1, tq, D), qmap),
            scratch_shapes=[pltpu.VMEM((tq, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Lq, D), jnp.float32),
        interpret=interpret,
    )(tmeta_q, tm, q_meta, k_meta, qh, kh, vh, doh, lse, delta)

    # dKV walks the kv-major list: each kv row's visited q tiles are
    # consecutive, accumulating dk/dv in scratch
    b_in_specs, _, b_out_map = _specs(H, group, tq, tk, D, Dv,
                                      out_axis="k")
    tmeta_k, nv_k = _compact_tiles(tm, kv_major=True)
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, **kw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(H, nv_k),
            in_specs=b_in_specs + res_specs,
            out_specs=[
                pl.BlockSpec((1, 1, tk, D), b_out_map),
                pl.BlockSpec((1, 1, tk, Dv), b_out_map),
            ],
            scratch_shapes=[pltpu.VMEM((tk, D), jnp.float32),
                            pltpu.VMEM((tk, Dv), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lk, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Lk, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(tmeta_k, tm, q_meta, k_meta, qh, kh, vh, doh, lse, delta)

    dq = dq.transpose(0, 2, 1, 3).astype(q.dtype)
    # per-q-head dk/dv -> sum the group axis back onto the kv heads
    dk = dk_h.reshape(B, Hkv, group, Lk, D).sum(axis=2)
    dv = dv_h.reshape(B, Hkv, group, Lk, Dv).sum(axis=2)
    dk = dk.transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv.transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_attention_vjp(scale, softcap, window, strict, tq, tk, interpret):
    """custom_vjp closure over the static kernel parameters (cached so
    repeated traces reuse one primitive and never retrace the rules)."""
    kw = dict(scale=scale, softcap=softcap, window=window, strict=strict,
              tq=tq, tk=tk, interpret=interpret)

    @jax.custom_vjp
    def attn(q, k, v, q_meta, k_meta, tile_map):
        return _forward(q, k, v, q_meta, k_meta, tile_map,
                        emit_lse=False, **kw)

    def attn_fwd(q, k, v, q_meta, k_meta, tile_map):
        o, lse = _forward(q, k, v, q_meta, k_meta, tile_map,
                          emit_lse=True, **kw)
        return o, (q, k, v, q_meta, k_meta, tile_map, o, lse)

    def attn_bwd(res, do):
        q, k, v, q_meta, k_meta, tile_map, o, lse = res
        dq, dk, dv = _backward(q, k, v, q_meta, k_meta, tile_map, o, lse,
                               do, **kw)

        def zero(a):  # int operands take float0 symbolic-zero cotangents
            return np.zeros(a.shape, dtype=jax.dtypes.float0)

        return dq, dk, dv, zero(q_meta), zero(k_meta), zero(tile_map)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def block_diff_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         q_meta: jax.Array, k_meta: jax.Array,
                         tile_map: jax.Array, *,
                         scale: float | None = None,
                         softcap: float | None = None,
                         window: int | None = None,
                         strict: bool = False,
                         tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                         interpret: bool = False) -> jax.Array:
    """Differentiable flash attention under the block-diffusion mask.

    q: (B, Lq, H, D);  k, v: (B, Lk, Hkv, D/Dv);
    q_meta: (B, Lq, 4) int32 [copy, block, step, pos] with copy==2 on
    invalid (padding) positions;  k_meta: (B, Lk, 4) likewise;
    tile_map: (B, Lq//tq, Lk//tk) int32 (0 = skip, >0 = compute), from
    ``ops.build_tile_map`` — shared by the forward and both backward
    kernels, so empty tiles are skipped in all three passes.
    """
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    assert Lq % tq == 0 and Lk % tk == 0, (Lq, Lk, tq, tk)
    assert H % Hkv == 0
    if scale is None:
        scale = D ** -0.5
    fn = _make_attention_vjp(
        float(scale), None if softcap is None else float(softcap),
        window, bool(strict), int(tq), int(tk), bool(interpret))
    return fn(q, k, v, q_meta, k_meta, tile_map)
