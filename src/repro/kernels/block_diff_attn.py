"""Pallas TPU flash-attention kernel for the block-diffusion mask.

This is the TPU-native adaptation of the paper's FlexAttention usage
(§4.1): the block-diffusion visibility predicate is evaluated *as code*
per (128 x 128) tile from per-position metadata, and tiles that are
provably empty are skipped via a precomputed block-sparse ``tile_map``
(the analogue of FlexAttention's BlockMask).  The duplicated-sequence SFT
mask attends only ~1/4 of the dense (2L)^2 score matrix; skipping empty
tiles recovers that factor on the MXU.

Memory plan (per grid step):
  VMEM: q tile (TQ, D), k/v tiles (TK, D), meta tiles (TQ|TK, 4) int32,
        f32 scratch acc (TQ, D) + running max / sum (TQ, 128 lanes).
  Grid: (batch*heads, num_q_tiles, num_kv_tiles) — the kv axis is the
        innermost (sequential on TPU), accumulating flash statistics in
        scratch across kv steps.

Validated under ``interpret=True`` on CPU against ``ref.mha_reference``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

DEFAULT_TQ = 128
DEFAULT_TK = 128
_LANES = 128

# meta column indices
COPY, BLOCK, STEP, POS = 0, 1, 2, 3
INVALID_COPY = 2  # matches no predicate clause -> never visible


def _tile_visibility(qm, km, window: int | None, strict: bool):
    """Evaluate the mask predicate on a (TQ, TK) tile.

    qm: (TQ, 4) int32, km: (TK, 4) int32.  Uses 2D slices only (TPU-safe:
    no 1D vectors inside the kernel).
    """
    qc = qm[:, COPY:COPY + 1]          # (TQ, 1)
    qb = qm[:, BLOCK:BLOCK + 1]
    qs = qm[:, STEP:STEP + 1]
    qp = qm[:, POS:POS + 1]
    kc = km[:, COPY:COPY + 1].T        # (1, TK)
    kb = km[:, BLOCK:BLOCK + 1].T
    ks = km[:, STEP:STEP + 1].T
    kp = km[:, POS:POS + 1].T

    k_is_a = kc == 0
    k_is_b = kc == 1

    vis_a_query = k_is_a & (kb <= qb)
    if strict:
        ctx = k_is_a & (kb < qb)
        own = k_is_b & (kb == qb) & (ks == qs)
    else:
        ctx = k_is_a & ((kb < qb) | ((kb == qb) & (ks < qs)))
        own = k_is_b & (kb == qb) & (ks >= qs)
    vis = jnp.where(qc == 0, vis_a_query, ctx | own)
    if window is not None:
        vis = vis & ((qp - kp) < window)
    return vis


def _kernel(tile_map_ref, qm_ref, km_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *,
            scale: float, softcap: float | None, window: int | None,
            strict: bool):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    needed = tile_map_ref[0, 0, 0] > 0

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (TQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (TK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (TQ, TK)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        vis = _tile_visibility(qm_ref[0], km_ref[0], window, strict)
        s = jnp.where(vis, s, NEG_INF)

        m_prev = m_ref[:, :1]                        # (TQ, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)   # (TQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)              # rescale old stats
        p = jnp.exp(s - m_new)                       # (TQ, TK)
        p = jnp.where(vis, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def block_diff_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         q_meta: jax.Array, k_meta: jax.Array,
                         tile_map: jax.Array, *,
                         scale: float | None = None,
                         softcap: float | None = None,
                         window: int | None = None,
                         strict: bool = False,
                         tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                         interpret: bool = False) -> jax.Array:
    """Flash attention under the block-diffusion mask.

    q: (B, Lq, H, D);  k, v: (B, Lk, Hkv, D);
    q_meta: (B, Lq, 4) int32 [copy, block, step, pos] with copy==2 on
    invalid (padding) positions;  k_meta: (B, Lk, 4) likewise;
    tile_map: (B, Lq//tq, Lk//tk) int32 (0 = skip, >0 = compute), from
    ``ops.build_tile_map``.
    """
    B, Lq, H, D = q.shape
    _, Lk, Hkv, _ = k.shape
    Dv = v.shape[3]
    assert Lq % tq == 0 and Lk % tk == 0, (Lq, Lk, tq, tk)
    assert H % Hkv == 0
    group = H // Hkv
    if scale is None:
        scale = D ** -0.5
    nq, nk = Lq // tq, Lk // tk

    # kernel-internal layout: (B, H, L, D)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    grid = (B * H, nq, nk)

    def qmap(bh, qi, ki):
        return (bh // H, bh % H, qi, 0)

    def kmap(bh, qi, ki):
        return (bh // H, (bh % H) // group, ki, 0)

    def qm_map(bh, qi, ki):
        return (bh // H, qi, 0)

    def km_map(bh, qi, ki):
        return (bh // H, ki, 0)

    def tm_map(bh, qi, ki):
        return (bh // H, qi, ki)

    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, strict=strict)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), tm_map),
            pl.BlockSpec((1, tq, 4), qm_map),
            pl.BlockSpec((1, tk, 4), km_map),
            pl.BlockSpec((1, 1, tq, D), qmap),
            pl.BlockSpec((1, 1, tk, D), kmap),
            pl.BlockSpec((1, 1, tk, Dv), kmap),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, Dv), qmap),
        out_shape=jax.ShapeDtypeStruct((B, H, Lq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, Dv), jnp.float32),
            pltpu.VMEM((tq, _LANES), jnp.float32),
            pltpu.VMEM((tq, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(tile_map.astype(jnp.int32), q_meta, k_meta, qh, kh, vh)

    return out.transpose(0, 2, 1, 3)
