"""Pallas TPU paged-attention family: read the KV page pool in place.

Two kernels share one design — the per-slot block table rides in as a
**scalar-prefetch** operand, so each grid step's BlockSpec index map
resolves "which page does sequence b's block j live in" *before* the
step's DMA is issued, and no dense-width ``paged_gather`` copy of the
pool is ever materialized:

``paged_decode_attention``
    The decode-mode counterpart of ``block_diff_attn.py``: one
    current-block query tile per sequence attends to its committed KV
    directly in the shared pool (``models.attention.PagedAttnCache``).
    Grid ``(B, Hkv, K + 1)`` with the key axis innermost (sequential on
    TPU, accumulating online-softmax statistics in scratch): steps
    ``j < K`` stream page ``table[b, j]``, step ``j == K`` attends the
    block's own fresh K/V (the bidirectional self-block of blockwise
    dLLM decode).  Per-tick transient decode memory is O(page), never
    O(slots x K*bsz).

``paged_prefill_attention``
    The plain-mode (committed-context) counterpart, serving the
    shared-prefix *suffix prefill* (``core.decoding.prefill_suffix``):
    suffix queries attend to (hit-prefix pages ++ suffix self keys).
    Grid ``(B, Hkv, suffix_q_tiles, K_hit + suffix_kv_tiles)``; the kv
    axis streams one prefix page or suffix block per step into a
    compact VMEM scratch, and the final step replays the *reference*
    chunk walk (``kernels.ops.chunked_masked_attention``: same
    ``_pick_chunk`` kv-chunk boundaries, same scale -> softcap -> mask
    -> online-(m, l) arithmetic, same dot shapes) over that scratch.
    Because the scratch reproduces the gathered key layout
    byte-for-byte (prefix pages in table order, then suffix, no
    interleaved padding) and every op matches the reference walk, the
    kernel's output is **bitwise identical** to the gathered
    ``plain_paged`` path — and therefore to a full prefill — which is
    the invariant ``serving/prefix_cache.py`` is built on
    (tests/test_paged_attn.py pins it across GQA/MLA x window x
    softcap x hit-depth grids).  Admission-time transient KV bytes
    drop to zero: the gather that used to run per suffix admission is
    replaced by per-page streaming inside the grid.

Masking reproduces ``models.attention`` semantics byte-for-byte.
Decode: a pool key is visible iff its block has a page
(``table >= 0``), the slot is filled (``pos >= 0``) and committed for
this sequence (``pos < cache_limit[b]``); self keys are visible iff
their position is filled (``pos >= 0`` — always true for real rows,
false only for tile padding).  Prefill: a key is visible iff filled
(``pos >= 0``) and block-causal (``k_pos // bsz <= q_pos // bsz``; the
suffix self-block is bidirectional because its keys share the queries'
blocks).  A sliding window ``(q_pos - k_pos) < window`` applies
everywhere.  Scores accumulate in f32 with the same scale -> softcap ->
mask order as the reference.

Execution planning (``plan_exec`` / ``KernelPlan``): off-TPU both
kernels auto-select ``interpret=True`` so CPU CI runs the *real* kernel
path.  On TPU, shapes below the (8, 128) f32 tile no longer fall back
to interpret mode — ``pad=None`` auto-enables **tile padding**: head
dims are zero-padded to a lane multiple (exact: the contraction gains
trailing ``+0.0`` terms only) and pages are padded to a sublane
multiple with ``pos = -1`` rows the validity mask hides (decode) or
with only the real rows written into the compact scratch (prefill, so
chunk boundaries — and bits — are unchanged).  ``plan_exec`` is the
queryable record of the choice (mode, reason, padding) that
``serving``/``launch.serve`` surface as a stat.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import _pick_chunk
from .ref import NEG_INF

_LANES = 128
_SUBLANES = 8
# reference chunk targets (kernels.ops.chunked_masked_attention
# defaults) — the prefill kernel must reuse the kv target so its chunk
# boundaries, and therefore its bits, match the gathered path
_K_CHUNK = 1024
_Q_CHUNK = 128


def default_interpret() -> bool:
    """Run compiled on TPU, interpreted everywhere else (CPU CI)."""
    return jax.default_backend() != "tpu"


def _tile_aligned(bsz: int, dk: int, dv: int) -> bool:
    """Shapes the compiled Mosaic path lowers without padding: the f32
    min tile is (8, 128).  Sub-tile shapes (small ``block_size``
    configs, non-128-multiple head dims) are zero-padded up to the tile
    by ``plan_exec``'s auto mode instead of falling back to interpret
    mode on TPU."""
    return bsz % _SUBLANES == 0 and dk % _LANES == 0 and dv % _LANES == 0


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """The execution mode a paged kernel will run under, and why.

    ``mode``    "compiled" (Mosaic on TPU) | "interpret" (the same
                kernel body evaluated op-by-op through XLA — the CPU CI
                path, and the explicit-``interpret=True`` path on TPU).
    ``reason``  human-readable cause: backend, tile alignment, padding.
    ``padded``  tile padding active (sub-tile shapes lifted to the
                (8, 128) f32 tile; masked/zero padding, bit-exact).
    """
    mode: str
    reason: str
    padded: bool

    @property
    def interpret(self) -> bool:
        return self.mode == "interpret"


def plan_exec(bsz: int, dk: int, dv: int, *,
              interpret: bool | None = None,
              pad: bool | None = None) -> KernelPlan:
    """Resolve (interpret?, pad?) for page shape (bsz, dk, dv).

    ``interpret=None`` auto-selects by backend (compiled on TPU only);
    ``pad=None`` auto-enables tile padding exactly when compiling a
    sub-tile shape.  Explicit booleans always win — tests force
    ``interpret=True, pad=True`` to pin the padded path's bit-parity on
    CPU, and ``pad=False`` on TPU falls back to interpret mode for
    sub-tile shapes (the pre-padding behaviour).
    """
    backend = jax.default_backend()
    aligned = _tile_aligned(bsz, dk, dv)
    forced = interpret is not None
    if interpret is None:
        interpret = backend != "tpu"
    if not interpret and not aligned and pad is None:
        pad = True
    if not interpret and not aligned and not pad:
        return KernelPlan(
            "interpret",
            f"sub-tile page shape (bsz={bsz}, dk={dk}, dv={dv}) with "
            "padding disabled", False)
    padded = bool(pad)
    if interpret:
        reason = "interpret requested" if forced else \
            f"backend={backend} (compiled Mosaic path needs a TPU)"
        return KernelPlan("interpret", reason, padded)
    reason = "tile-aligned page shape" if aligned else \
        (f"sub-tile page shape (bsz={bsz}, dk={dk}, dv={dv}) "
         "zero-padded to the (8, 128) tile")
    return KernelPlan("compiled", reason, padded)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_dim(a: jax.Array, axis: int, target: int,
             value=0) -> jax.Array:
    """Pad ``axis`` up to ``target`` with ``value`` (no-op if already
    there).  Zero-padding a contraction dim appends exact ``+0.0``
    terms; position arrays pad with -1 so the validity mask hides the
    rows."""
    if a.shape[axis] == target:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, target - a.shape[axis])
    return jnp.pad(a, widths, constant_values=value)


# ---------------------------------------------------------------------------
# decode kernel
# ---------------------------------------------------------------------------


def _kernel(table_ref, limit_ref, q_ref, kp_ref, vp_ref, pp_ref,
            ks_ref, vs_ref, qp_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, softcap: float | None, window: int | None,
            group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nk = pl.num_programs(2)          # K + 1: pages then the self block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    is_self = j == nk - 1
    # page id for this step (clamped read: the value is unused when
    # is_self; the index map already redirected -1 to the null page)
    t = table_ref[b, jnp.minimum(j, nk - 2)]
    lim = limit_ref[b]

    # all ``group`` query heads of this kv head ride one page fetch
    q = q_ref[0, 0].astype(jnp.float32)               # (group*n, Dk)
    k = jnp.where(is_self, ks_ref[0, 0], kp_ref[0, :, 0, :]) \
        .astype(jnp.float32)                          # (bsz, Dk)
    v = jnp.where(is_self, vs_ref[0, 0], vp_ref[0, :, 0, :]) \
        .astype(jnp.float32)                          # (bsz, Dv)
    q_pos = qp_ref[0:1, :]                            # (1, n)
    k_pos = jnp.where(is_self, q_pos, pp_ref[0:1, :])  # (1, bsz)
    # pool keys: block mapped & slot filled & committed for this row;
    # self keys: filled (pos >= 0 — real rows always, tile-padding rows
    # carry pos = -1 and stay invisible)
    page_ok = (t >= 0) & (k_pos >= 0) & (k_pos < lim)
    valid = jnp.where(is_self, k_pos >= 0, page_ok)
    if window is not None:
        valid = valid & ((q_pos.T - k_pos) < window)   # (n, bsz)
        valid = jnp.tile(valid, (group, 1))            # (group*n, bsz)
    else:
        valid = jnp.broadcast_to(valid, (q.shape[0], k.shape[0]))

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (group*n, bsz)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, :1]                              # (group*n, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                    # rescale old stats
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)                       # exp(NEG-NEG)=1 trap
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, pos_pages: jax.Array,
                           table: jax.Array, k_self: jax.Array,
                           v_self: jax.Array, positions: jax.Array,
                           cache_limit: jax.Array, *,
                           scale: float,
                           softcap: float | None = None,
                           window: int | None = None,
                           interpret: bool | None = None,
                           pad: bool | None = None) -> jax.Array:
    """Decode attention over (pool pages ++ self block), in place.

    q          (B, n, H, Dk)   current-block queries (n == page size)
    k_pages    (P, bsz, Hkv, Dk) shared pool, rotated keys
    v_pages    (P, bsz, Hkv, Dv)
    pos_pages  (P, bsz) int32  absolute position ids, -1 = empty slot
    table      (B, K) int32    block -> page, -1 = no page
    k_self     (B, n, Hkv, Dk) the block's own fresh keys
    v_self     (B, n, Hkv, Dv)
    positions  (B, n) int32    the block's absolute positions
    cache_limit (B,) int32     pool keys visible iff pos < limit[b]

    Returns (B, n, H, Dv) in q's dtype.  ``interpret``/``pad`` follow
    ``plan_exec``: interpret mode off-TPU, tile padding for sub-tile
    shapes on TPU.  Padding is bit-exact per construction — padded key
    rows carry ``pos = -1`` (masked -> exact ``+0.0`` tail terms in the
    softmax sum and the PV product), padded head dims are zero (exact
    ``+0.0`` tail terms in the QK contraction) — so the padded kernel
    matches the unpadded one bitwise (tests force ``pad=True`` on CPU
    to pin this).
    """
    B, n, H, Dk = q.shape
    P, bsz, Hkv, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    K = table.shape[1]
    assert n == bsz, (n, bsz)     # decode block == page granularity
    assert H % Hkv == 0
    group = H // Hkv
    plan = plan_exec(bsz, Dk, Dv, interpret=interpret, pad=pad)
    if plan.padded:
        bp = _ceil_to(bsz, _SUBLANES)
        dkp, dvp = _ceil_to(Dk, _LANES), _ceil_to(Dv, _LANES)
        q = _pad_dim(_pad_dim(q, 1, bp), 3, dkp)
        k_pages = _pad_dim(_pad_dim(k_pages, 1, bp), 3, dkp)
        v_pages = _pad_dim(_pad_dim(v_pages, 1, bp), 3, dvp)
        pos_pages = _pad_dim(pos_pages, 1, bp, value=-1)
        k_self = _pad_dim(_pad_dim(k_self, 1, bp), 3, dkp)
        v_self = _pad_dim(_pad_dim(v_self, 1, bp), 3, dvp)
        positions = _pad_dim(positions, 1, bp, value=-1)
        out = paged_decode_attention(
            q, k_pages, v_pages, pos_pages, table, k_self, v_self,
            positions, cache_limit, scale=scale, softcap=softcap,
            window=window, interpret=plan.interpret, pad=False)
        return out[:, :n, :, :Dv]

    # grid iterates KV heads, not query heads: head h attends kv head
    # h // group, so the whole group's queries are folded into one
    # (group*n, Dk) tile and every page is streamed once per kv head
    # per step (a per-q-head grid would re-DMA each page `group` times
    # — H times for MLA's MQA form)
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hkv, group * n, Dk)
    ksh = k_self.transpose(0, 2, 1, 3)    # (B, Hkv, n, Dk)
    vsh = v_self.transpose(0, 2, 1, 3)

    # index maps see (grid indices..., *scalar prefetch refs); the page
    # maps read the block table so each step DMAs exactly one page
    def q_map(b, h, j, tr, lr):
        return (b, h, 0, 0)

    def page_map(b, h, j, tr, lr):
        page = tr[b, jnp.minimum(j, K - 1)]
        return (jnp.maximum(page, 0), 0, h, 0)

    def pos_map(b, h, j, tr, lr):
        page = tr[b, jnp.minimum(j, K - 1)]
        return (jnp.maximum(page, 0), 0)

    def self_map(b, h, j, tr, lr):
        return (b, h, 0, 0)

    def row_map(b, h, j, tr, lr):
        return (b, 0)

    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, K + 1),
        in_specs=[
            pl.BlockSpec((1, 1, group * n, Dk), q_map),
            pl.BlockSpec((1, bsz, 1, Dk), page_map),
            pl.BlockSpec((1, bsz, 1, Dv), page_map),
            pl.BlockSpec((1, bsz), pos_map),
            pl.BlockSpec((1, 1, n, Dk), self_map),
            pl.BlockSpec((1, 1, n, Dv), self_map),
            pl.BlockSpec((1, n), row_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group * n, Dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((group * n, Dv), jnp.float32),
            pltpu.VMEM((group * n, _LANES), jnp.float32),
            pltpu.VMEM((group * n, _LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group * n, Dv), q.dtype),
        interpret=plan.interpret,
    )(table.astype(jnp.int32), cache_limit.astype(jnp.int32),
      qh, k_pages, v_pages, pos_pages, ksh, vsh,
      positions.astype(jnp.int32))
    return out.reshape(B, H, n, Dv).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# prefill kernel (plain mode: suffix queries vs prefix pages ++ self)
# ---------------------------------------------------------------------------


def _prefill_kernel(table_ref, q_ref, kp_ref, vp_ref, pp_ref,
                    ks_ref, vs_ref, sp_ref, qp_ref, o_ref,
                    k_s, v_s, pos_s, *,
                    scale: float, softcap: float | None,
                    window: int | None, group: int, bsz: int, Kp: int,
                    kc: int):
    b = pl.program_id(0)
    j = pl.program_id(3)
    n_kv = pl.num_programs(3)        # Kp prefix pages + Ts suffix blocks
    is_pfx = j < Kp

    # --- stream this step's block into the compact scratch ------------
    # prefix pages cast to the activation dtype on write (the reference
    # gathers with ``ck.astype(k_self.dtype)``); only the *real* bsz
    # rows of a (possibly tile-padded) fetched block are written, so
    # the scratch reproduces the gathered key layout exactly — prefix
    # pages in table order, then the suffix, no interleaved padding —
    # and the reference chunk boundaries land on the same keys
    t = table_ref[b, jnp.minimum(j, Kp - 1)] if Kp else jnp.int32(-1)
    k_blk = jnp.where(is_pfx, kp_ref[0, :, 0, :].astype(k_s.dtype),
                      ks_ref[0, 0, 0])
    v_blk = jnp.where(is_pfx, vp_ref[0, :, 0, :].astype(v_s.dtype),
                      vs_ref[0, 0, 0])
    pos_pfx = jnp.where(t >= 0, pp_ref[0, :], -1)
    pos_blk = jnp.where(is_pfx, pos_pfx, sp_ref[0, 0])
    k_s[pl.ds(j * bsz, bsz), :] = k_blk[:bsz]
    v_s[pl.ds(j * bsz, bsz), :] = v_blk[:bsz]
    pos_s[pl.ds(j * bsz, bsz), :] = jnp.broadcast_to(
        pos_blk[:bsz, None], (bsz, pos_s.shape[-1]))

    # --- final kv step: the reference chunk walk over the scratch -----
    @pl.when(j == n_kv - 1)
    def _attend():
        qf = q_ref[0, 0]                         # (group, qc, Dk)
        g, qc, _ = qf.shape
        qf = qf.reshape(g * qc, qf.shape[-1])
        q_pos = qp_ref[0, :]                     # (qc,)
        qb = q_pos // bsz
        Lk = n_kv * bsz
        dv = v_s.shape[-1]
        acc = jnp.zeros((g * qc, dv), jnp.float32)
        m = jnp.full((g * qc, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((g * qc, 1), jnp.float32)
        # static unroll: kc = _pick_chunk(Lk, 1024) — the *reference*
        # kv chunking, so each chunk's (m, l) rescale groups exactly
        # the keys chunked_masked_attention groups
        for ki in range(Lk // kc):
            ks = k_s[ki * kc:(ki + 1) * kc, :]
            vs = v_s[ki * kc:(ki + 1) * kc, :]
            kpos = pos_s[ki * kc:(ki + 1) * kc, 0]          # (kc,)
            s = jax.lax.dot_general(
                qf, ks, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            # plain-mode visibility: filled & block-causal (& window) —
            # models.attention builds exactly this from the gathered
            # positions (core.masks.visibility, all-copy-A layout)
            vis = (kpos >= 0)[None, :] \
                & ((kpos // bsz)[None, :] <= qb[:, None])
            if window is not None:
                vis = vis & ((q_pos[:, None] - kpos[None, :]) < window)
            vis = jnp.tile(vis, (g, 1))          # (g*qc, kc), g-major
            s = jnp.where(vis, s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new) * vis
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jax.lax.dot_general(
                p.astype(vs.dtype), vs, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m = m_new
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc / l).astype(o_ref.dtype).reshape(g, qc, dv)


def paged_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, pos_pages: jax.Array,
                            context_table: jax.Array, k_self: jax.Array,
                            v_self: jax.Array, positions: jax.Array, *,
                            scale: float,
                            softcap: float | None = None,
                            window: int | None = None,
                            interpret: bool | None = None,
                            pad: bool | None = None) -> jax.Array:
    """Plain-mode attention of suffix queries over (prefix pages ++
    suffix self keys), reading the pool in place.

    q             (B, T, H, Dk)   suffix queries, T a block multiple
    k_pages       (P, bsz, Hkv, Dk) shared pool, rotated keys
    v_pages       (P, bsz, Hkv, Dv)
    pos_pages     (P, bsz) int32  absolute positions, -1 = empty slot
    context_table (B, Kp) int32   hit-prefix block -> page (-1 masked)
    k_self        (B, T, Hkv, Dk) the suffix's own fresh keys
    v_self        (B, T, Hkv, Dv)
    positions     (B, T) int32    absolute suffix positions (all valid
                                  — the ``prefill_suffix`` layout)

    Returns (B, T, H, Dv) in q's dtype, **bitwise identical** to the
    gathered path (``models.attention`` ``_paged_context_kv`` +
    ``kernels.ops.chunked_masked_attention``): the kernel streams
    blocks into a compact scratch reproducing the gathered key layout,
    then replays the reference chunk walk — same kv-chunk boundaries
    (``_pick_chunk(Lk, 1024)``), same op order, same dot shapes.  Holds
    for ``attn_impl`` "structured"/"chunked" (both route plain passes
    through ``chunked_masked_attention``); the dense-mask "ref" impl
    agrees to rounding only.

    ``interpret``/``pad`` follow ``plan_exec``.  Tile padding pads the
    *DMA* block shapes; the scratch stays compact (real rows only), so
    padding never moves a chunk boundary and parity stays bitwise.

    Caveat: the replay makes the *kernel-side* op order identical, but
    XLA may still reassociate the softmax-denominator reduction
    (``jnp.sum(p, -1)``) differently when compiling the reference's
    ``lax.scan`` body at some shapes — observed at Dk=Dv=96/Lk=20,
    where only ``l`` diverges (~1e-7 in the output) while ``m`` and
    ``acc`` stay bitwise.  At the repo's model shapes (head dims
    16–40, block sizes 8/16 — pinned by tests/test_paged_attn.py) the
    compiled orders coincide and parity is exactly bitwise; padded vs
    unpadded kernel runs are bitwise at *every* shape.
    """
    B, T, H, Dk = q.shape
    P, bsz, Hkv, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    Kp = context_table.shape[1]
    assert T % bsz == 0, (T, bsz)
    assert H % Hkv == 0
    Ts = T // bsz
    group = H // Hkv
    plan = plan_exec(bsz, Dk, Dv, interpret=interpret, pad=pad)
    dkp, dvp, bp = Dk, Dv, bsz
    if plan.padded:
        bp = _ceil_to(bsz, _SUBLANES)
        dkp, dvp = _ceil_to(Dk, _LANES), _ceil_to(Dv, _LANES)
        q = _pad_dim(q, 3, dkp)
        k_pages = _pad_dim(_pad_dim(k_pages, 1, bp), 3, dkp)
        v_pages = _pad_dim(_pad_dim(v_pages, 1, bp), 3, dvp)
        pos_pages = _pad_dim(pos_pages, 1, bp, value=-1)
        k_self = _pad_dim(k_self, 3, dkp)
        v_self = _pad_dim(v_self, 3, dvp)

    Lk = (Kp + Ts) * bsz
    qc = _pick_chunk(T, _Q_CHUNK)
    kc = _pick_chunk(Lk, _K_CHUNK)
    nq = T // qc

    # fold queries per kv head (g-major rows — the reference einsum's
    # "bqhgd,bkhd->bhgqk" row order) and expose suffix K/V block-wise
    # so the kv grid axis can stream one block per step
    q5 = q.transpose(0, 2, 1, 3).reshape(B, Hkv, group, T, dkp)
    ks5 = k_self.reshape(B, Ts, bsz, Hkv, dkp).transpose(0, 1, 3, 2, 4)
    vs5 = v_self.reshape(B, Ts, bsz, Hkv, dvp).transpose(0, 1, 3, 2, 4)
    if plan.padded:
        ks5 = _pad_dim(ks5, 3, bp)
        vs5 = _pad_dim(vs5, 3, bp)
    spos = positions.reshape(B, Ts, bsz)
    if plan.padded:
        spos = _pad_dim(spos, 2, bp, value=-1)
    table = context_table.astype(jnp.int32)
    if Kp == 0:  # degenerate no-prefix call: keep the prefetch 2-D
        table = jnp.full((B, 1), -1, jnp.int32)

    def q_map(b, h, qt, j, tr):
        return (b, h, 0, qt, 0)

    def page_map(b, h, qt, j, tr):
        page = tr[b, jnp.minimum(j, max(Kp - 1, 0))]
        return (jnp.maximum(page, 0), 0, h, 0)

    def ppos_map(b, h, qt, j, tr):
        page = tr[b, jnp.minimum(j, max(Kp - 1, 0))]
        return (jnp.maximum(page, 0), 0)

    def self_map(b, h, qt, j, tr):
        return (b, jnp.clip(j - Kp, 0, Ts - 1), h, 0, 0)

    def spos_map(b, h, qt, j, tr):
        return (b, jnp.clip(j - Kp, 0, Ts - 1), 0)

    def qpos_map(b, h, qt, j, tr):
        return (b, qt)

    kern = functools.partial(_prefill_kernel, scale=scale,
                             softcap=softcap, window=window, group=group,
                             bsz=bsz, Kp=Kp, kc=kc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nq, Kp + Ts),
        in_specs=[
            pl.BlockSpec((1, 1, group, qc, dkp), q_map),
            pl.BlockSpec((1, bp, 1, dkp), page_map),
            pl.BlockSpec((1, bp, 1, dvp), page_map),
            pl.BlockSpec((1, bp), ppos_map),
            pl.BlockSpec((1, 1, 1, bp, dkp), self_map),
            pl.BlockSpec((1, 1, 1, bp, dvp), self_map),
            pl.BlockSpec((1, 1, bp), spos_map),
            pl.BlockSpec((1, qc), qpos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, qc, dvp), q_map),
        scratch_shapes=[
            pltpu.VMEM((Lk, dkp), k_self.dtype),
            pltpu.VMEM((Lk, dvp), v_self.dtype),
            # positions replicated across a full lane: a (Lk, 1) buffer
            # is not (8, 128)-tile addressable in compiled mode
            pltpu.VMEM((Lk, _LANES), jnp.int32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, T, dvp), q.dtype),
        interpret=plan.interpret,
    )(table, q5, k_pages, v_pages, pos_pages, ks5, vs5,
      spos.astype(jnp.int32), positions.astype(jnp.int32))
    out = out.reshape(B, H, T, dvp).transpose(0, 2, 1, 3)
    return out[..., :Dv] if plan.padded else out
