"""Pallas TPU paged-decode attention: read the KV page pool in place.

The decode-mode counterpart of ``block_diff_attn.py``: one current-block
query tile per sequence attends to its committed KV *directly in the
shared page pool* (``models.attention.PagedAttnCache``).  The per-slot
block table rides in as a **scalar-prefetch** operand, so each grid
step's BlockSpec index map resolves "which page does sequence b's block
j live in" *before* the step's DMA is issued — the kernel gathers pages
page-by-page inside the grid instead of materializing the dense-width
``paged_gather`` copy (slots x K*bsz keys per layer per step) that the
gathered fallback pays.

Grid: ``(B, Hkv, K + 1)`` with the key axis innermost (sequential on
TPU, accumulating online-softmax statistics in scratch).  The kv-head
grid axis folds each GQA group's queries into one (group*n, Dk) tile,
so a page is streamed exactly once per kv head per step — never once
per query head (for MLA's latent MQA that is a single fetch for all H
heads):

* steps ``j < K`` load page ``table[b, j]`` from the pool (table entry
  -1 — no page — loads the null page 0 and is masked invalid);
* step ``j == K`` attends the block's own fresh K/V (the bidirectional
  self-block of blockwise-dLLM decode).

Masking reproduces ``models.attention`` decode semantics byte-for-byte:
a pool key is visible iff its block has a page (``table >= 0``), the
slot is filled (``pos >= 0``) and committed for this sequence
(``pos < cache_limit[b]``); self keys are always visible; a sliding
window ``(q_pos - k_pos) < window`` applies to both.  Scores accumulate
in f32 with the same scale -> softcap -> mask order as the reference.

Off-TPU the kernel auto-selects ``interpret=True`` so CPU CI runs the
*real* kernel path (mirroring how ``block_diff_attn`` is validated
against ``ref.mha_reference``).

Memory plan (per grid step): q tile (n, Dk), one page of k/v
((bsz, Dk)/(bsz, Dv)) + its (1, bsz) positions, f32 scratch acc
(n, Dv) + running max / sum (n, 128 lanes).  VMEM is O(page), never
O(sequence) — transient decode memory no longer scales with K.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

_LANES = 128


def default_interpret() -> bool:
    """Run compiled on TPU, interpreted everywhere else (CPU CI)."""
    return jax.default_backend() != "tpu"


def _tile_aligned(bsz: int, dk: int, dv: int) -> bool:
    """Shapes the compiled Mosaic path is known to lower: the f32 min
    tile is (8, 128), so sub-tile pages (small ``block_size`` configs,
    non-128-multiple head dims) stay on interpret mode even on TPU
    until compiled-mode tile padding lands (ROADMAP follow-up) —
    correct everywhere, compiled only where safe."""
    return bsz % 8 == 0 and dk % _LANES == 0 and dv % _LANES == 0


def _kernel(table_ref, limit_ref, q_ref, kp_ref, vp_ref, pp_ref,
            ks_ref, vs_ref, qp_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, softcap: float | None, window: int | None,
            group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nk = pl.num_programs(2)          # K + 1: pages then the self block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    is_self = j == nk - 1
    # page id for this step (clamped read: the value is unused when
    # is_self; the index map already redirected -1 to the null page)
    t = table_ref[b, jnp.minimum(j, nk - 2)]
    lim = limit_ref[b]

    # all ``group`` query heads of this kv head ride one page fetch
    q = q_ref[0, 0].astype(jnp.float32)               # (group*n, Dk)
    k = jnp.where(is_self, ks_ref[0, 0], kp_ref[0, :, 0, :]) \
        .astype(jnp.float32)                          # (bsz, Dk)
    v = jnp.where(is_self, vs_ref[0, 0], vp_ref[0, :, 0, :]) \
        .astype(jnp.float32)                          # (bsz, Dv)
    q_pos = qp_ref[0:1, :]                            # (1, n)
    k_pos = jnp.where(is_self, q_pos, pp_ref[0:1, :])  # (1, bsz)
    # pool keys: block mapped & slot filled & committed for this row;
    # self keys: always visible (the bidirectional self block)
    page_ok = (t >= 0) & (k_pos >= 0) & (k_pos < lim)
    valid = jnp.where(is_self, jnp.ones_like(page_ok), page_ok)
    if window is not None:
        valid = valid & ((q_pos.T - k_pos) < window)   # (n, bsz)
        valid = jnp.tile(valid, (group, 1))            # (group*n, bsz)
    else:
        valid = jnp.broadcast_to(valid, (q.shape[0], k.shape[0]))

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (group*n, bsz)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, :1]                              # (group*n, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                    # rescale old stats
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)                       # exp(NEG-NEG)=1 trap
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, pos_pages: jax.Array,
                           table: jax.Array, k_self: jax.Array,
                           v_self: jax.Array, positions: jax.Array,
                           cache_limit: jax.Array, *,
                           scale: float,
                           softcap: float | None = None,
                           window: int | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """Decode attention over (pool pages ++ self block), in place.

    q          (B, n, H, Dk)   current-block queries (n == page size)
    k_pages    (P, bsz, Hkv, Dk) shared pool, rotated keys
    v_pages    (P, bsz, Hkv, Dv)
    pos_pages  (P, bsz) int32  absolute position ids, -1 = empty slot
    table      (B, K) int32    block -> page, -1 = no page
    k_self     (B, n, Hkv, Dk) the block's own fresh keys
    v_self     (B, n, Hkv, Dv)
    positions  (B, n) int32    the block's absolute positions
    cache_limit (B,) int32     pool keys visible iff pos < limit[b]

    Returns (B, n, H, Dv) in q's dtype.  ``interpret=None`` auto-selects
    interpret mode off-TPU — and on TPU whenever the page shapes fall
    below the compiled path's (8, 128) f32 tile (``_tile_aligned``), so
    the kernel is correct everywhere and compiled only where safe.
    """
    B, n, H, Dk = q.shape
    P, bsz, Hkv, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    K = table.shape[1]
    assert n == bsz, (n, bsz)     # decode block == page granularity
    assert H % Hkv == 0
    group = H // Hkv
    if interpret is None:
        interpret = default_interpret() or not _tile_aligned(bsz, Dk, Dv)

    # grid iterates KV heads, not query heads: head h attends kv head
    # h // group, so the whole group's queries are folded into one
    # (group*n, Dk) tile and every page is streamed once per kv head
    # per step (a per-q-head grid would re-DMA each page `group` times
    # — H times for MLA's MQA form)
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hkv, group * n, Dk)
    ksh = k_self.transpose(0, 2, 1, 3)    # (B, Hkv, n, Dk)
    vsh = v_self.transpose(0, 2, 1, 3)

    # index maps see (grid indices..., *scalar prefetch refs); the page
    # maps read the block table so each step DMAs exactly one page
    def q_map(b, h, j, tr, lr):
        return (b, h, 0, 0)

    def page_map(b, h, j, tr, lr):
        page = tr[b, jnp.minimum(j, K - 1)]
        return (jnp.maximum(page, 0), 0, h, 0)

    def pos_map(b, h, j, tr, lr):
        page = tr[b, jnp.minimum(j, K - 1)]
        return (jnp.maximum(page, 0), 0)

    def self_map(b, h, j, tr, lr):
        return (b, h, 0, 0)

    def row_map(b, h, j, tr, lr):
        return (b, 0)

    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, K + 1),
        in_specs=[
            pl.BlockSpec((1, 1, group * n, Dk), q_map),
            pl.BlockSpec((1, bsz, 1, Dk), page_map),
            pl.BlockSpec((1, bsz, 1, Dv), page_map),
            pl.BlockSpec((1, bsz), pos_map),
            pl.BlockSpec((1, 1, n, Dk), self_map),
            pl.BlockSpec((1, 1, n, Dv), self_map),
            pl.BlockSpec((1, n), row_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group * n, Dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((group * n, Dv), jnp.float32),
            pltpu.VMEM((group * n, _LANES), jnp.float32),
            pltpu.VMEM((group * n, _LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group * n, Dv), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), cache_limit.astype(jnp.int32),
      qh, k_pages, v_pages, pos_pages, ksh, vsh,
      positions.astype(jnp.int32))
    return out.reshape(B, H, n, Dv).transpose(0, 2, 1, 3)
