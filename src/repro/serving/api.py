"""First-class request API for the serving stack.

This module is the stable surface a front end programs against —
the LMDeploy-style request/response types of the paper's serving side
(§4.2), decoupled from both the engine and the scheduler so neither
has to be imported to *describe* work:

``SamplingParams``
    Every decode-time knob a single request may set — denoise threshold
    ``tau``, ``temperature``, reveal policy ``mode`` (dynamic vs
    static), static-mode step budget ``n_steps``, response length cap
    ``max_new_blocks``, stop token ``eos_id`` and an optional
    deterministic ``seed``.  The whole point of the type is that these
    are **per-request, per-row traced values** all the way down: the
    pool's jitted block-advance reads them out of per-sequence vectors
    in ``core.decoding.GenState``, so one ``SlotScheduler`` pool serves
    arbitrarily mixed configurations with zero retraces — changing τ is
    a field on a request, not an engine rebuild.  (DiFFPO makes the
    per-request threshold an RL lever; d1 sweeps decode budgets per
    task — both are plain ``SamplingParams`` traffic here.)

    Only ``s_max`` — the global denoise-loop bound — stays a pool
    static: it is the one value that fixes compiled loop *structure*
    rather than data.  Per-request ``n_steps`` above the pool's
    ``s_max`` is effectively clamped (the loop flushes all remaining
    masks at step ``s_max - 1``).

``Request`` / ``RequestOutput``
    The queue entry (prompt + rng + params) and the structured
    completion a streaming front end consumes: uid, decoded text,
    ``finish_reason`` ("eos" | "length") and admit→finish latency in
    scheduler ticks.

``GenerationConfig``
    Pool/engine construction config (slot count, cache layout, KV
    budget, ``s_max``) plus the *default* ``SamplingParams`` applied to
    requests that do not carry their own.  Kept flat for backwards
    compatibility; ``.sampling()`` derives the default params object.

Prefix-cache interaction: ``SamplingParams`` only shapes *decoding* —
prompt prefill (and therefore committed prompt KV) is parameter-free,
so requests with different params share prompt pages freely and a
params change can never invalidate a cached prefix.  The scheduler's
admission path relies on this (and tests/test_sampling_params.py pins
it): prefix keys are content hashes of prompt blocks only.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode parameters (all traced per row, never static).

    tau             dynamic mode: reveal positions whose top-1 prob
                    exceeds this threshold (at least one per step)
    temperature     0 = greedy argmax, > 0 = categorical sampling
    mode            "dynamic" (confidence threshold) | "static" (fixed
                    reveal count per step)
    n_steps         static mode: denoise steps per block (reveals
                    ceil(block_size / n_steps) positions per step)
    max_new_blocks  response budget in blocks (None = cache capacity)
    eos_id          stop token; -1 disables EOS stopping entirely
    seed            fallback rng source: used only when no explicit key
                    accompanies the request (an explicit key always
                    wins, preserving batch drivers' per-row streams)
    """
    tau: float = 0.9
    temperature: float = 0.0
    mode: str = "dynamic"
    n_steps: int = 8
    max_new_blocks: int | None = None
    eos_id: int = 1
    seed: int | None = None

    def __post_init__(self):
        if self.mode not in ("dynamic", "static"):
            raise ValueError(
                f"mode must be dynamic|static, got {self.mode!r}")
        if self.n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {self.n_steps}")
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.max_new_blocks is not None and self.max_new_blocks < 0:
            raise ValueError(
                f"max_new_blocks must be >= 0, got {self.max_new_blocks}")

    @property
    def dynamic(self) -> bool:
        return self.mode == "dynamic"

    def replace(self, **kw) -> "SamplingParams":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class Request:
    """One queued generation request (prompt already tokenised and
    trimmed to ``prompt_blocks`` block-aligned blocks)."""
    uid: int
    prompt: np.ndarray           # (Lp,) int32, Lp = prompt_blocks * bsz
    prompt_blocks: int           # true prompt length in blocks
    rng: "object"                # (2,) per-request rng key
    params: SamplingParams = SamplingParams()


@dataclasses.dataclass
class RequestOutput:
    """Structured streaming completion (what ``RolloutEngine.stream``
    yields): the decoded text plus everything a front end needs to
    report — why the request stopped and how long it decoded.

    ``latency_ticks`` spans admission → completion (the decode time in
    pool block-steps); queue wait before admission — e.g. page-pool
    backpressure deferrals — is *not* included (``admitted_tick`` is
    stamped when the request enters a slot, not when it was submitted).

    ``param_version`` is the model-weight version (``ModelServer.
    version``) live when the request was admitted.  Under the async RL
    loop weights are pushed between pool ticks, so a long response may
    finish on newer weights than it started on; the admission version is
    the request's staleness tag (the per-block record rides on the raw
    ``Completion``).
    """
    uid: int
    text: str                    # decoded, trimmed at the first EOS
    token_ids: np.ndarray        # generated ids, trimmed at first EOS
    finish_reason: str           # "eos" | "length"
    prompt_blocks: int
    gen_blocks: int
    gen_tokens: int              # generated tokens to first EOS incl.
    denoise_steps: int           # denoise steps actually executed
    admitted_tick: int           # scheduler tick the request entered
    completed_tick: int          # scheduler tick it finished
    params: SamplingParams = SamplingParams()
    param_version: int = 0       # weight version live at admission

    @property
    def latency_ticks(self) -> int:
        """Admit -> finish latency in scheduler ticks (block steps)."""
        return self.completed_tick - self.admitted_tick


@dataclasses.dataclass
class GenerationConfig:
    """Pool/engine construction config + default ``SamplingParams``.

    The decode fields (``mode``/``tau``/``n_steps``/``temperature``/
    ``eos_id``) are only *defaults* — any request may override them via
    its own ``SamplingParams`` without retracing the pool.
    """
    max_len: int = 256
    s_max: int = 8               # max denoise steps per block (static:
    # the one compiled loop bound — per-request n_steps clamps to it)
    mode: str = "dynamic"        # default: dynamic | static
    tau: float = 0.9
    n_steps: int = 8             # default static denoise steps per block
    temperature: float = 0.0
    eos_id: int = 1
    batching: str = "continuous"  # continuous (slot pool) | static
    n_slots: int = 8             # continuous: decode-slot pool size
    cache: str = "dense"         # continuous: dense | paged KV layout
    n_pages: int | None = None   # paged: pool size (None = dense-equal)
    prefix_cache: bool | None = None  # paged: share prompt pages across
    # requests (None = auto: on for pure-attention backbones)
    kernel: str = "ref"          # paged decode KV layout: "ref" gathers
    # pages into a dense-width copy per step, "pallas" reads the page
    # pool in place (kernels.paged_attn; interpret-mode off-TPU)
    sync_each_tick: bool = False  # block on device results inside the
    # generate call for honest per-call latency stats; off by default —
    # the sync serializes dispatch (dirlint: hot-sync)
    trace: bool = False          # record obs.trace lifecycle/tick spans
    # (host wall-clock around dispatch; never syncs the device)
    trace_capacity: int = 65536  # span ring-buffer size (oldest evicted)

    def sampling(self, **overrides) -> SamplingParams:
        """The default per-request params this config implies."""
        base = SamplingParams(tau=self.tau, temperature=self.temperature,
                              mode=self.mode, n_steps=self.n_steps,
                              eos_id=self.eos_id)
        return base.replace(**overrides) if overrides else base
