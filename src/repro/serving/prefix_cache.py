"""Shared-prefix KV index: refcounted page sharing for group rollouts.

The third layer of the serving cache stack (slots -> pages -> *shared*
pages).  DiPO's online loop rolls out ``group_size`` G trajectories per
prompt, so a paged pool without sharing prefills the identical prompt G
times and holds G copies of the same KV pages.  This module is the
vLLM/SGLang-style fix: a block-granular radix index over *committed
prompt blocks*, mapping block content to the page that already holds its
keys, with per-page reference counts layered onto the scheduler's
free-list allocator.

Key structure
-------------
A prompt is identified block-by-block with a *chained* content hash:
``key[b] = H(key[b-1] ++ tokens of block b)``.  A key therefore commits
to the entire absolute prefix ``blocks [0, b]`` — equal keys imply equal
tokens at equal positions, which is exactly the condition under which
one KV page can serve many sequences (pages store rotated keys with
absolute position ids).  The chain makes the flat ``dict`` a radix trie:
looking up a prompt walks its chain keys in order and stops at the first
absent entry, yielding the longest cached prefix.

Lifecycle
---------
* **register** — at admission, each freshly prefilled *prompt* block is
  inserted with ``refs=1``.  Generated blocks are never registered:
  shared pages are read-only prompt blocks by construction (a live
  slot's commit cursor never re-enters its prompt region), so no
  copy-on-write machinery is needed.
* **acquire** — a later request whose prefix matches bumps the refcount
  of every hit entry and maps the hit pages straight into its block
  table; only the suffix is prefilled.
* **release** — slot eviction decrements.  At ``refs == 0`` the entry
  stays *cached* (the page keeps its contents and is not returned to
  the free list) so future groups can still hit it.
* **evict_lru** — under page pressure the allocator reclaims idle
  (``refs == 0``) entries leaf-first in LRU order.  Entries with live
  references are never evicted, so reservation-based admission keeps
  its no-deadlock guarantee: every page is either free, reclaimable, or
  covered by a live slot's reservation/refcount.

Leaf-first eviction keeps the trie sound: an interior entry is only
reclaimed once no longer-prefix entry depends on it, so a lookup can
never match a chain with a hole.  Idle subtrees always contain an idle
leaf (a live reference on a descendant implies live references on every
ancestor, because hits are taken as contiguous chains from the root),
so the number of reclaimable pages always equals the number of idle
entries.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


def chain_keys(prompt: np.ndarray, block_size: int) -> list[bytes]:
    """Chained per-block content keys for a block-aligned prompt.

    ``key[b]`` hashes the previous key plus block ``b``'s tokens, so it
    commits to the whole prefix ``[0, b]`` *at its absolute positions* —
    the invariant that makes a KV page (rotated keys + position ids)
    reusable verbatim by any prompt sharing that prefix.
    """
    arr = np.ascontiguousarray(np.asarray(prompt, np.int32))
    assert arr.ndim == 1 and arr.shape[0] % block_size == 0
    keys: list[bytes] = []
    prev = b""
    for b in range(arr.shape[0] // block_size):
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(arr[b * block_size:(b + 1) * block_size].tobytes())
        prev = h.digest()
        keys.append(prev)
    return keys


@dataclasses.dataclass
class Entry:
    """One cached prompt block: its chain key, the page holding its KV,
    the number of live slots referencing it, and trie/LRU bookkeeping."""
    key: bytes
    parent: bytes | None
    page: int
    refs: int = 0
    children: int = 0
    stamp: int = 0


class PrefixIndex:
    """Radix index of committed prompt blocks -> page ids.

    Pure host-side bookkeeping: pages themselves live in the scheduler's
    ``PagedAttnCache`` pool; this class only decides which page ids are
    shared, which are idle-but-cached, and which may be reclaimed.
    """

    def __init__(self) -> None:
        self._entries: dict[bytes, Entry] = {}
        self._clock = 0
        self.n_active = 0        # entries with refs >= 1
        self.n_shared = 0        # entries with refs >= 2

    # ------------------------------------------------------------ views
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: bytes) -> bool:
        return key in self._entries

    @property
    def n_idle(self) -> int:
        """Cached entries with no live reference (reclaimable)."""
        return len(self._entries) - self.n_active

    def entry(self, key: bytes) -> Entry:
        return self._entries[key]

    # ---------------------------------------------------------- lookup
    def match(self, keys: list[bytes]) -> list[Entry]:
        """Longest cached prefix: entries for ``keys[:h]``, h maximal."""
        out: list[Entry] = []
        for k in keys:
            e = self._entries.get(k)
            if e is None:
                break
            out.append(e)
        return out

    # -------------------------------------------------------- refcounts
    def acquire(self, entries: list[Entry]) -> None:
        """Take one live reference on each hit entry (and touch LRU).

        Must be called *before* any page allocation for the same
        admission: an un-acquired hit with ``refs == 0`` is reclaimable
        and could be evicted out from under the request.
        """
        self._clock += 1
        for e in entries:
            if e.refs == 0:
                self.n_active += 1
            elif e.refs == 1:
                self.n_shared += 1
            e.refs += 1
            e.stamp = self._clock

    def register(self, keys: list[bytes], start: int,
                 pages: list[int]) -> list[bytes]:
        """Insert freshly prefilled prompt blocks ``keys[start:]``.

        ``pages[i]`` holds block ``start + i``'s committed KV.  New
        entries are born with ``refs = 1`` (the admitting slot).  The
        parent of ``keys[start]`` must already be present — i.e.
        ``start`` is the match length returned by :meth:`match` for the
        same admission.  Returns the keys the slot now holds references
        on (caller passes hit keys + these to :meth:`release` later).
        """
        assert len(pages) == len(keys) - start
        self._clock += 1
        parent = keys[start - 1] if start > 0 else None
        new: list[bytes] = []
        for k, page in zip(keys[start:], pages):
            assert k not in self._entries, "duplicate prefix registration"
            self._entries[k] = Entry(key=k, parent=parent, page=int(page),
                                     refs=1, stamp=self._clock)
            self.n_active += 1
            if parent is not None:
                self._entries[parent].children += 1
            parent = k
            new.append(k)
        return new

    def release(self, keys: list[bytes]) -> None:
        """Drop one live reference per key (slot eviction).

        Entries reaching ``refs == 0`` stay cached — their pages are
        reclaimed lazily by :meth:`evict_lru` under page pressure.
        """
        for k in keys:
            e = self._entries[k]
            assert e.refs > 0, "refcount underflow"
            e.refs -= 1
            if e.refs == 0:
                self.n_active -= 1
            elif e.refs == 1:
                self.n_shared -= 1

    # ---------------------------------------------------------- reclaim
    def evict_lru(self) -> int | None:
        """Reclaim the LRU idle *leaf* entry; returns its page id.

        Never touches an entry with live references, and never leaves a
        dangling child (leaf-first), so the index stays a sound trie.
        Returns None when nothing is reclaimable.  Linear scan per
        reclaim — reclaims happen only under page pressure and the index
        is bounded by the page pool; an idle-leaf heap would make this
        O(log n) if pools grow by orders of magnitude.
        """
        best: Entry | None = None
        for e in self._entries.values():
            if e.refs == 0 and e.children == 0 and \
                    (best is None or e.stamp < best.stamp):
                best = e
        if best is None:
            return None
        del self._entries[best.key]
        if best.parent is not None and best.parent in self._entries:
            self._entries[best.parent].children -= 1
        return best.page
