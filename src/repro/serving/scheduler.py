"""Slot-based continuous-batching scheduler for blockwise-dLLM decoding.

Architecture
------------
The scheduler owns a fixed pool of ``n_slots`` decode slots backed by one
batched ``core.decoding.GenState`` (tokens / step maps / per-slot block
cursors / per-slot rng keys / decode caches).  Time advances in *ticks*:
one tick = one call of the jitted ``core.decoding.advance_block`` over
the whole pool, i.e. every live slot denoises and commits exactly one
block.  Between ticks — block boundaries, the only points where a
blockwise dLLM can change batch composition without corrupting caches —
the scheduler runs its Python-side control loop:

  admit    queued requests are prefetched into freed slots: a B=1
           ``prefill`` builds the request's cache rows, which are then
           scattered into the pool for that slot together with its
           prompt tokens, rng key, cursor and block budget;
  advance  one jitted pool step (inactive slots are ``done`` and merely
           re-commit their frozen block — idempotent by construction);
  evict    slots whose sequence hit EOS or its block budget are
           harvested into ``Completion`` records and returned to the
           free list.

Cache layouts (``cache=``)
--------------------------
``"dense"``  every slot owns a contiguous ``max_len`` cache region; slot
             count is therefore capped by worst-case length, and a short
             request reserves as much KV memory as the longest one.

``"paged"``  the vLLM-style fix: attention KV lives in one shared pool
             of ``n_pages`` block-sized pages (``models.attention.
             PagedAttnCache``; one page = one ``block_size`` block,
             matching the blockwise commit granularity), addressed
             through a per-slot block table carried in
             ``GenState.table``.  Recurrent/conv states are O(1) per
             sequence and stay per-slot.  Page lifecycle:

               * admission  — one page per true prompt block, filled by
                 scattering the B=1 prefill row block-by-block;
               * advance    — one page per live slot for the block its
                 cursor is about to commit;
               * eviction   — all of a slot's pages return to the free
                 list and its table row is reset to -1, so the slot's
                 subsequent idempotent re-commits dump into the null
                 page (page 0, never allocated) instead of a page that
                 may already belong to another request.

             Admission reserves a request's worst case (``prompt_blocks
             + budget`` pages) up front, so mid-flight allocation can
             never fail and there is no preemption; when the head of the
             queue does not fit, admission *defers* (backpressure,
             counted in ``stats.deferred``) until evictions free pages —
             it never crashes.  Short-budget requests therefore stop
             reserving long-request memory, and slot count decouples
             from ``max_len``.

             How decode *reads* the pool is the orthogonal
             ``kernel=`` knob (the KV layout,
             ``models.attention.resolve_kv_layout``):

               * ``"ref"``    — ``paged_gather`` materializes a
                 dense-width K/V copy per layer per tick (portable
                 fallback / parity oracle);
               * ``"pallas"`` — the page-aware kernels
                 (``kernels.paged_attn``) read pages in place via the
                 scalar-prefetched block table — decode *and* the
                 shared-prefix suffix prefill — so per-step transient
                 KV drops to zero (``stats.transient_kv_bytes``), the
                 admission-time prefix gather disappears
                 (``stats.admit_transient_kv_bytes``) and decode
                 memory stops scaling with slots x K*bsz.  Off-TPU
                 they run under ``interpret=True`` — CI exercises the
                 real kernel path; ``kernel_plan`` records the
                 compiled/interpret choice and why.

             Both layouts are byte-identical in decode tokens to dense
             (tests/test_paged_attn.py), and the kernel choice is a
             pool static like ``s_max`` — it never retraces per
             request.

Shared-prefix layer (``prefix_cache=``, paged only)
---------------------------------------------------
The third cache layer (slots -> pages -> *shared* pages): a refcounted
radix index over committed prompt blocks (``serving.prefix_cache``)
built for DiPO's G-rollouts-per-prompt groups, where every group member
would otherwise prefill and store the identical prompt G times.

  * admission — the index is probed for the longest cached prefix; hit
    blocks map the *existing* pages into the new slot's table
    (refcount++) and only the suffix is prefilled
    (``core.decoding.prefill_suffix`` — byte-identical to the same
    blocks of a full prefill; a full hit skips the model entirely).
    Freshly prefilled prompt blocks are registered into the index.
  * eviction — a slot releases its prompt-page references; a page
    returns to the free list only when *exclusive* (generated blocks,
    refcount-0 reclaims).  Refcount-0 index entries stay cached for
    future groups and are reclaimed leaf-first in LRU order under page
    pressure, so reservation-based admission keeps its no-deadlock
    guarantee: admission checks ``reserved + live-referenced index
    pages`` against the pool, and every other page is free or
    reclaimable.
  * generated blocks stay private — shared pages are read-only prompt
    blocks by construction (the commit cursor never re-enters the
    prompt region), so there is no copy-on-write.

Requires a pure-attention backbone (recurrent layers carry per-slot
state that pages cannot share); ``prefix_cache=None`` auto-enables
exactly then.  Byte-for-byte token parity between prefix-cache on/off
additionally assumes the cache dtype equals the activation dtype (the
fp32 default) — see ``core.decoding.prefill_suffix``.

Per-request sampling (``serving.api.SamplingParams``)
-----------------------------------------------------
Every decode parameter — tau, temperature, dynamic/static mode, static
n_steps, block budget, stop token, seed — is **request-granular**:
``submit(..., params=SamplingParams(...))`` scatters the request's
values into per-row vectors on the pooled ``GenState`` at admission,
and the jitted ``advance_block`` reads them per row.  One compiled
step therefore serves arbitrarily mixed configurations with zero
retraces (``n_advance_traces`` counts compilations — it stays at 1
after warmup no matter what parameter mix arrives); the pool-level
``s_max`` is the single remaining static.  Mixed-batch outputs are
byte-identical per row to homogeneous runs (tests/
test_sampling_params.py).

Sampling parameters never touch the prefix cache: prompt prefill is
parameter-free, so the radix index keys on prompt *content* only and
requests with different τ/temperature/budgets share prompt pages
freely — a params change can never invalidate cached prompt KV.

Request lifecycle: ``submit() -> queued -> admitted (slot) -> decoding
-> completed`` — completions stream out of ``step()``/``run()`` in
finish order, not arrival order.

DiPO-exactness: every row of ``advance_block`` evolves independently
(per-row caches or per-row block-table entries, per-row rng streams), so
a request's tokens and step map depend only on its own prompt + rng key
— *not* on which other requests happen to share the pool, nor on the
cache layout: paged and dense produce byte-identical tokens and step
maps (tested in tests/test_scheduler.py), so RL rollouts harvested from
the scheduler remain exactly consumable by the DiPO trajectory replay.

Follow-ups tracked in ROADMAP.md: multi-host page pools, batched
same-width admission, and optimistic admission + preemption.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import TraceGuard
from repro.core import decoding
from repro.core.masks import plain_layout
from repro.kernels.ops import layout_tile_stats
from repro.models import attention
from repro.obs import profile
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving.api import GenerationConfig, Request, SamplingParams
from repro.serving.prefix_cache import PrefixIndex, chain_keys

# distinguishes "caller did not pass max_new_blocks" from an explicit
# None (= decode to cache capacity) in submit()
_UNSET = object()


@dataclasses.dataclass
class Completion:
    """A finished request, harvested at eviction time."""
    uid: int
    tokens: np.ndarray           # (max_len,) prompt ++ generation ++ MASK
    steps: np.ndarray            # (max_len,) per-token reveal-step map
    prompt_blocks: int
    gen_blocks: int
    gen_tokens: int              # generated tokens up to first EOS incl.
    denoise_steps: int           # actual denoise steps executed (dynamic)
    finish_reason: str           # "eos" | "length" (hit block budget)
    admitted_tick: int
    completed_tick: int
    params: SamplingParams = SamplingParams()
    # model-weight version (ModelServer.version) live when the request
    # entered its slot — the staleness tag async RL consumes
    param_version: int = 0
    # per-generated-block weight version (len == gen_blocks): a weight
    # push lands between ticks, so an in-flight request finishes its
    # current block on the old params and picks the new ones up at the
    # next advance — this is the per-block record of that handoff
    block_versions: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int64))

    @property
    def finished_eos(self) -> bool:
        return self.finish_reason == "eos"

    @property
    def latency_ticks(self) -> int:
        """Admit -> finish latency in scheduler ticks."""
        return self.completed_tick - self.admitted_tick


@dataclasses.dataclass
class SchedulerStats:
    """Honest utilization counters (the fig6/serve_bench substrate).

    Every field doubles as the bound storage of an instrument in
    ``self.registry`` (an ``obs.metrics.MetricsRegistry`` under the
    ``dirl_scheduler`` namespace): the hot paths keep mutating plain
    attributes (``stats.ticks += 1`` — one attribute write, no
    instrument dispatch) while exporters read the same values through
    ``registry.collect()``.  A fresh stats object — the established
    warmup reset pattern ``sched.stats = SchedulerStats()`` — therefore
    also resets the exported view, counters included (the
    process-restart analogue that monotonic semantics permit).
    """
    ticks: int = 0               # pool advance steps executed
    slot_ticks: int = 0          # ticks * n_slots (paid compute)
    active_slot_ticks: int = 0   # slot-ticks that advanced a live request
    admitted: int = 0
    completed: int = 0
    gen_tokens: int = 0          # tokens served, cut at first EOS incl.
    denoise_steps: int = 0       # actual denoise steps across requests
    peak_active: int = 0         # max concurrently live slots
    prefill_blocks: int = 0      # prompt blocks actually prefilled
    # per-tick cache-KV bytes the decode layout copies out of the
    # resident cache (max over layers: dense concat / paged gather);
    # 0 on the in-place kernel="pallas" path — static per pool config
    transient_kv_bytes: int = 0
    # peak admission-time cache-KV bytes one suffix prefill gathered
    # out of the pool (the hit-prefix width, max over layers and over
    # admissions so far); 0 on the in-place prefill kernel path
    admit_transient_kv_bytes: int = 0
    # execution mode of the paged Pallas kernels for this pool shape:
    # "compiled" | "interpret" (kernel="pallas") or "" (no kernel)
    kernel_mode: str = ""
    # compilations of the jitted pool advance (TraceGuard counter) —
    # the zero-retrace contract: 1 across any SamplingParams mix
    advance_traces: int = 0
    # paged cache only
    deferred: int = 0            # admissions deferred for lack of pages
    page_allocs: int = 0
    page_frees: int = 0
    peak_pages_in_use: int = 0   # physical peak (incl. idle cached pages)
    peak_pages_live: int = 0     # peak pages referenced by live slots
    # prefix cache only
    prefix_hit_blocks: int = 0   # prompt blocks served from shared pages
    prefix_miss_blocks: int = 0  # prompt blocks that paid a prefill
    shared_pages: int = 0        # peak pages referenced by >= 2 slots
    prefix_evictions: int = 0    # refcount-0 index entries LRU-reclaimed
    # tile-map visit fraction of the most recent admission's prefill
    # attention (block-causal mask at block granularity) — the sparsity
    # the tile-sparse kernel family skips on the serve side
    prefill_tile_visit_fraction: float = 0.0

    # monotonic fields -> Counter; level/peak fields -> Gauge
    _COUNTER_FIELDS = ("ticks", "slot_ticks", "active_slot_ticks",
                       "admitted", "completed", "gen_tokens",
                       "denoise_steps", "prefill_blocks", "deferred",
                       "page_allocs", "page_frees", "prefix_hit_blocks",
                       "prefix_miss_blocks", "prefix_evictions")
    _GAUGE_FIELDS = ("peak_active", "transient_kv_bytes",
                     "admit_transient_kv_bytes", "advance_traces",
                     "peak_pages_in_use", "peak_pages_live",
                     "shared_pages", "prefill_tile_visit_fraction")

    def __post_init__(self):
        # non-field attribute: stays out of dataclasses.fields() and
        # out of __eq__/__repr__, so stats comparisons are value-only
        self.registry = MetricsRegistry("dirl_scheduler")
        for f in self._COUNTER_FIELDS:
            self.registry.counter(f, bind=(self, f))
        for f in self._GAUGE_FIELDS:
            self.registry.gauge(f, bind=(self, f))
        self.registry.info("kernel_mode",
                           "paged-kernel execution mode for this pool",
                           bind=(self, "kernel_mode"))

    @property
    def utilization(self) -> float:
        """Fraction of paid slot-ticks that did useful work."""
        return self.active_slot_ticks / max(self.slot_ticks, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt blocks served from shared pages."""
        total = self.prefix_hit_blocks + self.prefix_miss_blocks
        return self.prefix_hit_blocks / max(total, 1)


class SlotScheduler:
    """Fixed-slot continuous batcher over one jitted block-advance.

    Construction takes one ``GenerationConfig`` (pool shape + cache
    layout + the *default* ``SamplingParams`` for requests that carry
    none) — keyword overrides patch individual fields, so legacy
    ``SlotScheduler(model, n_slots=..., tau=...)`` call sites keep
    working without mirroring every config field through the signature.
    """

    def __init__(self, model, gen_cfg: GenerationConfig | None = None,
                 tracer: Tracer | None = None, **overrides):
        if gen_cfg is None:
            gen_cfg = GenerationConfig()
        if overrides:
            gen_cfg = dataclasses.replace(gen_cfg, **overrides)
        # one tracer per stack: the engine passes its own so scheduler
        # ticks and request lifecycles land in the same export; a
        # standalone scheduler builds one from the config (disabled by
        # default — a disabled tracer records nothing but still times)
        self.tracer = tracer if tracer is not None else Tracer(
            capacity=gen_cfg.trace_capacity, enabled=gen_cfg.trace)
        cfg = model.cfg
        n_slots, max_len = gen_cfg.n_slots, gen_cfg.max_len
        cache = gen_cfg.cache
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if cache not in ("dense", "paged"):
            raise ValueError(f"cache must be dense|paged, got {cache!r}")
        kernel = gen_cfg.kernel
        if kernel not in ("ref", "pallas"):
            raise ValueError(f"kernel must be ref|pallas, got {kernel!r}")
        if kernel == "pallas" and cache != "paged":
            raise ValueError(
                "kernel='pallas' requires cache='paged' — dense rows "
                "have no page pool to read in place")
        assert max_len % cfg.block_size == 0
        self.model = model
        self.gen_cfg = gen_cfg
        self.default_params = gen_cfg.sampling()
        self.n_slots = n_slots
        self.max_len = max_len
        self.n_blocks_total = max_len // cfg.block_size
        self.eos_id = gen_cfg.eos_id        # default stop token
        self.cache = cache
        self.kernel = kernel
        self.stats = SchedulerStats()
        n_pages = gen_cfg.n_pages
        prefix_cache = gen_cfg.prefix_cache

        self.prefix: PrefixIndex | None = None
        if cache == "paged":
            # default: the same KV footprint a dense pool would reserve,
            # plus the never-allocated null page 0
            self.n_pages = n_pages if n_pages is not None \
                else n_slots * self.n_blocks_total + 1
            if self.n_pages < 2:
                raise ValueError("paged cache needs >= 2 pages")
            self._free_pages = list(range(self.n_pages - 1, 0, -1))
            self._table_host = np.full(
                (n_slots, self.n_blocks_total), -1, np.int64)
            self._pages_reserved = 0          # worst case of live slots
            self._slot_resv = [0] * n_slots   # per-slot reserved pages
            self._slot_limit = [0] * n_slots  # per-slot block-cursor cap
            self._slot_blk = [0] * n_slots    # host mirror of state.blk
            # shared-prefix index: auto-on for pure-attention stacks
            # (recurrent layers carry per-slot state pages cannot share)
            if prefix_cache is None:
                prefix_cache = not cfg.ssm_kind
            if prefix_cache:
                if cfg.ssm_kind:
                    raise ValueError(
                        "prefix_cache requires a pure-attention backbone "
                        f"(got ssm_kind={cfg.ssm_kind!r}: recurrent "
                        "boundary states are per-slot, not per-page)")
                self.prefix = PrefixIndex()
            self._slot_nodes: list[list[bytes]] = \
                [[] for _ in range(n_slots)]
        else:
            if prefix_cache:
                raise ValueError("prefix_cache requires cache='paged'")
            self.n_pages = 0

        self._queue: deque[Request] = deque()
        self._admit_info: dict = {}   # labels of the latest admission
        self._slot_req: list[Request | None] = [None] * n_slots
        self._slot_admit_tick: list[int] = [0] * n_slots
        # model-weight versioning (async RL provenance): the version
        # passed to step() is stamped per slot at admission and appended
        # per advance, so a harvest can reconstruct exactly which
        # weights produced each generated block.  One int per pool
        # advance (one model forward), indexed by an absolute counter so
        # the `sched.stats = SchedulerStats()` warmup reset cannot skew
        # it — negligible memory even for very long-lived pools.
        self._slot_admit_version: list[int] = [0] * n_slots
        self._slot_admit_abs: list[int] = [0] * n_slots
        self._tick_versions: list[int] = []
        self._next_uid = 0
        self._state = self._init_pool()
        # pool-static (cache layout + kernel choice fix it at
        # construction); re-stamped into stats every tick so the common
        # warmup pattern `sched.stats = SchedulerStats()` self-heals
        self.transient_kv_bytes = self._transient_kv_bytes()
        self.stats.transient_kv_bytes = self.transient_kv_bytes
        # how the paged Pallas kernels would execute on this pool's
        # page shape (None when kernel="ref" / dense cache)
        self.kernel_plan = self._kernel_plan()
        self.stats.kernel_mode = \
            self.kernel_plan.mode if self.kernel_plan else ""

        # donate the pool state: the old GenState (slot caches included)
        # is always dead after the call, so advance/admit alias their
        # buffers in place instead of holding a 2x-peak copy per tick
        # (backends without donation support just ignore the hint).
        # All sampling parameters live in GenState's per-row vectors;
        # s_max is the single static, so one trace serves every request
        # mix — each TraceGuard counts compilations to prove it (the
        # wrapped body only runs when jax traces it).
        s_max = gen_cfg.s_max

        def _advance_impl(params, st):
            return decoding.advance_block(model, params, st, s_max=s_max,
                                          kv_kernel=self.kernel)

        self._advance = TraceGuard(_advance_impl, donate_argnums=(1,),
                                   name="advance")
        self._admit_jit = TraceGuard(self._admit_impl, donate_argnums=(1,),
                                     name="admit")
        self._admit_hit_jit = TraceGuard(self._admit_hit_impl,
                                         donate_argnums=(0,),
                                         name="admit_hit")
        self._admit_suffix_jit = TraceGuard(self._admit_suffix_impl,
                                            donate_argnums=(1,),
                                            name="admit_suffix")

    @property
    def n_advance_traces(self) -> int:
        """Compilations of the pool advance so far (the zero-retrace
        witness: stays 1 across arbitrary SamplingParams mixes)."""
        return self._advance.n_traces

    def guard_stats(self) -> dict[str, int]:
        """Compile counts per jitted entry point."""
        return {g.name: g.n_traces
                for g in (self._advance, self._admit_jit,
                          self._admit_hit_jit, self._admit_suffix_jit)}

    # ----------------------------------------------------------- state
    def _transient_kv_bytes(self) -> int:
        """Peak per-tick cache-KV copy the decode layout materializes
        (max over attention layers — layers run sequentially under the
        scan, so one layer's gather is live at a time).  0 for the
        in-place ``kernel="pallas"`` path."""
        caches = self._state.caches
        out = 0
        for c in (list(caches["prefix"].values())
                  + list(caches["groups"].values())):
            if isinstance(c, (attention.AttnCache,
                              attention.PagedAttnCache)):
                out = max(out, attention.transient_kv_bytes(
                    c, self.n_slots, self.n_blocks_total, self.kernel))
        return out

    def _attn_caches(self):
        caches = self._state.caches
        return [c for c in (list(caches["prefix"].values())
                            + list(caches["groups"].values()))
                if isinstance(c, (attention.AttnCache,
                                  attention.PagedAttnCache))]

    def _kernel_plan(self):
        """``kernels.paged_attn.KernelPlan`` for this pool's page shape,
        or None when no Pallas kernel is ever launched."""
        for c in self._attn_caches():
            plan = attention.kernel_exec_plan(c, self.kernel)
            if plan is not None:
                return plan
        return None

    def _admit_transient_kv_bytes(self, n_ctx_blocks: int) -> int:
        """Cache-KV bytes one B=1 suffix prefill copies out of the pool
        (the shared-prefix gather width, max over attention layers —
        layers run sequentially, so one gather is live at a time).
        0 for the in-place ``kernel="pallas"`` prefill kernel."""
        out = 0
        for c in self._attn_caches():
            if isinstance(c, attention.PagedAttnCache):
                out = max(out, attention.prefill_transient_kv_bytes(
                    c, 1, n_ctx_blocks, self.kernel))
        return out

    @property
    def n_usable_pages(self) -> int:
        """Allocatable pages (excludes the null page)."""
        return max(self.n_pages - 1, 0)

    @property
    def pages_in_use(self) -> int:
        """Pages off the free list (live-referenced + idle cached)."""
        return self.n_usable_pages - len(self._free_pages) \
            if self.cache == "paged" else 0

    @property
    def pages_live(self) -> int:
        """Pages referenced by live slots (excludes idle cached pages).

        This is the memory a pool *without* prefix retention would need
        at the same instant — the apples-to-apples peak for the
        prefix-cache on/off benchmark.
        """
        idle = self.prefix.n_idle if self.prefix is not None else 0
        return self.pages_in_use - idle

    def _init_pool(self) -> decoding.GenState:
        cfg = self.model.cfg
        S, L = self.n_slots, self.max_len
        MASK = cfg.resolved_mask_token
        if self.cache == "paged":
            caches = self.model.make_paged_caches(S, self.n_pages)
            table = jnp.full((S, self.n_blocks_total), -1, jnp.int32)
        else:
            caches = self.model.make_caches(S, L)
            table = None
        return decoding.GenState(
            tokens=jnp.full((S, L), MASK, jnp.int32),
            steps=jnp.zeros((S, L), jnp.int32),
            caches=caches,
            blk=jnp.zeros((S,), jnp.int32),
            done=jnp.ones((S,), bool),        # all slots start free
            rng=jnp.zeros((S, 2), jnp.uint32),
            limit=jnp.zeros((S,), jnp.int32),
            n_denoise=jnp.zeros((S,), jnp.int32),
            # free slots carry inert sampling rows (eos -1 = disabled);
            # admission overwrites them with the request's params
            **decoding.sampling_vectors(S, tau=0.0, temperature=0.0,
                                        n_steps=1, mode="static",
                                        eos_id=-1),
            table=table)

    @staticmethod
    def _scatter_layer(pool, new, slot, pages, *, grouped: bool):
        """Scatter one layer of a B=1 prefill into the pool.

        Paged attention layers scatter block-by-block into the request's
        freshly allocated pages; per-slot states (SSM/conv/shift) scatter
        into the slot's row as in the dense layout.
        """
        if pool is None:
            return None
        if isinstance(pool, attention.PagedAttnCache):
            fn = attention.write_prompt_pages_grouped if grouped \
                else attention.write_prompt_pages
            return fn(pool, new, pages)
        if grouped:  # group leaves carry a leading (G,) axis
            return jax.tree.map(lambda p, n: p.at[:, slot].set(n[:, 0]),
                                pool, new)
        return jax.tree.map(lambda p, n: p.at[slot].set(n[0]), pool, new)

    @staticmethod
    def _samp_scalars(p: SamplingParams) -> tuple:
        """A request's sampling fields as traced jit scalars — different
        values reuse the compiled admit executables, never retrace."""
        return (jnp.float32(p.tau), jnp.float32(p.temperature),
                jnp.int32(p.n_steps), jnp.bool_(p.dynamic),
                jnp.int32(p.eos_id))

    @staticmethod
    def _scatter_slot(st: decoding.GenState, slot, row, key, limit, blk,
                      caches, table, samp) -> decoding.GenState:
        """Write one admitted request's per-slot state into the pool.

        Every admission path (cold prefill, full prefix hit, suffix
        prefill) funnels through this single GenState constructor, so a
        new per-sequence field only needs threading once.  ``samp`` is
        the ``_samp_scalars`` tuple — the request's SamplingParams
        landing in the pool's per-row vectors.
        """
        tau, temp, n_steps, dynamic, eos = samp
        return decoding.GenState(
            tokens=st.tokens.at[slot].set(row),
            steps=st.steps.at[slot].set(0),
            caches=caches,
            blk=st.blk.at[slot].set(blk),
            done=st.done.at[slot].set(False),
            rng=st.rng.at[slot].set(key),
            limit=st.limit.at[slot].set(limit),
            n_denoise=st.n_denoise.at[slot].set(0),
            tau=st.tau.at[slot].set(tau),
            temperature=st.temperature.at[slot].set(temp),
            n_steps=st.n_steps.at[slot].set(n_steps),
            dynamic=st.dynamic.at[slot].set(dynamic),
            eos=st.eos.at[slot].set(eos),
            table=table)

    def _admit_impl(self, params, st: decoding.GenState, slot,
                    prompt, pblocks, key, limit, samp,
                    pages=None) -> decoding.GenState:
        """Prefill one request (B=1) and scatter it into slot ``slot``.

        Compiles once per distinct true prompt length in blocks; the slot
        index and all per-request scalars are traced, so steady-state
        admission is a single cached executable.  ``pages`` (paged cache
        only) holds one page id per prompt block.
        """
        cfg = self.model.cfg
        MASK = cfg.resolved_mask_token
        paged = self.cache == "paged"
        caches1 = decoding.prefill(self.model, params, prompt, pblocks,
                                   self.max_len, ring=not paged)
        row = jnp.concatenate(
            [prompt[0].astype(jnp.int32),
             jnp.full((self.max_len - prompt.shape[1],), MASK, jnp.int32)])
        caches = {
            "prefix": {
                lk: self._scatter_layer(c, caches1["prefix"][lk], slot,
                                        pages, grouped=False)
                for lk, c in st.caches["prefix"].items()},
            "groups": {
                lk: self._scatter_layer(c, caches1["groups"][lk], slot,
                                        pages, grouped=True)
                for lk, c in st.caches["groups"].items()},
        }
        table = st.table
        if paged:
            table = table.at[slot, :pages.shape[0]].set(pages)
        return self._scatter_slot(st, slot, row, key, limit, pblocks[0],
                                  caches, table, samp)

    def _admit_hit_impl(self, st: decoding.GenState, slot, row, key,
                        limit, table_row, pblocks,
                        samp) -> decoding.GenState:
        """Admit a full prefix-cache hit: every prompt block is already
        committed in shared pages, so no model call happens at all —
        just scatter the slot's tokens / cursor / rng / block table.
        Compiles once (all shapes are pool-static).
        """
        return self._scatter_slot(st, slot, row, key, limit, pblocks,
                                  st.caches,
                                  st.table.at[slot].set(table_row), samp)

    def _admit_suffix_impl(self, params, st: decoding.GenState, slot,
                           suffix, row, key, limit, ctx_pages, sfx_pages,
                           table_row, samp) -> decoding.GenState:
        """Admit a partial prefix-cache hit: prefill only the suffix.

        ``suffix`` (1, Ls) are the prompt blocks beyond the hit;
        ``ctx_pages`` (h,) the shared pages of the hit prefix;
        ``sfx_pages`` (Ls // bsz,) fresh pages receiving the suffix KV.
        The committed pass reads the prefix through the shared pages
        (``decoding.prefill_suffix``), so the hit blocks are never
        re-prefilled.  Compiles per (hit, suffix) block-count pair.
        """
        bsz = self.model.cfg.block_size
        h = ctx_pages.shape[0]
        pblocks = h + suffix.shape[1] // bsz
        caches = decoding.prefill_suffix(
            self.model, params, suffix, jnp.int32(h), st.caches,
            context_table=ctx_pages[None], write_pages=sfx_pages[None],
            kv_kernel=self.kernel)
        return self._scatter_slot(st, slot, row, key, limit, pblocks,
                                  caches,
                                  st.table.at[slot].set(table_row), samp)

    def _note_prefill_tiles(self, req: Request) -> None:
        """Host-side gauge: tile-map sparsity of this admission's prefill
        attention (block granularity, i.e. the block-causal mask)."""
        bsz = self.model.cfg.block_size
        meta = plain_layout(jnp.asarray(req.prompt, jnp.int32)[None],
                            jnp.ones((1, len(req.prompt)), bool),
                            block_size=bsz)
        stats = layout_tile_stats(meta, tq=bsz, tk=bsz)
        self.stats.prefill_tile_visit_fraction = stats["visit_fraction"]

    def _admit_paged(self, params, slot: int, req: Request,
                     budget: int) -> bool:
        """Admit one request into ``slot`` under the paged allocator.

        Returns False (defer, nothing mutated) when the worst case does
        not fit.  With the prefix index enabled, the feasibility check
        covers the slot's *private* worst case (its generation budget)
        plus the index pages its admission turns live — hit blocks map
        shared pages in (refcount++), and only the suffix is prefilled.
        """
        cfg = self.model.cfg
        bsz = cfg.block_size
        pb = req.prompt_blocks
        limit = pb + budget
        samp = self._samp_scalars(req.params)
        if self.prefix is None:
            if self._pages_reserved + limit > self.n_usable_pages:
                return False
            pages = self._take_pages(pb)
            self._table_host[slot, :pb] = pages
            self._pages_reserved += limit
            self._slot_resv[slot] = limit
            self._slot_limit[slot] = limit
            self._slot_blk[slot] = pb
            self.stats.page_allocs += pb
            self.stats.prefill_blocks += pb
            self._note_prefill_tiles(req)
            self._admit_info = {"path": "cold", "hit_blocks": 0,
                                "new_pages": pb}
            with profile.annotate("prefill"):
                self._state = self._admit_jit(
                    params, self._state, jnp.int32(slot),
                    req.prompt[None], jnp.asarray([pb], jnp.int32),
                    req.rng, jnp.int32(limit), samp,
                    jnp.asarray(pages, jnp.int32))
            return True

        # the prefix index keys on prompt *content* only — sampling
        # params shape decoding, never prompt KV, so requests with
        # different params share (and register) pages identically
        keys = chain_keys(req.prompt, bsz)
        hits = self.prefix.match(keys)
        h = len(hits)
        idle_hits = sum(1 for e in hits if e.refs == 0)
        # invariant kept <= n_usable: live slots' private worst cases
        # (_pages_reserved) + live-referenced index pages (n_active);
        # everything outside it is free or reclaimable, so mid-flight
        # cursor allocation can never fail
        if self._pages_reserved + self.prefix.n_active + budget \
                + (pb - h) + idle_hits > self.n_usable_pages:
            return False
        # acquire before allocating: _take_pages may LRU-reclaim idle
        # entries, and an unreferenced hit would be fair game
        self.prefix.acquire(hits)
        new_pages = self._take_pages(pb - h)
        hit_pages = [e.page for e in hits]
        node_keys = [e.key for e in hits]
        node_keys += self.prefix.register(keys, h, new_pages)
        self._slot_nodes[slot] = node_keys
        self._table_host[slot, :pb] = hit_pages + new_pages
        self._pages_reserved += budget
        self._slot_resv[slot] = budget
        self._slot_limit[slot] = limit
        self._slot_blk[slot] = pb
        self.stats.page_allocs += len(new_pages)
        self.stats.prefix_hit_blocks += h
        self.stats.prefix_miss_blocks += pb - h
        self.stats.prefill_blocks += pb - h
        if pb > h:
            self._note_prefill_tiles(req)
        self.stats.shared_pages = max(self.stats.shared_pages,
                                      self.prefix.n_shared)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.pages_in_use)
        self.stats.peak_pages_live = max(self.stats.peak_pages_live,
                                         self.pages_live)

        table_row = jnp.asarray(self._table_host[slot], jnp.int32)
        self._admit_info = {"hit_blocks": h, "new_pages": len(new_pages)}
        if h == 0:
            # cold prompt: the PR-2 path — one B=1 plain prefill,
            # scattered into the fresh pages (then registered above)
            self._admit_info["path"] = "cold"
            with profile.annotate("prefill"):
                self._state = self._admit_jit(
                    params, self._state, jnp.int32(slot),
                    req.prompt[None], jnp.asarray([pb], jnp.int32),
                    req.rng, jnp.int32(limit), samp,
                    jnp.asarray(new_pages, jnp.int32))
            return True
        row = np.full((self.max_len,), cfg.resolved_mask_token, np.int32)
        row[:pb * bsz] = req.prompt
        if h == pb:
            # full hit (the DiPO G-group case): zero prefill
            self._admit_info["path"] = "full_hit"
            self._state = self._admit_hit_jit(
                self._state, jnp.int32(slot), jnp.asarray(row), req.rng,
                jnp.int32(limit), table_row, jnp.int32(pb), samp)
        else:
            self.stats.admit_transient_kv_bytes = max(
                self.stats.admit_transient_kv_bytes,
                self._admit_transient_kv_bytes(h))
            self._admit_info["path"] = "suffix_prefill"
            with profile.annotate("prefill_suffix"):
                self._state = self._admit_suffix_jit(
                    params, self._state, jnp.int32(slot),
                    req.prompt[None, h * bsz:], jnp.asarray(row),
                    req.rng, jnp.int32(limit),
                    jnp.asarray(hit_pages, jnp.int32),
                    jnp.asarray(new_pages, jnp.int32), table_row, samp)
        return True

    def _empty_completion(self, req: Request,
                          param_version: int = 0) -> Completion:
        """Zero-budget request: completes without ever touching a slot.

        The record is explicitly all-prompt: tokens beyond the true
        prompt stay MASK, the reveal-step map is all zero and
        ``gen_blocks == gen_tokens == 0`` — so downstream packaging
        (``rollout_to_batch``) can never mistake the prompt for
        revealed-at-step-0 generation.
        """
        cfg = self.model.cfg
        tokens = np.full((self.max_len,), cfg.resolved_mask_token,
                         np.int32)
        tokens[:req.prompt.shape[0]] = req.prompt
        self.stats.admitted += 1
        self.stats.completed += 1
        return Completion(
            uid=req.uid, tokens=tokens,
            steps=np.zeros((self.max_len,), np.int32),
            prompt_blocks=req.prompt_blocks, gen_blocks=0,
            gen_tokens=0, denoise_steps=0, finish_reason="length",
            admitted_tick=self.stats.ticks,
            completed_tick=self.stats.ticks, params=req.params,
            param_version=param_version)

    # ------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, prompt_blocks: int, rng=None, *,
               params: SamplingParams | None = None,
               max_new_blocks: int | None = _UNSET) -> int:
        """Queue a request; returns its uid (completions carry it).

        ``params`` carries every per-request decode knob (defaults to
        the pool's ``GenerationConfig`` sampling fields); the legacy
        ``max_new_blocks=`` keyword overrides the params' budget.  An
        explicit ``rng`` key always wins (so batch drivers keep their
        per-row key streams and static/continuous parity regardless of
        params); with ``rng`` omitted, ``params.seed`` derives the key
        — deterministic replay for a front end that cannot thread jax
        keys.

        The prompt is trimmed to its true ``prompt_blocks`` blocks:
        batch-padding blocks beyond that never influence decoding (the
        cache limit masks them and commits overwrite them), and dropping
        them keeps paged admission from allocating pages for padding.
        """
        prompt = np.asarray(prompt, np.int32)
        prompt_blocks = int(prompt_blocks)
        bsz = self.model.cfg.block_size
        assert prompt.ndim == 1 and prompt.shape[0] % bsz == 0
        assert 1 <= prompt_blocks <= self.n_blocks_total
        assert prompt_blocks * bsz <= prompt.shape[0]
        if params is None:
            params = self.default_params
        if max_new_blocks is not _UNSET:
            params = params.replace(max_new_blocks=max_new_blocks)
        if rng is None:
            if params.seed is None:
                raise ValueError("submit needs an rng key or params.seed")
            rng = jax.random.PRNGKey(params.seed)
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid=uid,
                                   prompt=prompt[:prompt_blocks * bsz],
                                   prompt_blocks=prompt_blocks,
                                   rng=jnp.asarray(rng),
                                   params=params))
        # lifecycle span 1/2: queued, closed at admission (or at the
        # zero-budget short circuit) with the wait labeled
        self.tracer.begin(("queued", uid), f"req {uid} queued",
                          cat="request", track="queue", uid=uid,
                          prompt_blocks=prompt_blocks)
        return uid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    # ------------------------------------------------- paged allocator
    def _take_pages(self, n: int) -> list[int]:
        """Pop ``n`` pages: free list first, then LRU prefix reclaims.

        Reclaimed pages held cached prompt KV of idle (refcount-0) index
        entries; their ``pos`` is wiped before reuse so the stale keys
        can never pass a later owner's ``cache_limit`` mask.  Guaranteed
        to succeed by the admission invariant: reserved worst cases plus
        live-referenced index pages never exceed the pool, so everything
        else is free or reclaimable.
        """
        out, reclaimed = [], []
        for _ in range(n):
            if self._free_pages:
                out.append(self._free_pages.pop())
                continue
            page = self.prefix.evict_lru() if self.prefix is not None \
                else None
            if page is None:
                raise RuntimeError(
                    "page pool exhausted — reservation invariant broken")
            reclaimed.append(page)
            out.append(page)
        if reclaimed:
            self.stats.prefix_evictions += len(reclaimed)
            self._invalidate_pages(reclaimed)
        return out

    def _alloc_cursor_pages(self) -> None:
        """Give every live slot a page for the block it commits next.

        Cannot fail: admission reserved each request's worst case, and a
        live slot's cursor is always below its limit, so at least one
        reserved-but-unallocated page remains for it (reclaiming idle
        prefix-cache pages if the free list is dry).
        """
        slots, blks, pages = [], [], []
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            b = self._slot_blk[slot]
            if self._table_host[slot, b] < 0:
                pg = self._take_pages(1)[0]
                self._table_host[slot, b] = pg
                slots.append(slot)
                blks.append(b)
                pages.append(pg)
        if slots:
            table = self._state.table.at[
                jnp.asarray(slots, jnp.int32),
                jnp.asarray(blks, jnp.int32)].set(
                    jnp.asarray(pages, jnp.int32))
            self._state = dataclasses.replace(self._state, table=table)
        self.stats.page_allocs += len(slots)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.pages_in_use)
        self.stats.peak_pages_live = max(self.stats.peak_pages_live,
                                         self.pages_live)

    def _free_slot_pages(self, slot: int) -> list[int]:
        """Release a slot's pages; returns the *exclusive* pages freed.

        Prompt pages registered in the prefix index are not freed — the
        slot just drops its references and the entries stay cached
        (reclaimed later under pressure).  Generated-block pages are
        always exclusive and return to the free list.
        """
        row = self._table_host[slot]
        pages = [int(p) for p in row[row >= 0]]
        nodes = self._slot_nodes[slot]
        if nodes:
            # row is block-ordered: the first len(nodes) mapped pages
            # are the registered prompt blocks, the rest generation
            self.prefix.release(nodes)
            self._slot_nodes[slot] = []
            pages = pages[len(nodes):]
        self._free_pages.extend(pages)
        self.stats.page_frees += len(pages)
        row[:] = -1
        self._pages_reserved -= self._slot_resv[slot]
        self._slot_resv[slot] = 0
        self._slot_limit[slot] = 0
        return pages

    def _invalidate_pages(self, pages: list[int]) -> None:
        """Free-list hygiene: wipe the ``pos`` of pages being freed.

        A reused page must look empty until its new owner writes it —
        stale positions from the previous request could otherwise pass
        the ``pos < cache_limit`` validity mask of a cursor page that is
        allocated (for the commit) before it is first written.  Applies
        equally to prefix-cache reclaims: a reclaimed page held valid
        cached keys by design, which become stale the moment the entry
        leaves the index.
        """
        idx = jnp.asarray(pages, jnp.int32)

        def wipe(c, grouped):
            if not isinstance(c, attention.PagedAttnCache):
                return c
            return attention.wipe_pages(c, idx, grouped=grouped)

        caches = self._state.caches
        caches = {
            "prefix": {lk: wipe(c, False)
                       for lk, c in caches["prefix"].items()},
            "groups": {lk: wipe(c, True)
                       for lk, c in caches["groups"].items()},
        }
        self._state = dataclasses.replace(self._state, caches=caches)

    # ------------------------------------------------------------ tick
    def step(self, params, param_version: int = 0) -> list[Completion]:
        """One scheduler tick: admit -> advance -> evict.

        ``params`` are the *model weights* (the per-request decode
        parameters ride on each submitted request); ``param_version`` is
        their monotone version tag (``ModelServer.version``) — stamped
        onto admissions and onto every block this tick commits, so
        completions carry exact per-block weight provenance.  Weights
        (and their version) may change between ticks without retracing:
        that block boundary is precisely where the async RL loop lands
        ``update_weights`` without draining the pool.  Returns the
        completions harvested this tick (possibly empty).

        Instrumentation: the tick and its three phases are recorded as
        tracer spans on the ``scheduler`` track; admitted requests get
        lifecycle spans on per-slot tracks.  All span timestamps are
        host wall-clock around jit *dispatch* — the tracer never syncs
        the device, so instrumentation cannot change tokens, retraces,
        or the ``hot-sync`` contract (tests assert byte-parity and
        ``n_advance_traces == 1`` with tracing on).
        """
        if isinstance(params, SamplingParams):
            raise TypeError(
                "step(params=) takes model weights; per-request "
                "SamplingParams belong on submit(..., params=...)")
        with self.tracer.span("tick", cat="scheduler", track="scheduler",
                              tick=self.stats.ticks):
            return self._tick(params, param_version)

    def _tick(self, params, param_version: int = 0) -> list[Completion]:
        self.stats.transient_kv_bytes = self.transient_kv_bytes
        if not self.stats.kernel_mode and self.kernel_plan:
            self.stats.kernel_mode = self.kernel_plan.mode
        # ---- admit queued requests into free slots -------------------
        out: list[Completion] = []
        with self.tracer.span("admit", cat="scheduler",
                              track="scheduler") as adm:
            n_adm = 0
            for slot in range(self.n_slots):
                if not self._queue or self._slot_req[slot] is not None:
                    continue
                req = self._queue[0]
                budget = self.n_blocks_total - req.prompt_blocks
                if req.params.max_new_blocks is not None:
                    budget = min(budget, req.params.max_new_blocks)
                if budget <= 0:
                    # nothing to decode (prompt fills the cache / zero
                    # block budget) — complete immediately, never touch
                    # a slot
                    self._queue.popleft()
                    out.append(self._empty_completion(req, param_version))
                    self.tracer.end(("queued", req.uid), outcome="empty")
                    continue
                limit = req.prompt_blocks + budget
                if self.cache == "paged":
                    if limit > self.n_usable_pages:
                        raise ValueError(
                            f"request {req.uid} needs {limit} pages but "
                            f"the pool only has {self.n_usable_pages}")
                    if not self._admit_paged(params, slot, req, budget):
                        # out of pages: defer the FIFO head until
                        # evictions free some (backpressure, not a crash)
                        self.stats.deferred += 1
                        self.tracer.instant("defer", cat="scheduler",
                                            track="scheduler",
                                            uid=req.uid,
                                            queued=len(self._queue))
                        break
                else:
                    self.stats.prefill_blocks += req.prompt_blocks
                    self._note_prefill_tiles(req)
                    self._admit_info = {"path": "dense", "hit_blocks": 0}
                    with profile.annotate("prefill"):
                        self._state = self._admit_jit(
                            params, self._state, jnp.int32(slot),
                            req.prompt[None],
                            jnp.asarray([req.prompt_blocks], jnp.int32),
                            req.rng, jnp.int32(limit),
                            self._samp_scalars(req.params), None)
                self._queue.popleft()
                self._slot_req[slot] = req
                self._slot_admit_tick[slot] = self.stats.ticks
                self._slot_admit_version[slot] = param_version
                self._slot_admit_abs[slot] = len(self._tick_versions)
                self.stats.admitted += 1
                n_adm += 1
                # lifecycle span 2/2: decode, one track per slot —
                # closed at harvest with the finish labels
                info = self._admit_info
                self.tracer.end(("queued", req.uid), slot=slot, **info)
                self.tracer.begin(
                    ("decode", req.uid), f"req {req.uid}",
                    cat="request", track=f"slot {slot}", uid=req.uid,
                    slot=slot, kernel_mode=self.stats.kernel_mode,
                    prompt_blocks=req.prompt_blocks, budget=budget,
                    **info)
            adm.args["admitted"] = n_adm

        self.stats.peak_active = max(self.stats.peak_active,
                                     self.n_active)
        if not any(r is not None for r in self._slot_req):
            return out

        # ---- advance the whole pool by one block ---------------------
        # span brackets page allocation + jit dispatch; advance_block's
        # result is left unsynced, so dur is dispatch time unless
        # sync_each_tick (engine) or a profiler capture asks for more
        with self.tracer.span("advance", cat="scheduler",
                              track="scheduler", n_active=self.n_active):
            if self.cache == "paged":
                self._alloc_cursor_pages()
            with profile.annotate("advance_block"):
                self._state = self._advance(params, self._state)
        # every live slot committed one block under these weights
        self._tick_versions.append(param_version)
        self.stats.advance_traces = self._advance.n_traces
        self.stats.ticks += 1
        self.stats.slot_ticks += self.n_slots
        self.stats.active_slot_ticks += self.n_active
        if self.cache == "paged":
            # mirror advance_block's cursor update (live slots were all
            # not-done going in): blk <- min(blk + 1, limit)
            for slot, req in enumerate(self._slot_req):
                if req is not None:
                    self._slot_blk[slot] = min(self._slot_blk[slot] + 1,
                                               self._slot_limit[slot])

        # ---- evict finished slots ------------------------------------
        with self.tracer.span("harvest", cat="scheduler",
                              track="scheduler") as hv:
            done = np.asarray(self._state.done)
            evicted: list[int] = []
            freed_pages: list[int] = []
            for slot in range(self.n_slots):
                req = self._slot_req[slot]
                if req is None or not done[slot]:
                    continue
                tokens = np.asarray(self._state.tokens[slot])
                steps = np.asarray(self._state.steps[slot])
                gen_blocks = int(self._state.blk[slot]) \
                    - req.prompt_blocks
                bsz = self.model.cfg.block_size
                lo, hi = req.prompt_blocks * bsz, \
                    (req.prompt_blocks + gen_blocks) * bsz
                # serve-stats count tokens up to and including the first
                # EOS (the *request's* stop token): the rest of an EOS
                # block is padding, not output
                eos_id = req.params.eos_id
                gen_tokens = int(decoding.count_gen_tokens(
                    tokens[None], [req.prompt_blocks], [gen_blocks],
                    eos_id=eos_id, block_size=bsz)[0])
                hit_eos = bool((tokens[lo:hi] == eos_id).any())
                # a live slot advances on every tick from admission to
                # harvest, so its gen blocks map one-to-one onto the
                # tick-version records starting at its admission point
                a0 = self._slot_admit_abs[slot]
                comp = Completion(
                    uid=req.uid, tokens=tokens, steps=steps,
                    prompt_blocks=req.prompt_blocks,
                    gen_blocks=gen_blocks, gen_tokens=gen_tokens,
                    denoise_steps=int(self._state.n_denoise[slot]),
                    finish_reason="eos" if hit_eos else "length",
                    admitted_tick=self._slot_admit_tick[slot],
                    completed_tick=self.stats.ticks, params=req.params,
                    param_version=self._slot_admit_version[slot],
                    block_versions=np.asarray(
                        self._tick_versions[a0:a0 + gen_blocks],
                        np.int64))
                out.append(comp)
                self.tracer.end(("decode", req.uid),
                                finish_reason=comp.finish_reason,
                                gen_tokens=comp.gen_tokens,
                                gen_blocks=comp.gen_blocks,
                                denoise_steps=comp.denoise_steps,
                                latency_ticks=comp.latency_ticks)
                self._slot_req[slot] = None
                evicted.append(slot)
                if self.cache == "paged":
                    freed_pages.extend(self._free_slot_pages(slot))
                self.stats.completed += 1
                self.stats.gen_tokens += gen_tokens
                self.stats.denoise_steps += comp.denoise_steps
            if evicted and self.cache == "paged":
                # reset the device table rows so the freed slots'
                # idempotent re-commits dump into the null page, not
                # into pages that may be re-allocated to other requests
                # (shared prompt pages stay mapped in the *surviving*
                # sharers' rows untouched)
                table = self._state.table.at[
                    jnp.asarray(evicted, jnp.int32)].set(-1)
                self._state = dataclasses.replace(self._state,
                                                  table=table)
                if freed_pages:
                    # exclusive pages only: wiping a still-shared page
                    # would blind the survivors to their own prompt
                    self._invalidate_pages(freed_pages)
            hv.args["completed"] = len(evicted)
        return out

    def run(self, params) -> Iterator[Completion]:
        """Drive ticks until queue + slots drain, streaming completions."""
        while self.has_work:
            yield from self.step(params)
