"""Slot-based continuous-batching scheduler for blockwise-dLLM decoding.

Architecture
------------
The scheduler owns a fixed pool of ``n_slots`` decode slots backed by one
batched ``core.decoding.GenState`` (tokens / step maps / per-slot block
cursors / per-slot rng keys / decode caches).  Time advances in *ticks*:
one tick = one call of the jitted ``core.decoding.advance_block`` over
the whole pool, i.e. every live slot denoises and commits exactly one
block.  Between ticks — block boundaries, the only points where a
blockwise dLLM can change batch composition without corrupting caches —
the scheduler runs its Python-side control loop:

  admit    queued requests are prefetched into freed slots: a B=1
           ``prefill`` builds the request's cache rows, which are then
           scattered into the pool for that slot together with its
           prompt tokens, rng key, cursor and block budget;
  advance  one jitted pool step (inactive slots are ``done`` and merely
           re-commit their frozen block — idempotent by construction);
  evict    slots whose sequence hit EOS or its block budget are
           harvested into ``Completion`` records and returned to the
           free list.

Cache layouts (``cache=``)
--------------------------
``"dense"``  every slot owns a contiguous ``max_len`` cache region; slot
             count is therefore capped by worst-case length, and a short
             request reserves as much KV memory as the longest one.

``"paged"``  the vLLM-style fix: attention KV lives in one shared pool
             of ``n_pages`` block-sized pages (``models.attention.
             PagedAttnCache``; one page = one ``block_size`` block,
             matching the blockwise commit granularity), addressed
             through a per-slot block table carried in
             ``GenState.table``.  Recurrent/conv states are O(1) per
             sequence and stay per-slot.  Page lifecycle:

               * admission  — one page per true prompt block, filled by
                 scattering the B=1 prefill row block-by-block;
               * advance    — one page per live slot for the block its
                 cursor is about to commit;
               * eviction   — all of a slot's pages return to the free
                 list and its table row is reset to -1, so the slot's
                 subsequent idempotent re-commits dump into the null
                 page (page 0, never allocated) instead of a page that
                 may already belong to another request.

             Admission reserves a request's worst case (``prompt_blocks
             + budget`` pages) up front, so mid-flight allocation can
             never fail and there is no preemption; when the head of the
             queue does not fit, admission *defers* (backpressure,
             counted in ``stats.deferred``) until evictions free pages —
             it never crashes.  Short-budget requests therefore stop
             reserving long-request memory, and slot count decouples
             from ``max_len``.

Request lifecycle: ``submit() -> queued -> admitted (slot) -> decoding
-> completed`` — completions stream out of ``step()``/``run()`` in
finish order, not arrival order.

DiPO-exactness: every row of ``advance_block`` evolves independently
(per-row caches or per-row block-table entries, per-row rng streams), so
a request's tokens and step map depend only on its own prompt + rng key
— *not* on which other requests happen to share the pool, nor on the
cache layout: paged and dense produce byte-identical tokens and step
maps (tested in tests/test_scheduler.py), so RL rollouts harvested from
the scheduler remain exactly consumable by the DiPO trajectory replay.

Follow-ups tracked in ROADMAP.md: multi-host pools and batched
same-width admission.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.models import attention


@dataclasses.dataclass
class Request:
    """One generation request (prompt already tokenised, block-aligned)."""
    uid: int
    prompt: np.ndarray           # (Lp,) int32, Lp = prompt_blocks * bsz
    prompt_blocks: int           # true prompt length in blocks
    rng: jax.Array               # (2,) per-request rng key
    max_new_blocks: int | None = None   # None = fill cache capacity


@dataclasses.dataclass
class Completion:
    """A finished request, harvested at eviction time."""
    uid: int
    tokens: np.ndarray           # (max_len,) prompt ++ generation ++ MASK
    steps: np.ndarray            # (max_len,) per-token reveal-step map
    prompt_blocks: int
    gen_blocks: int
    gen_tokens: int              # generated tokens up to first EOS incl.
    denoise_steps: int           # actual denoise steps executed (dynamic)
    finished_eos: bool           # True: EOS; False: hit block budget
    admitted_tick: int
    completed_tick: int


@dataclasses.dataclass
class SchedulerStats:
    """Honest utilization counters (the fig6/serve_bench substrate)."""
    ticks: int = 0               # pool advance steps executed
    slot_ticks: int = 0          # ticks * n_slots (paid compute)
    active_slot_ticks: int = 0   # slot-ticks that advanced a live request
    admitted: int = 0
    completed: int = 0
    gen_tokens: int = 0          # tokens served, cut at first EOS incl.
    denoise_steps: int = 0       # actual denoise steps across requests
    peak_active: int = 0         # max concurrently live slots
    # paged cache only
    deferred: int = 0            # admissions deferred for lack of pages
    page_allocs: int = 0
    page_frees: int = 0
    peak_pages_in_use: int = 0

    @property
    def utilization(self) -> float:
        """Fraction of paid slot-ticks that did useful work."""
        return self.active_slot_ticks / max(self.slot_ticks, 1)


class SlotScheduler:
    """Fixed-slot continuous batcher over one jitted block-advance."""

    def __init__(self, model, n_slots: int, max_len: int, *,
                 s_max: int = 8, mode: str = "dynamic", tau: float = 0.9,
                 n_steps: int = 8, temperature: float = 0.0,
                 eos_id: int = 1, cache: str = "dense",
                 n_pages: int | None = None):
        cfg = model.cfg
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if cache not in ("dense", "paged"):
            raise ValueError(f"cache must be dense|paged, got {cache!r}")
        assert max_len % cfg.block_size == 0
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.n_blocks_total = max_len // cfg.block_size
        self.eos_id = eos_id
        self.cache = cache
        self.stats = SchedulerStats()

        if cache == "paged":
            # default: the same KV footprint a dense pool would reserve,
            # plus the never-allocated null page 0
            self.n_pages = n_pages if n_pages is not None \
                else n_slots * self.n_blocks_total + 1
            if self.n_pages < 2:
                raise ValueError("paged cache needs >= 2 pages")
            self._free_pages = list(range(self.n_pages - 1, 0, -1))
            self._table_host = np.full(
                (n_slots, self.n_blocks_total), -1, np.int64)
            self._pages_reserved = 0          # worst case of live slots
            self._slot_limit = [0] * n_slots
            self._slot_blk = [0] * n_slots    # host mirror of state.blk
        else:
            self.n_pages = 0

        self._queue: deque[Request] = deque()
        self._slot_req: list[Request | None] = [None] * n_slots
        self._slot_admit_tick: list[int] = [0] * n_slots
        self._next_uid = 0
        self._state = self._init_pool()

        # donate the pool state: the old GenState (slot caches included)
        # is always dead after the call, so advance/admit alias their
        # buffers in place instead of holding a 2x-peak copy per tick
        # (backends without donation support just ignore the hint)
        self._advance = jax.jit(functools.partial(
            decoding.advance_block, model, mode=mode, tau=tau,
            n_steps=n_steps, temperature=temperature, s_max=s_max,
            eos_id=eos_id), donate_argnums=(1,))
        self._admit_jit = jax.jit(self._admit_impl, donate_argnums=(1,))

    # ----------------------------------------------------------- state
    @property
    def n_usable_pages(self) -> int:
        """Allocatable pages (excludes the null page)."""
        return max(self.n_pages - 1, 0)

    @property
    def pages_in_use(self) -> int:
        return self.n_usable_pages - len(self._free_pages) \
            if self.cache == "paged" else 0

    def _init_pool(self) -> decoding.GenState:
        cfg = self.model.cfg
        S, L = self.n_slots, self.max_len
        MASK = cfg.resolved_mask_token
        if self.cache == "paged":
            caches = self.model.make_paged_caches(S, self.n_pages)
            table = jnp.full((S, self.n_blocks_total), -1, jnp.int32)
        else:
            caches = self.model.make_caches(S, L)
            table = None
        return decoding.GenState(
            tokens=jnp.full((S, L), MASK, jnp.int32),
            steps=jnp.zeros((S, L), jnp.int32),
            caches=caches,
            blk=jnp.zeros((S,), jnp.int32),
            done=jnp.ones((S,), bool),        # all slots start free
            rng=jnp.zeros((S, 2), jnp.uint32),
            limit=jnp.zeros((S,), jnp.int32),
            n_denoise=jnp.zeros((S,), jnp.int32),
            table=table)

    @staticmethod
    def _scatter_layer(pool, new, slot, pages, *, grouped: bool):
        """Scatter one layer of a B=1 prefill into the pool.

        Paged attention layers scatter block-by-block into the request's
        freshly allocated pages; per-slot states (SSM/conv/shift) scatter
        into the slot's row as in the dense layout.
        """
        if pool is None:
            return None
        if isinstance(pool, attention.PagedAttnCache):
            fn = attention.write_prompt_pages_grouped if grouped \
                else attention.write_prompt_pages
            return fn(pool, new, pages)
        if grouped:  # group leaves carry a leading (G,) axis
            return jax.tree.map(lambda p, n: p.at[:, slot].set(n[:, 0]),
                                pool, new)
        return jax.tree.map(lambda p, n: p.at[slot].set(n[0]), pool, new)

    def _admit_impl(self, params, st: decoding.GenState, slot,
                    prompt, pblocks, key, limit,
                    pages=None) -> decoding.GenState:
        """Prefill one request (B=1) and scatter it into slot ``slot``.

        Compiles once per distinct true prompt length in blocks; the slot
        index and all per-request scalars are traced, so steady-state
        admission is a single cached executable.  ``pages`` (paged cache
        only) holds one page id per prompt block.
        """
        cfg = self.model.cfg
        MASK = cfg.resolved_mask_token
        paged = self.cache == "paged"
        caches1 = decoding.prefill(self.model, params, prompt, pblocks,
                                   self.max_len, ring=not paged)
        row = jnp.concatenate(
            [prompt[0].astype(jnp.int32),
             jnp.full((self.max_len - prompt.shape[1],), MASK, jnp.int32)])
        caches = {
            "prefix": {
                lk: self._scatter_layer(c, caches1["prefix"][lk], slot,
                                        pages, grouped=False)
                for lk, c in st.caches["prefix"].items()},
            "groups": {
                lk: self._scatter_layer(c, caches1["groups"][lk], slot,
                                        pages, grouped=True)
                for lk, c in st.caches["groups"].items()},
        }
        table = st.table
        if paged:
            table = table.at[slot, :pages.shape[0]].set(pages)
        return decoding.GenState(
            tokens=st.tokens.at[slot].set(row),
            steps=st.steps.at[slot].set(0),
            caches=caches,
            blk=st.blk.at[slot].set(pblocks[0]),
            done=st.done.at[slot].set(False),
            rng=st.rng.at[slot].set(key),
            limit=st.limit.at[slot].set(limit),
            n_denoise=st.n_denoise.at[slot].set(0),
            table=table)

    def _empty_completion(self, req: Request) -> Completion:
        """Zero-budget request: completes without ever touching a slot.

        The record is explicitly all-prompt: tokens beyond the true
        prompt stay MASK, the reveal-step map is all zero and
        ``gen_blocks == gen_tokens == 0`` — so downstream packaging
        (``rollout_to_batch``) can never mistake the prompt for
        revealed-at-step-0 generation.
        """
        cfg = self.model.cfg
        tokens = np.full((self.max_len,), cfg.resolved_mask_token,
                         np.int32)
        tokens[:req.prompt.shape[0]] = req.prompt
        self.stats.admitted += 1
        self.stats.completed += 1
        return Completion(
            uid=req.uid, tokens=tokens,
            steps=np.zeros((self.max_len,), np.int32),
            prompt_blocks=req.prompt_blocks, gen_blocks=0,
            gen_tokens=0, denoise_steps=0, finished_eos=False,
            admitted_tick=self.stats.ticks,
            completed_tick=self.stats.ticks)

    # ------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, prompt_blocks: int, rng, *,
               max_new_blocks: int | None = None) -> int:
        """Queue a request; returns its uid (completions carry it).

        The prompt is trimmed to its true ``prompt_blocks`` blocks:
        batch-padding blocks beyond that never influence decoding (the
        cache limit masks them and commits overwrite them), and dropping
        them keeps paged admission from allocating pages for padding.
        """
        prompt = np.asarray(prompt, np.int32)
        prompt_blocks = int(prompt_blocks)
        bsz = self.model.cfg.block_size
        assert prompt.ndim == 1 and prompt.shape[0] % bsz == 0
        assert 1 <= prompt_blocks <= self.n_blocks_total
        assert prompt_blocks * bsz <= prompt.shape[0]
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid=uid,
                                   prompt=prompt[:prompt_blocks * bsz],
                                   prompt_blocks=prompt_blocks,
                                   rng=jnp.asarray(rng),
                                   max_new_blocks=max_new_blocks))
        return uid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    # ------------------------------------------------- paged allocator
    def _alloc_cursor_pages(self) -> None:
        """Give every live slot a page for the block it commits next.

        Cannot fail: admission reserved each request's worst case, and a
        live slot's cursor is always below its limit, so at least one
        reserved-but-unallocated page remains for it.
        """
        slots, blks, pages = [], [], []
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            b = self._slot_blk[slot]
            if self._table_host[slot, b] < 0:
                pg = self._free_pages.pop()
                self._table_host[slot, b] = pg
                slots.append(slot)
                blks.append(b)
                pages.append(pg)
        if slots:
            table = self._state.table.at[
                jnp.asarray(slots, jnp.int32),
                jnp.asarray(blks, jnp.int32)].set(
                    jnp.asarray(pages, jnp.int32))
            self._state = dataclasses.replace(self._state, table=table)
        self.stats.page_allocs += len(slots)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.pages_in_use)

    def _free_slot_pages(self, slot: int) -> list[int]:
        row = self._table_host[slot]
        pages = [int(p) for p in row[row >= 0]]
        self._free_pages.extend(pages)
        self.stats.page_frees += len(pages)
        row[:] = -1
        self._pages_reserved -= self._slot_limit[slot]
        self._slot_limit[slot] = 0
        return pages

    def _invalidate_pages(self, pages: list[int]) -> None:
        """Free-list hygiene: wipe the ``pos`` of pages being freed.

        A reused page must look empty until its new owner writes it —
        stale positions from the previous request could otherwise pass
        the ``pos < cache_limit`` validity mask of a cursor page that is
        allocated (for the commit) before it is first written.
        """
        idx = jnp.asarray(pages, jnp.int32)

        def wipe(c, grouped):
            if not isinstance(c, attention.PagedAttnCache):
                return c
            pos = c.pos.at[:, idx].set(-1) if grouped \
                else c.pos.at[idx].set(-1)
            return c._replace(pos=pos)

        caches = self._state.caches
        caches = {
            "prefix": {lk: wipe(c, False)
                       for lk, c in caches["prefix"].items()},
            "groups": {lk: wipe(c, True)
                       for lk, c in caches["groups"].items()},
        }
        self._state = dataclasses.replace(self._state, caches=caches)

    # ------------------------------------------------------------ tick
    def step(self, params) -> list[Completion]:
        """One scheduler tick: admit -> advance -> evict.

        Returns the completions harvested this tick (possibly empty).
        """
        # ---- admit queued requests into free slots -------------------
        out: list[Completion] = []
        for slot in range(self.n_slots):
            if not self._queue or self._slot_req[slot] is not None:
                continue
            req = self._queue[0]
            budget = self.n_blocks_total - req.prompt_blocks
            if req.max_new_blocks is not None:
                budget = min(budget, req.max_new_blocks)
            if budget <= 0:
                # nothing to decode (prompt fills the cache / zero block
                # budget) — complete immediately, never touch a slot
                self._queue.popleft()
                out.append(self._empty_completion(req))
                continue
            limit = req.prompt_blocks + budget
            if self.cache == "paged":
                if limit > self.n_usable_pages:
                    raise ValueError(
                        f"request {req.uid} needs {limit} pages but the "
                        f"pool only has {self.n_usable_pages}")
                if self._pages_reserved + limit > self.n_usable_pages:
                    # out of pages: defer the FIFO head until evictions
                    # free some (backpressure, never a crash)
                    self.stats.deferred += 1
                    break
            self._queue.popleft()
            pages = None
            if self.cache == "paged":
                pages = [self._free_pages.pop()
                         for _ in range(req.prompt_blocks)]
                self._table_host[slot, :req.prompt_blocks] = pages
                self._pages_reserved += limit
                self._slot_limit[slot] = limit
                self._slot_blk[slot] = req.prompt_blocks
                self.stats.page_allocs += len(pages)
                pages = jnp.asarray(pages, jnp.int32)
            self._state = self._admit_jit(
                params, self._state, jnp.int32(slot), req.prompt[None],
                jnp.asarray([req.prompt_blocks], jnp.int32), req.rng,
                jnp.int32(limit), pages)
            self._slot_req[slot] = req
            self._slot_admit_tick[slot] = self.stats.ticks
            self.stats.admitted += 1

        self.stats.peak_active = max(self.stats.peak_active,
                                     self.n_active)
        if not any(r is not None for r in self._slot_req):
            return out

        # ---- advance the whole pool by one block ---------------------
        if self.cache == "paged":
            self._alloc_cursor_pages()
        self._state = self._advance(params, self._state)
        self.stats.ticks += 1
        self.stats.slot_ticks += self.n_slots
        self.stats.active_slot_ticks += self.n_active
        if self.cache == "paged":
            # mirror advance_block's cursor update (live slots were all
            # not-done going in): blk <- min(blk + 1, limit)
            for slot, req in enumerate(self._slot_req):
                if req is not None:
                    self._slot_blk[slot] = min(self._slot_blk[slot] + 1,
                                               self._slot_limit[slot])

        # ---- evict finished slots ------------------------------------
        done = np.asarray(self._state.done)
        evicted: list[int] = []
        freed_pages: list[int] = []
        for slot in range(self.n_slots):
            req = self._slot_req[slot]
            if req is None or not done[slot]:
                continue
            tokens = np.asarray(self._state.tokens[slot])
            steps = np.asarray(self._state.steps[slot])
            gen_blocks = int(self._state.blk[slot]) - req.prompt_blocks
            bsz = self.model.cfg.block_size
            lo, hi = req.prompt_blocks * bsz, \
                (req.prompt_blocks + gen_blocks) * bsz
            # serve-stats count tokens up to and including the first
            # EOS: the rest of an EOS block is padding, not output
            gen_tokens = int(decoding.count_gen_tokens(
                tokens[None], [req.prompt_blocks], [gen_blocks],
                eos_id=self.eos_id, block_size=bsz)[0])
            comp = Completion(
                uid=req.uid, tokens=tokens, steps=steps,
                prompt_blocks=req.prompt_blocks, gen_blocks=gen_blocks,
                gen_tokens=gen_tokens,
                denoise_steps=int(self._state.n_denoise[slot]),
                finished_eos=bool((tokens[lo:hi] == self.eos_id).any()),
                admitted_tick=self._slot_admit_tick[slot],
                completed_tick=self.stats.ticks)
            out.append(comp)
            self._slot_req[slot] = None
            evicted.append(slot)
            if self.cache == "paged":
                freed_pages.extend(self._free_slot_pages(slot))
            self.stats.completed += 1
            self.stats.gen_tokens += gen_tokens
            self.stats.denoise_steps += comp.denoise_steps
        if evicted and self.cache == "paged":
            # reset the device table rows so the freed slots' idempotent
            # re-commits dump into the null page, not into pages that
            # may be re-allocated to other requests
            table = self._state.table.at[
                jnp.asarray(evicted, jnp.int32)].set(-1)
            self._state = dataclasses.replace(self._state, table=table)
            self._invalidate_pages(freed_pages)
        return out

    def run(self, params) -> Iterator[Completion]:
        """Drive ticks until queue + slots drain, streaming completions."""
        while self.has_work:
            yield from self.step(params)
