"""Slot-based continuous-batching scheduler for blockwise-dLLM decoding.

Architecture
------------
The scheduler owns a fixed pool of ``n_slots`` decode slots backed by one
batched ``core.decoding.GenState`` (tokens / step maps / KV+SSM caches /
per-slot block cursors / per-slot rng keys).  Time advances in *ticks*:
one tick = one call of the jitted ``core.decoding.advance_block`` over
the whole pool, i.e. every live slot denoises and commits exactly one
block.  Between ticks — block boundaries, the only points where a
blockwise dLLM can change batch composition without corrupting caches —
the scheduler runs its Python-side control loop:

  admit    queued requests are prefetched into freed slots: a B=1
           ``prefill`` builds the request's cache rows, which are then
           scattered into the pool's cache region for that slot together
           with its prompt tokens, rng key, cursor and block budget;
  advance  one jitted pool step (inactive slots are ``done`` and merely
           re-commit their frozen block — idempotent by construction);
  evict    slots whose sequence hit EOS or its block budget are
           harvested into ``Completion`` records and returned to the
           free list.

Request lifecycle: ``submit() -> queued -> admitted (slot) -> decoding
-> completed`` — completions stream out of ``step()``/``run()`` in
finish order, not arrival order.

DiPO-exactness: every row of ``advance_block`` evolves independently
(per-row caches, per-row rng streams), so a request's tokens and step
map depend only on its own prompt + rng key — *not* on which other
requests happen to share the pool.  Continuous batching therefore
produces token-identical outputs to the one-shot ``generate`` under the
same per-sequence keys (tested in tests/test_scheduler.py), and RL
rollouts harvested from the scheduler remain exactly consumable by the
DiPO trajectory replay.

Follow-ups tracked in ROADMAP.md: paged KV-cache (slot-size decoupled
from ``max_len``) and multi-host pools.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding


@dataclasses.dataclass
class Request:
    """One generation request (prompt already tokenised, block-aligned)."""
    uid: int
    prompt: np.ndarray           # (Lp,) int32, Lp a block multiple
    prompt_blocks: int           # true prompt length in blocks
    rng: jax.Array               # (2,) per-request rng key
    max_new_blocks: int | None = None   # None = fill cache capacity


@dataclasses.dataclass
class Completion:
    """A finished request, harvested at eviction time."""
    uid: int
    tokens: np.ndarray           # (max_len,) prompt ++ generation ++ MASK
    steps: np.ndarray            # (max_len,) per-token reveal-step map
    prompt_blocks: int
    gen_blocks: int
    denoise_steps: int           # actual denoise steps executed (dynamic)
    finished_eos: bool           # True: EOS; False: hit block budget
    admitted_tick: int
    completed_tick: int


@dataclasses.dataclass
class SchedulerStats:
    """Honest utilization counters (the fig6/serve_bench substrate)."""
    ticks: int = 0               # pool advance steps executed
    slot_ticks: int = 0          # ticks * n_slots (paid compute)
    active_slot_ticks: int = 0   # slot-ticks that advanced a live request
    admitted: int = 0
    completed: int = 0
    gen_tokens: int = 0          # tokens produced (gen_blocks * block)
    denoise_steps: int = 0       # actual denoise steps across requests

    @property
    def utilization(self) -> float:
        """Fraction of paid slot-ticks that did useful work."""
        return self.active_slot_ticks / max(self.slot_ticks, 1)


class SlotScheduler:
    """Fixed-slot continuous batcher over one jitted block-advance."""

    def __init__(self, model, n_slots: int, max_len: int, *,
                 s_max: int = 8, mode: str = "dynamic", tau: float = 0.9,
                 n_steps: int = 8, temperature: float = 0.0,
                 eos_id: int = 1):
        cfg = model.cfg
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        assert max_len % cfg.block_size == 0
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.n_blocks_total = max_len // cfg.block_size
        self.eos_id = eos_id
        self.stats = SchedulerStats()

        self._queue: deque[Request] = deque()
        self._slot_req: list[Request | None] = [None] * n_slots
        self._slot_admit_tick: list[int] = [0] * n_slots
        self._next_uid = 0
        self._state = self._init_pool()

        # donate the pool state: the old GenState (slot caches included)
        # is always dead after the call, so advance/admit alias their
        # buffers in place instead of holding a 2x-peak copy per tick
        # (backends without donation support just ignore the hint)
        self._advance = jax.jit(functools.partial(
            decoding.advance_block, model, mode=mode, tau=tau,
            n_steps=n_steps, temperature=temperature, s_max=s_max,
            eos_id=eos_id), donate_argnums=(1,))
        self._admit_jit = jax.jit(self._admit_impl, donate_argnums=(1,))

    # ----------------------------------------------------------- state
    def _init_pool(self) -> decoding.GenState:
        cfg = self.model.cfg
        S, L = self.n_slots, self.max_len
        MASK = cfg.resolved_mask_token
        return decoding.GenState(
            tokens=jnp.full((S, L), MASK, jnp.int32),
            steps=jnp.zeros((S, L), jnp.int32),
            caches=self.model.make_caches(S, L),
            blk=jnp.zeros((S,), jnp.int32),
            done=jnp.ones((S,), bool),        # all slots start free
            rng=jnp.zeros((S, 2), jnp.uint32),
            limit=jnp.zeros((S,), jnp.int32),
            n_denoise=jnp.zeros((S,), jnp.int32))

    def _admit_impl(self, params, st: decoding.GenState, slot,
                    prompt, pblocks, key, limit) -> decoding.GenState:
        """Prefill one request (B=1) and scatter it into slot ``slot``.

        Compiles once per distinct prompt width (a block multiple); the
        slot index and all per-request scalars are traced, so steady-state
        admission is a single cached executable.
        """
        cfg = self.model.cfg
        MASK = cfg.resolved_mask_token
        caches1 = decoding.prefill(self.model, params, prompt, pblocks,
                                   self.max_len)
        row = jnp.concatenate(
            [prompt[0].astype(jnp.int32),
             jnp.full((self.max_len - prompt.shape[1],), MASK, jnp.int32)])
        # prefix cache leaves are (B, ...); group leaves are (G, B, ...)
        caches = {
            "prefix": jax.tree.map(lambda p, n: p.at[slot].set(n[0]),
                                   st.caches["prefix"],
                                   caches1["prefix"]),
            "groups": jax.tree.map(lambda p, n: p.at[:, slot].set(n[:, 0]),
                                   st.caches["groups"],
                                   caches1["groups"]),
        }
        return decoding.GenState(
            tokens=st.tokens.at[slot].set(row),
            steps=st.steps.at[slot].set(0),
            caches=caches,
            blk=st.blk.at[slot].set(pblocks[0]),
            done=st.done.at[slot].set(False),
            rng=st.rng.at[slot].set(key),
            limit=st.limit.at[slot].set(limit),
            n_denoise=st.n_denoise.at[slot].set(0))

    def _empty_completion(self, req: Request) -> Completion:
        cfg = self.model.cfg
        tokens = np.full((self.max_len,), cfg.resolved_mask_token,
                         np.int32)
        tokens[:req.prompt.shape[0]] = req.prompt
        self.stats.admitted += 1
        self.stats.completed += 1
        return Completion(
            uid=req.uid, tokens=tokens,
            steps=np.zeros((self.max_len,), np.int32),
            prompt_blocks=req.prompt_blocks, gen_blocks=0,
            denoise_steps=0, finished_eos=False,
            admitted_tick=self.stats.ticks,
            completed_tick=self.stats.ticks)

    # ------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, prompt_blocks: int, rng, *,
               max_new_blocks: int | None = None) -> int:
        """Queue a request; returns its uid (completions carry it)."""
        prompt = np.asarray(prompt, np.int32)
        assert prompt.ndim == 1 and \
            prompt.shape[0] % self.model.cfg.block_size == 0
        assert prompt.shape[0] <= self.max_len
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid=uid, prompt=prompt,
                                   prompt_blocks=int(prompt_blocks),
                                   rng=jnp.asarray(rng),
                                   max_new_blocks=max_new_blocks))
        return uid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def step(self, params) -> list[Completion]:
        """One scheduler tick: admit -> advance -> evict.

        Returns the completions harvested this tick (possibly empty).
        """
        # ---- admit queued requests into free slots -------------------
        out: list[Completion] = []
        for slot in range(self.n_slots):
            if not self._queue or self._slot_req[slot] is not None:
                continue
            req = self._queue.popleft()
            budget = self.n_blocks_total - req.prompt_blocks
            if req.max_new_blocks is not None:
                budget = min(budget, req.max_new_blocks)
            if budget <= 0:
                # nothing to decode (prompt fills the cache / zero block
                # budget) — complete immediately, never touch a slot
                out.append(self._empty_completion(req))
                continue
            limit = req.prompt_blocks + budget
            self._state = self._admit_jit(
                params, self._state, jnp.int32(slot), req.prompt[None],
                jnp.asarray([req.prompt_blocks], jnp.int32), req.rng,
                jnp.int32(limit))
            self._slot_req[slot] = req
            self._slot_admit_tick[slot] = self.stats.ticks
            self.stats.admitted += 1

        if not any(r is not None for r in self._slot_req):
            return out

        # ---- advance the whole pool by one block ---------------------
        self._state = self._advance(params, self._state)
        self.stats.ticks += 1
        self.stats.slot_ticks += self.n_slots
        self.stats.active_slot_ticks += self.n_active

        # ---- evict finished slots ------------------------------------
        done = np.asarray(self._state.done)
        for slot in range(self.n_slots):
            req = self._slot_req[slot]
            if req is None or not done[slot]:
                continue
            tokens = np.asarray(self._state.tokens[slot])
            steps = np.asarray(self._state.steps[slot])
            gen_blocks = int(self._state.blk[slot]) - req.prompt_blocks
            bsz = self.model.cfg.block_size
            lo, hi = req.prompt_blocks * bsz, \
                (req.prompt_blocks + gen_blocks) * bsz
            eos = bool((tokens[lo:hi] == self.eos_id).any())
            comp = Completion(
                uid=req.uid, tokens=tokens, steps=steps,
                prompt_blocks=req.prompt_blocks, gen_blocks=gen_blocks,
                denoise_steps=int(self._state.n_denoise[slot]),
                finished_eos=eos,
                admitted_tick=self._slot_admit_tick[slot],
                completed_tick=self.stats.ticks)
            out.append(comp)
            self._slot_req[slot] = None
            self.stats.completed += 1
            self.stats.gen_tokens += gen_blocks * bsz
            self.stats.denoise_steps += comp.denoise_steps
        return out

    def run(self, params) -> Iterator[Completion]:
        """Drive ticks until queue + slots drain, streaming completions."""
        while self.has_work:
            yield from self.step(params)
