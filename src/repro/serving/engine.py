"""RolloutEngine — continuous-batching blockwise-dLLM inference.

The JetEngine/LMDeploy role, rebuilt on ``serving.scheduler``: requests
enter a queue, a fixed-slot ``SlotScheduler`` admits them into freed
decode slots at block boundaries, and completions stream back in finish
order.  The lock-step one-shot path (every request padded to the batch
max and decoded to drain — the pre-refactor behaviour) is kept as
``batching="static"`` for A/B benchmarking (benchmarks/serve_bench.py).

Contracts kept:
  * ``generate_ids(prompt_tokens, prompt_blocks, rng) -> gen dict`` —
    row order == input order, token- and step-map-identical between the
    static and continuous paths for the same rng (per-sequence key
    streams; see core.decoding), so rl/trainer.py, launch/serve.py and
    the fig6/fig7 benchmarks run unchanged.
  * ``generate_texts`` — texts trimmed at the first EOS.
  * ``EngineStats`` — throughput counters, now *honest*:
    ``total_steps`` counts denoise steps actually executed (dynamic
    early-exit included), not ``blocks * s_max``; ``total_tokens``
    counts generated tokens up to the first EOS inclusive (not the
    block-padded tail); continuous runs also record slot utilization
    (active slot-ticks / paid slot-ticks).

The continuous path's KV layout is selectable: ``cache="dense"`` (each
slot owns a ``max_len`` cache region) or ``cache="paged"`` (slots share
an ``n_pages`` pool of block-sized pages through per-slot block tables —
see serving.scheduler).  Paged pools add a third layer,
``prefix_cache`` (auto-on for pure-attention stacks): a refcounted
radix index shares committed prompt pages across requests, so DiPO's
G-rollouts-per-prompt groups (``generate_group_ids``) prefill each
unique prompt once and hold one copy of its KV.  All layouts produce
byte-identical tokens; ``EngineStats.prefix_hit_rate`` reports the
fraction of prompt blocks served from shared pages.

The engine reads weights from a ``ModelServer`` (in-place updates) or
``OfflineWeightStore`` (checkpoint baseline) — swapping one for the
other reproduces the paper's Fig. 6 ablation without touching the
engine.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import pad_to_block
from repro.serving.scheduler import Completion, SlotScheduler


@dataclasses.dataclass
class GenerationConfig:
    max_len: int = 256
    s_max: int = 8               # max denoise steps per block
    mode: str = "dynamic"        # dynamic | static
    tau: float = 0.9
    n_steps: int = 8             # static: denoise steps per block
    temperature: float = 0.0
    eos_id: int = 1
    batching: str = "continuous"  # continuous (slot pool) | static
    n_slots: int = 8             # continuous: decode-slot pool size
    cache: str = "dense"         # continuous: dense | paged KV layout
    n_pages: int | None = None   # paged: pool size (None = dense-equal)
    prefix_cache: bool | None = None  # paged: share prompt pages across
    # requests (None = auto: on for pure-attention backbones)


@dataclasses.dataclass
class EngineStats:
    rollouts: int = 0
    total_tokens: int = 0
    total_steps: int = 0          # denoise steps actually executed
    wall_seconds: float = 0.0
    slot_ticks: int = 0           # continuous: paid slot-steps
    active_slot_ticks: int = 0    # continuous: useful slot-steps
    prefix_hit_blocks: int = 0    # prompt blocks served from shared pages
    prefix_miss_blocks: int = 0   # prompt blocks that paid a prefill

    @property
    def tokens_per_step(self) -> float:
        return self.total_tokens / max(self.total_steps, 1)

    @property
    def utilization(self) -> float:
        """Fraction of paid slot compute that advanced a live request."""
        return self.active_slot_ticks / max(self.slot_ticks, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt blocks served from shared pages."""
        total = self.prefix_hit_blocks + self.prefix_miss_blocks
        return self.prefix_hit_blocks / max(total, 1)


class RolloutEngine:
    def __init__(self, model, weight_store, gen_cfg: GenerationConfig,
                 tokenizer: ByteTokenizer | None = None):
        self.model = model
        self.store = weight_store
        self.gen_cfg = gen_cfg
        self.tok = tokenizer or ByteTokenizer()
        self.stats = EngineStats()
        self.last_call: dict = {}
        self._pending: list[Completion] = []   # stream() completions
        # harvested while a generate_ids drain drove the shared pool
        self._gen_jit = jax.jit(
            functools.partial(
                decoding.generate, model,
                max_len=gen_cfg.max_len, s_max=gen_cfg.s_max,
                mode=gen_cfg.mode, tau=gen_cfg.tau,
                n_steps=gen_cfg.n_steps,
                temperature=gen_cfg.temperature, eos_id=gen_cfg.eos_id),
            static_argnames=())
        self._sched: SlotScheduler | None = None

    @property
    def scheduler(self) -> SlotScheduler:
        """The persistent slot pool (created on first use)."""
        if self._sched is None:
            g = self.gen_cfg
            self._sched = SlotScheduler(
                self.model, n_slots=g.n_slots, max_len=g.max_len,
                s_max=g.s_max, mode=g.mode, tau=g.tau, n_steps=g.n_steps,
                temperature=g.temperature, eos_id=g.eos_id,
                cache=g.cache, n_pages=g.n_pages,
                prefix_cache=g.prefix_cache)
        return self._sched

    # ------------------------------------------------------------------
    def generate_ids(self, prompt_tokens: np.ndarray,
                     prompt_blocks: np.ndarray, rng) -> dict:
        """Run blockwise decode on pre-tokenised prompts.

        Row order of the returned dict matches the input; the static and
        continuous paths are token-identical for the same ``rng``.
        """
        t0 = time.perf_counter()
        params = self.store.params   # offline store pays a load here
        if self.gen_cfg.batching == "static":
            gen = self._gen_jit(params, jnp.asarray(prompt_tokens),
                                jnp.asarray(prompt_blocks), rng)
            jax.block_until_ready(gen["tokens"])
            self.last_call = {"batching": "static"}
        else:
            gen = self._generate_ids_continuous(params, prompt_tokens,
                                                prompt_blocks, rng)
        dt = time.perf_counter() - t0
        B = prompt_tokens.shape[0]
        self.stats.rollouts += B
        # honest tokens/sec numerator: count only up to the first EOS
        self.stats.total_tokens += int(decoding.count_gen_tokens(
            gen["tokens"], gen["prompt_blocks"], gen["gen_blocks"],
            eos_id=self.gen_cfg.eos_id,
            block_size=self.model.cfg.block_size).sum())
        self.stats.total_steps += int(jnp.sum(gen["denoise_steps"]))
        self.stats.wall_seconds += dt
        return gen

    def generate_group_ids(self, prompt_tokens: np.ndarray,
                           prompt_blocks: np.ndarray, rng,
                           group_size: int) -> dict:
        """Roll out ``group_size`` trajectories per prompt (DiPO groups).

        Expands (P, Lp) prompts to a (P*G, Lp) batch with each group's G
        members *adjacent*, then runs ``generate_ids`` — identical rng
        layout to repeating the prompts by hand, so results are
        unchanged.  The point of the dedicated entry is the serving
        side: adjacent identical prompts admit back-to-back, so with
        ``cache="paged"`` + ``prefix_cache`` the first member registers
        the prompt's pages and the other G-1 map them straight into
        their block tables — one prefill and one KV copy per *unique*
        prompt instead of per request.
        """
        toks = np.repeat(np.asarray(prompt_tokens), group_size, axis=0)
        blocks = np.repeat(np.asarray(prompt_blocks), group_size, axis=0)
        return self.generate_ids(toks, blocks, rng)

    def _generate_ids_continuous(self, params, prompt_tokens,
                                 prompt_blocks, rng) -> dict:
        """Drain a fixed request batch through the slot pool."""
        sched = self.scheduler
        prompt_tokens = np.asarray(prompt_tokens)
        prompt_blocks = np.asarray(prompt_blocks)
        B, Lp = prompt_tokens.shape
        max_len = self.gen_cfg.max_len
        # the one-shot generate runs every row to its own block budget
        # (EOS or cache capacity), so the slot pool must too — a budget
        # derived from the *padded* width would truncate short-prompt
        # rows and break static/continuous parity
        keys = decoding._per_seq_keys(rng, B)
        uid_to_row = {}
        for i in range(B):
            uid = sched.submit(prompt_tokens[i], int(prompt_blocks[i]),
                               keys[i], max_new_blocks=None)
            uid_to_row[uid] = i

        tokens = np.zeros((B, max_len), np.int32)
        steps = np.zeros((B, max_len), np.int32)
        gen_blocks = np.zeros((B,), np.int32)
        denoise = np.zeros((B,), np.int32)
        done = np.zeros((B,), bool)
        ticks0 = sched.stats.ticks
        slot0, active0 = sched.stats.slot_ticks, \
            sched.stats.active_slot_ticks
        hit0, miss0 = sched.stats.prefix_hit_blocks, \
            sched.stats.prefix_miss_blocks
        n_done = 0
        while n_done < B:
            for comp in sched.step(params):
                row = uid_to_row.pop(comp.uid, None)
                if row is None:
                    # a streaming request finished mid-drain: hold it
                    # for the next stream() pass
                    self._pending.append(comp)
                    continue
                tokens[row] = comp.tokens
                steps[row] = comp.steps
                gen_blocks[row] = comp.gen_blocks
                denoise[row] = comp.denoise_steps
                # static parity: a zero-budget row (no loop trips) is
                # never flagged done by the one-shot generate either
                done[row] = comp.finished_eos or (
                    comp.gen_blocks > 0
                    and comp.prompt_blocks + comp.gen_blocks
                    >= sched.n_blocks_total)
                n_done += 1
        self.stats.slot_ticks += sched.stats.slot_ticks - slot0
        self.stats.active_slot_ticks += \
            sched.stats.active_slot_ticks - active0
        hit = sched.stats.prefix_hit_blocks - hit0
        miss = sched.stats.prefix_miss_blocks - miss0
        self.stats.prefix_hit_blocks += hit
        self.stats.prefix_miss_blocks += miss
        self.last_call = {
            "batching": "continuous",
            "ticks": sched.stats.ticks - ticks0,
            "utilization": (sched.stats.active_slot_ticks - active0)
            / max(sched.stats.slot_ticks - slot0, 1),
            "prefix_hit_rate": hit / max(hit + miss, 1),
        }
        return {"tokens": jnp.asarray(tokens), "steps": jnp.asarray(steps),
                "gen_blocks": jnp.asarray(gen_blocks),
                "prompt_blocks": jnp.asarray(prompt_blocks, jnp.int32),
                "done": jnp.asarray(done),
                "denoise_steps": jnp.asarray(denoise)}

    # ------------------------------------------------- streaming serve
    def _encode_prompt(self, prompt: str) -> tuple[np.ndarray, int]:
        bsz = self.model.cfg.block_size
        enc = pad_to_block(self.tok.encode(prompt, bos=True), bsz,
                           self.tok.pad_id)
        return np.asarray(enc, np.int32), len(enc) // bsz

    def submit(self, prompt: str, rng) -> int:
        """Queue one text request on the live pool; returns its uid."""
        toks, blocks = self._encode_prompt(prompt)
        return self.scheduler.submit(toks, blocks, rng)

    def stream(self, params=None) -> Iterator[tuple[int, str]]:
        """Drive the pool until it drains, yielding (uid, text) in
        completion order — new ``submit``s may land mid-stream.

        With ``params=None`` the live store weights are re-read every
        tick, so in-place server updates take effect mid-stream."""
        sched = self.scheduler
        live = params is None
        while sched.has_work or self._pending:
            if sched.has_work:
                p = self.store.params if live else params
                t0 = time.perf_counter()
                slot0 = sched.stats.slot_ticks
                active0 = sched.stats.active_slot_ticks
                hit0 = sched.stats.prefix_hit_blocks
                miss0 = sched.stats.prefix_miss_blocks
                self._pending.extend(sched.step(p))
                self.stats.wall_seconds += time.perf_counter() - t0
                self.stats.slot_ticks += sched.stats.slot_ticks - slot0
                self.stats.active_slot_ticks += \
                    sched.stats.active_slot_ticks - active0
                self.stats.prefix_hit_blocks += \
                    sched.stats.prefix_hit_blocks - hit0
                self.stats.prefix_miss_blocks += \
                    sched.stats.prefix_miss_blocks - miss0
            # pop-one/yield-one: if the consumer abandons the generator
            # mid-iteration, undelivered completions stay in _pending
            # for the next stream() call
            while self._pending:
                comp = self._pending.pop(0)
                self.stats.rollouts += 1
                self.stats.total_tokens += comp.gen_tokens
                self.stats.total_steps += comp.denoise_steps
                yield comp.uid, self._completion_text(comp)

    def _completion_text(self, comp: Completion) -> str:
        bsz = self.model.cfg.block_size
        lo = comp.prompt_blocks * bsz
        hi = lo + comp.gen_blocks * bsz
        return self._trim_eos(comp.tokens[lo:hi])

    def _trim_eos(self, ids: np.ndarray) -> str:
        """Decode a completion, trimmed at the first EOS token."""
        eos = np.flatnonzero(ids == self.gen_cfg.eos_id)
        if eos.size:
            ids = ids[:eos[0]]
        return self.tok.decode(ids)

    # ----------------------------------------------------- batch texts
    def generate_texts(self, prompts: Sequence[str], rng) -> list[str]:
        bsz = self.model.cfg.block_size
        encs = [self._encode_prompt(p) for p in prompts]
        lp = max(e.shape[0] for e, _ in encs)
        toks = np.zeros((len(prompts), lp), np.int32)
        blocks = np.zeros((len(prompts),), np.int32)
        for i, (e, nb) in enumerate(encs):
            toks[i, :e.shape[0]] = e
            blocks[i] = nb
        gen = self.generate_ids(toks, blocks, rng)
        outs = []
        for i in range(len(prompts)):
            start = int(blocks[i]) * bsz
            end = start + int(gen["gen_blocks"][i]) * bsz
            outs.append(self._trim_eos(np.asarray(gen["tokens"][i,
                                                               start:end])))
        return outs
