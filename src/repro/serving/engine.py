"""RolloutEngine — batched blockwise-dLLM inference (the JetEngine role).

Wraps the jitted ``core.decoding.generate`` loop with request batching,
tokenisation, dynamic/static decoding policy, and the throughput counters
the fig6/fig7 benchmarks read.  The engine reads weights from a
``ModelServer`` (in-place updates) or ``OfflineWeightStore`` (checkpoint
baseline) — swapping one for the other reproduces the paper's Fig. 6
ablation without touching the engine.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import pad_to_block


@dataclasses.dataclass
class GenerationConfig:
    max_len: int = 256
    s_max: int = 8               # max denoise steps per block
    mode: str = "dynamic"        # dynamic | static
    tau: float = 0.9
    n_steps: int = 8             # static: denoise steps per block
    temperature: float = 0.0
    eos_id: int = 1


@dataclasses.dataclass
class EngineStats:
    rollouts: int = 0
    total_tokens: int = 0
    total_steps: int = 0          # denoise steps executed (blocks * s_max)
    wall_seconds: float = 0.0

    @property
    def tokens_per_step(self) -> float:
        return self.total_tokens / max(self.total_steps, 1)


class RolloutEngine:
    def __init__(self, model, weight_store, gen_cfg: GenerationConfig,
                 tokenizer: ByteTokenizer | None = None):
        self.model = model
        self.store = weight_store
        self.gen_cfg = gen_cfg
        self.tok = tokenizer or ByteTokenizer()
        self.stats = EngineStats()
        self._gen_jit = jax.jit(
            functools.partial(
                decoding.generate, model,
                max_len=gen_cfg.max_len, s_max=gen_cfg.s_max,
                mode=gen_cfg.mode, tau=gen_cfg.tau,
                n_steps=gen_cfg.n_steps,
                temperature=gen_cfg.temperature, eos_id=gen_cfg.eos_id),
            static_argnames=())

    # ------------------------------------------------------------------
    def generate_ids(self, prompt_tokens: np.ndarray,
                     prompt_blocks: np.ndarray, rng) -> dict:
        """Run the jitted blockwise decode on pre-tokenised prompts."""
        t0 = time.perf_counter()
        params = self.store.params   # offline store pays a load here
        gen = self._gen_jit(params, jnp.asarray(prompt_tokens),
                            jnp.asarray(prompt_blocks), rng)
        jax.block_until_ready(gen["tokens"])
        dt = time.perf_counter() - t0
        B = prompt_tokens.shape[0]
        bsz = self.model.cfg.block_size
        new_tokens = int(jnp.sum(gen["gen_blocks"])) * bsz
        self.stats.rollouts += B
        self.stats.total_tokens += new_tokens
        self.stats.total_steps += int(jnp.sum(gen["gen_blocks"])) * \
            self.gen_cfg.s_max
        self.stats.wall_seconds += dt
        return gen

    def generate_texts(self, prompts: Sequence[str], rng) -> list[str]:
        bsz = self.model.cfg.block_size
        encs = [pad_to_block(self.tok.encode(p, bos=True), bsz,
                             self.tok.pad_id) for p in prompts]
        lp = max(len(e) for e in encs)
        toks = np.zeros((len(prompts), lp), np.int32)
        blocks = np.zeros((len(prompts),), np.int32)
        for i, e in enumerate(encs):
            toks[i, :len(e)] = e
            blocks[i] = len(e) // bsz
        gen = self.generate_ids(toks, blocks, rng)
        outs = []
        for i in range(len(prompts)):
            start = int(blocks[i]) * bsz
            end = start + int(gen["gen_blocks"][i]) * bsz
            outs.append(self.tok.decode(np.asarray(gen["tokens"][i,
                                                                 start:end])))
        return outs
