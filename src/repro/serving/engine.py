"""RolloutEngine — continuous-batching blockwise-dLLM inference.

The JetEngine/LMDeploy role, rebuilt on ``serving.scheduler``: requests
enter a queue, a fixed-slot ``SlotScheduler`` admits them into freed
decode slots at block boundaries, and completions stream back in finish
order.  The lock-step one-shot path (every request padded to the batch
max and decoded to drain — the pre-refactor behaviour) is kept as
``batching="static"`` for A/B benchmarking (benchmarks/serve_bench.py).

Request API (``serving.api``): every decode parameter is
request-granular.  ``submit(prompt, params=SamplingParams(...))``
queues a text request with its own τ / temperature / mode / step
budget / block budget / stop token / seed; ``generate_ids(...,
sampling=...)`` runs a whole batch of mixed configurations through one
jitted call (static path) or one slot pool (continuous path) — the
parameters ride in per-row vectors, so serving mixed traffic never
retraces and a row's tokens are bit-identical to a homogeneous run.
``stream()`` yields structured ``RequestOutput`` records (uid, text,
``finish_reason`` "eos" | "length", admit→finish latency in ticks).

Contracts kept:
  * ``generate_ids(prompt_tokens, prompt_blocks, rng) -> gen dict`` —
    row order == input order, token- and step-map-identical between the
    static and continuous paths for the same rng (per-sequence key
    streams; see core.decoding), so rl/trainer.py, launch/serve.py and
    the fig6/fig7 benchmarks run unchanged.
  * ``generate_texts`` — texts trimmed at the first EOS.
  * ``EngineStats`` — throughput counters, now *honest*:
    ``total_steps`` counts denoise steps actually executed (dynamic
    early-exit included), not ``blocks * s_max``; ``total_tokens``
    counts generated tokens up to the first EOS inclusive (not the
    block-padded tail); continuous runs also record slot utilization
    (active slot-ticks / paid slot-ticks) and admit→finish latency
    (``latency_p50`` / ``latency_p95``, in scheduler ticks).

The continuous path's KV layout is selectable: ``cache="dense"`` (each
slot owns a ``max_len`` cache region) or ``cache="paged"`` (slots share
an ``n_pages`` pool of block-sized pages through per-slot block tables —
see serving.scheduler).  Paged pools add a third layer,
``prefix_cache`` (auto-on for pure-attention stacks): a refcounted
radix index shares committed prompt pages across requests, so DiPO's
G-rollouts-per-prompt groups (``generate_group_ids``) prefill each
unique prompt once and hold one copy of its KV.  Sampling params never
affect prompt KV, so mixed-params requests share prefix pages freely.
All layouts produce byte-identical tokens; ``EngineStats.
prefix_hit_rate`` reports the fraction of prompt blocks served from
shared pages.  Paged pools additionally choose how decode *reads* the
pool via ``kernel="ref"|"pallas"`` — the gathered fallback vs the
in-place page-aware Pallas kernels (decode + suffix prefill);
``EngineStats.transient_kv_bytes`` / ``admit_transient_kv_bytes``
report the per-tick and admission-time K/V copies the chosen layout
pays (both 0 in-place), ``kernel_mode`` whether the kernels compile
or interpret on this backend.

The engine reads weights from a ``ModelServer`` (in-place updates) or
``OfflineWeightStore`` (checkpoint baseline) — swapping one for the
other reproduces the paper's Fig. 6 ablation without touching the
engine.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoding
from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import pad_to_block
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving.api import (GenerationConfig, RequestOutput,
                               SamplingParams)
from repro.serving.scheduler import Completion, SlotScheduler

__all__ = ["EngineStats", "GenerationConfig", "RequestOutput",
           "RolloutEngine", "SamplingParams"]


@dataclasses.dataclass
class EngineStats:
    """Engine-level throughput/latency counters.

    Like ``SchedulerStats``, every field is bound storage for an
    instrument in ``self.registry`` (namespace ``dirl_engine``): hot
    paths mutate attributes, exporters read ``registry.collect()``,
    and the warmup reset ``engine.stats = EngineStats()`` resets the
    exported view too.

    ``wall_seconds`` covers *engine-side* wall time under one uniform
    definition on every path: the time spent driving the pool plus
    packaging completions, measured around jit dispatch (the
    ``generate_ids`` call body; each ``stream()`` pool tick).  Consumer
    wait between ``stream`` yields is excluded.  With
    ``sync_each_tick`` the measured region includes a device sync, so
    the same field reports honest device latency.
    """
    rollouts: int = 0
    total_tokens: int = 0
    total_steps: int = 0          # denoise steps actually executed
    wall_seconds: float = 0.0
    slot_ticks: int = 0           # continuous: paid slot-steps
    active_slot_ticks: int = 0    # continuous: useful slot-steps
    prefix_hit_blocks: int = 0    # prompt blocks served from shared pages
    prefix_miss_blocks: int = 0   # prompt blocks that paid a prefill
    # continuous: per-tick cache-KV bytes the pool's decode layout
    # copies out of the resident cache (scheduler.stats mirror; 0 on
    # the in-place kernel="pallas" path)
    transient_kv_bytes: int = 0
    # continuous: peak admission-time cache-KV bytes one suffix prefill
    # gathered out of the pool (scheduler.stats mirror; 0 in-place)
    admit_transient_kv_bytes: int = 0
    # execution mode of the paged Pallas kernels ("compiled" |
    # "interpret", "" when no kernel is launched)
    kernel_mode: str = ""
    # continuous: compilations of the scheduler's pool advance
    # (TraceGuard mirror) — the zero-retrace contract keeps this at 1
    # across arbitrary per-request SamplingParams mixes
    advance_traces: int = 0
    # weight version (ModelServer.version) most recently read from the
    # store while driving the pool — the in-place update observability
    # hook: a push mid-stream moves this gauge at the next tick
    param_version: int = 0
    # continuous: per-completion admit -> finish latency, in scheduler
    # ticks (one tick = one block-advance over the pool).  An
    # obs.metrics.Histogram: cumulative count/sum plus a bounded
    # reservoir window for percentiles — a long-lived server keeps the
    # most recent 4096, not every request.  Deque-compatible (append /
    # len / iter), so legacy call sites read/write it unchanged.
    latencies: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "latency_ticks", "admit->finish latency in scheduler ticks",
            reservoir=4096))

    _COUNTER_FIELDS = ("rollouts", "total_tokens", "total_steps",
                       "slot_ticks", "active_slot_ticks",
                       "prefix_hit_blocks", "prefix_miss_blocks")
    _GAUGE_FIELDS = ("wall_seconds", "transient_kv_bytes",
                     "admit_transient_kv_bytes", "advance_traces",
                     "param_version")

    def __post_init__(self):
        self.registry = MetricsRegistry("dirl_engine")
        for f in self._COUNTER_FIELDS:
            self.registry.counter(f, bind=(self, f))
        for f in self._GAUGE_FIELDS:
            self.registry.gauge(f, bind=(self, f))
        self.registry.info("kernel_mode",
                           "paged-kernel execution mode",
                           bind=(self, "kernel_mode"))
        self.registry.adopt("latency_ticks", self.latencies)

    @property
    def tokens_per_step(self) -> float:
        return self.total_tokens / max(self.total_steps, 1)

    @property
    def utilization(self) -> float:
        """Fraction of paid slot compute that advanced a live request."""
        return self.active_slot_ticks / max(self.slot_ticks, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt blocks served from shared pages."""
        total = self.prefix_hit_blocks + self.prefix_miss_blocks
        return self.prefix_hit_blocks / max(total, 1)

    @property
    def latency_p50(self) -> float:
        """Median admit -> finish latency in scheduler ticks."""
        return self.latencies.percentile(50)

    @property
    def latency_p95(self) -> float:
        """95th-percentile admit -> finish latency in scheduler ticks."""
        return self.latencies.percentile(95)

    @property
    def latency_p99(self) -> float:
        """Tail (99th-percentile) admit -> finish latency in scheduler
        ticks — the SLO-facing number (over the bounded recent window)."""
        return self.latencies.percentile(99)


class RolloutEngine:
    def __init__(self, model, weight_store, gen_cfg: GenerationConfig,
                 tokenizer: ByteTokenizer | None = None):
        self.model = model
        self.store = weight_store
        self.gen_cfg = gen_cfg
        self.tok = tokenizer or ByteTokenizer()
        self.stats = EngineStats()
        # one tracer for the whole stack: handed to the scheduler so
        # engine drains, tick phases and request lifecycles land in a
        # single export (disabled by default — still used for timing)
        self.tracer = Tracer(capacity=gen_cfg.trace_capacity,
                             enabled=gen_cfg.trace)
        self.last_call: dict = {}
        self._pending: list[Completion] = []   # stream() completions
        # harvested while a generate_ids drain drove the shared pool
        self._rng = jax.random.PRNGKey(0)      # submit() key stream
        # sampling parameters enter as traced (B,) vectors, so one
        # compiled executable serves every config mix; only max_len and
        # s_max (shapes / loop bound) are baked in
        self._gen_jit = jax.jit(functools.partial(
            decoding.generate, model,
            max_len=gen_cfg.max_len, s_max=gen_cfg.s_max))
        self._sched: SlotScheduler | None = None

    @property
    def scheduler(self) -> SlotScheduler:
        """The persistent slot pool (created on first use).

        The whole ``GenerationConfig`` is handed over as one object —
        the scheduler reads the pool fields and derives its default
        ``SamplingParams`` from the decode fields, so a new config knob
        is threaded exactly once.
        """
        if self._sched is None:
            self._sched = SlotScheduler(self.model, self.gen_cfg,
                                        tracer=self.tracer)
            self.stats.transient_kv_bytes = \
                self._sched.transient_kv_bytes
            self.stats.kernel_mode = self._sched.stats.kernel_mode
        return self._sched

    # ------------------------------------------------------- sampling
    def _resolve_sampling(self, B: int, sampling, prompt_blocks):
        """Normalise ``sampling`` to a per-row params list + the vector
        kwargs ``decoding.generate`` consumes (incl. per-row ``limit``).
        """
        if sampling is None:
            plist = [self.gen_cfg.sampling()] * B
        elif isinstance(sampling, SamplingParams):
            plist = [sampling] * B
        else:
            plist = list(sampling)
            if len(plist) != B:
                raise ValueError(
                    f"sampling list has {len(plist)} entries "
                    f"for a batch of {B}")
        nbt = self.gen_cfg.max_len // self.model.cfg.block_size
        pb = np.asarray(prompt_blocks, np.int64)
        limit = np.full((B,), nbt, np.int32)
        for i, p in enumerate(plist):
            if p.max_new_blocks is not None:
                limit[i] = min(nbt, int(pb[i]) + p.max_new_blocks)
        kw = dict(
            tau=np.array([p.tau for p in plist], np.float32),
            temperature=np.array([p.temperature for p in plist],
                                 np.float32),
            n_steps=np.array([p.n_steps for p in plist], np.int32),
            mode=np.array([p.dynamic for p in plist], bool),
            eos_id=np.array([p.eos_id for p in plist], np.int32),
            limit=limit)
        return plist, kw

    # ------------------------------------------------------------------
    def generate_ids(self, prompt_tokens: np.ndarray,
                     prompt_blocks: np.ndarray, rng,
                     sampling=None) -> dict:
        """Run blockwise decode on pre-tokenised prompts.

        ``sampling``: None (config defaults), one ``SamplingParams``
        applied to every row, or a per-row sequence — a mixed batch
        costs no extra compilation on either path.  Row order of the
        returned dict matches the input; the static and continuous
        paths are token-identical for the same ``rng``.
        """
        # one obs span defines wall_seconds for the whole call on both
        # paths (a disabled tracer still times; see EngineStats docs)
        with self.tracer.span("generate_ids", cat="engine",
                              track="engine",
                              batching=self.gen_cfg.batching) as sp:
            self.stats.param_version = getattr(self.store, "version", 0)
            params = self.store.params  # offline store pays a load here
            B = prompt_tokens.shape[0]
            plist, vec_kw = self._resolve_sampling(B, sampling,
                                                   prompt_blocks)
            if self.gen_cfg.batching == "static":
                gen = self._gen_jit(params, jnp.asarray(prompt_tokens),
                                    jnp.asarray(prompt_blocks), rng,
                                    **vec_kw)
                if self.gen_cfg.sync_each_tick:
                    # opt-in: honest wall-clock per call, at dispatch cost
                    jax.block_until_ready(gen["tokens"])  # dirlint: ok(hot-sync)
                self.last_call = {"batching": "static"}
            else:
                gen = self._generate_ids_continuous(
                    params, prompt_tokens, prompt_blocks, rng, plist)
        dt = sp.dur
        self.stats.rollouts += B
        # honest tokens/sec numerator: count only up to the first EOS
        # (each row's own stop token)
        self.stats.total_tokens += int(decoding.count_gen_tokens(
            gen["tokens"], gen["prompt_blocks"], gen["gen_blocks"],
            eos_id=np.array([p.eos_id for p in plist], np.int32),
            block_size=self.model.cfg.block_size).sum())
        self.stats.total_steps += int(jnp.sum(gen["denoise_steps"]))
        self.stats.wall_seconds += dt
        return gen

    def generate_group_ids(self, prompt_tokens: np.ndarray,
                           prompt_blocks: np.ndarray, rng,
                           group_size: int, sampling=None) -> dict:
        """Roll out ``group_size`` trajectories per prompt (DiPO groups).

        Expands (P, Lp) prompts to a (P*G, Lp) batch with each group's G
        members *adjacent*, then runs ``generate_ids`` — identical rng
        layout to repeating the prompts by hand, so results are
        unchanged.  ``sampling`` may be one ``SamplingParams`` or a
        per-*prompt* sequence (length P, expanded across each group) —
        the per-group τ lever DiFFPO trains with.  The point of the
        dedicated entry is the serving side: adjacent identical prompts
        admit back-to-back, so with ``cache="paged"`` + ``prefix_cache``
        the first member registers the prompt's pages and the other G-1
        map them straight into their block tables — one prefill and one
        KV copy per *unique* prompt (sampling params never affect
        prompt KV, so mixed-τ groups share exactly the same).
        """
        toks = np.repeat(np.asarray(prompt_tokens), group_size, axis=0)
        blocks = np.repeat(np.asarray(prompt_blocks), group_size, axis=0)
        if sampling is not None and not isinstance(sampling,
                                                   SamplingParams):
            sampling = [p for p in sampling for _ in range(group_size)]
        return self.generate_ids(toks, blocks, rng, sampling=sampling)

    def _generate_ids_continuous(self, params, prompt_tokens,
                                 prompt_blocks, rng, plist) -> dict:
        """Drain a fixed request batch through the slot pool."""
        sched = self.scheduler
        # re-mirrored every drain from the scheduler's authoritative
        # pool-static value (never the resettable stats snapshot), so
        # the warmup pattern `engine.stats = EngineStats()` keeps it
        self.stats.transient_kv_bytes = sched.transient_kv_bytes
        prompt_tokens = np.asarray(prompt_tokens)
        prompt_blocks = np.asarray(prompt_blocks)
        B, Lp = prompt_tokens.shape
        max_len = self.gen_cfg.max_len
        # the one-shot generate runs every row to its own block budget
        # (EOS, max_new_blocks, or cache capacity), so the slot pool
        # must too — per-row limits, never the padded width
        keys = decoding._per_seq_keys(rng, B)
        uid_to_row = {}
        for i in range(B):
            uid = sched.submit(prompt_tokens[i], int(prompt_blocks[i]),
                               keys[i], params=plist[i])
            uid_to_row[uid] = i

        tokens = np.zeros((B, max_len), np.int32)
        steps = np.zeros((B, max_len), np.int32)
        gen_blocks = np.zeros((B,), np.int32)
        denoise = np.zeros((B,), np.int32)
        done = np.zeros((B,), bool)
        ticks0 = sched.stats.ticks
        slot0, active0 = sched.stats.slot_ticks, \
            sched.stats.active_slot_ticks
        hit0, miss0 = sched.stats.prefix_hit_blocks, \
            sched.stats.prefix_miss_blocks
        version = getattr(self.store, "version", 0)
        n_done = 0
        while n_done < B:
            for comp in sched.step(params, param_version=version):
                row = uid_to_row.pop(comp.uid, None)
                if row is None:
                    # a streaming request finished mid-drain: hold it
                    # for the next stream() pass
                    self._pending.append(comp)
                    continue
                tokens[row] = comp.tokens
                steps[row] = comp.steps
                gen_blocks[row] = comp.gen_blocks
                denoise[row] = comp.denoise_steps
                # static parity: a decoded row completes only at EOS or
                # its limit (both done in the one-shot generate); a
                # zero-budget row (no loop trips) is never flagged done
                done[row] = comp.gen_blocks > 0
                self.stats.latencies.append(comp.latency_ticks)
                n_done += 1
        self.stats.slot_ticks += sched.stats.slot_ticks - slot0
        self.stats.active_slot_ticks += \
            sched.stats.active_slot_ticks - active0
        hit = sched.stats.prefix_hit_blocks - hit0
        miss = sched.stats.prefix_miss_blocks - miss0
        self.stats.prefix_hit_blocks += hit
        self.stats.prefix_miss_blocks += miss
        self.stats.admit_transient_kv_bytes = max(
            self.stats.admit_transient_kv_bytes,
            sched.stats.admit_transient_kv_bytes)
        self.stats.advance_traces = sched.n_advance_traces
        self.last_call = {
            "batching": "continuous",
            "ticks": sched.stats.ticks - ticks0,
            "utilization": (sched.stats.active_slot_ticks - active0)
            / max(sched.stats.slot_ticks - slot0, 1),
            "prefix_hit_rate": hit / max(hit + miss, 1),
        }
        return {"tokens": jnp.asarray(tokens), "steps": jnp.asarray(steps),
                "gen_blocks": jnp.asarray(gen_blocks),
                "prompt_blocks": jnp.asarray(prompt_blocks, jnp.int32),
                "done": jnp.asarray(done),
                "denoise_steps": jnp.asarray(denoise)}

    # ------------------------------------------------- streaming serve
    def _encode_prompt(self, prompt: str) -> tuple[np.ndarray, int]:
        bsz = self.model.cfg.block_size
        enc = pad_to_block(self.tok.encode(prompt, bos=True), bsz,
                           self.tok.pad_id)
        return np.asarray(enc, np.int32), len(enc) // bsz

    def submit(self, prompt: str, rng=None,
               params: SamplingParams | None = None) -> int:
        """Queue one text request on the live pool; returns its uid.

        ``params`` carries the request's own decode configuration
        (pool defaults otherwise).  ``rng`` may be omitted: with
        ``params.seed`` set the key derives from the seed, else the
        engine draws from its internal key stream.
        """
        toks, blocks = self._encode_prompt(prompt)
        if rng is None and (params is None or params.seed is None):
            self._rng, rng = jax.random.split(self._rng)
        return self.scheduler.submit(toks, blocks, rng, params=params)

    def stream_completions(self, params=None) -> Iterator[Completion]:
        """Drive the pool until it drains, yielding raw ``Completion``
        records (full tokens + reveal-step map + per-block weight
        versions) in completion order — new ``submit``s may land
        mid-stream.

        With ``params=None`` the live store weights (and their version)
        are re-read every tick, so in-place server updates take effect
        at the next block boundary with the pool still full — the
        drain-free weight push the async RL producer rides on.  Text
        front ends want ``stream()``, which packages each completion
        into a ``RequestOutput``."""
        if isinstance(params, SamplingParams):
            raise TypeError(
                "stream(params=) takes model weights; per-request "
                "SamplingParams belong on submit(..., params=...)")
        sched = self.scheduler
        self.stats.transient_kv_bytes = sched.transient_kv_bytes
        live = params is None
        while sched.has_work or self._pending:
            if sched.has_work:
                version = getattr(self.store, "version", 0)
                p = self.store.params if live else params
                self.stats.param_version = version
                slot0 = sched.stats.slot_ticks
                active0 = sched.stats.active_slot_ticks
                hit0 = sched.stats.prefix_hit_blocks
                miss0 = sched.stats.prefix_miss_blocks
                # engine-side wall time: pool tick + (stream) completion
                # packaging; consumer wait between yields excluded —
                # the same definition generate_ids uses
                with self.tracer.span("stream_tick", cat="engine",
                                      track="engine") as sp:
                    self._pending.extend(
                        sched.step(p, param_version=version))
                self.stats.wall_seconds += sp.dur
                self.stats.slot_ticks += sched.stats.slot_ticks - slot0
                self.stats.active_slot_ticks += \
                    sched.stats.active_slot_ticks - active0
                self.stats.prefix_hit_blocks += \
                    sched.stats.prefix_hit_blocks - hit0
                self.stats.prefix_miss_blocks += \
                    sched.stats.prefix_miss_blocks - miss0
                self.stats.admit_transient_kv_bytes = max(
                    self.stats.admit_transient_kv_bytes,
                    sched.stats.admit_transient_kv_bytes)
                self.stats.advance_traces = sched.n_advance_traces
            # pop-one/yield-one: if the consumer abandons the generator
            # mid-iteration, undelivered completions stay in _pending
            # for the next stream() call
            while self._pending:
                comp = self._pending.pop(0)
                self.stats.rollouts += 1
                self.stats.total_tokens += comp.gen_tokens
                self.stats.total_steps += comp.denoise_steps
                self.stats.latencies.append(comp.latency_ticks)
                yield comp

    def stream(self, params=None) -> Iterator[RequestOutput]:
        """Drive the pool until it drains, yielding ``RequestOutput``
        records in completion order — new ``submit``s may land
        mid-stream.

        With ``params=None`` the live store weights are re-read every
        tick, so in-place server updates take effect mid-stream."""
        for comp in self.stream_completions(params):
            with self.tracer.span("package", cat="engine",
                                  track="engine", uid=comp.uid) as psp:
                out = self._to_output(comp)
            self.stats.wall_seconds += psp.dur
            yield out

    def _to_output(self, comp: Completion) -> RequestOutput:
        """Package a raw completion into the structured streaming
        record (text and ids trimmed at the request's own stop token)."""
        bsz = self.model.cfg.block_size
        lo = comp.prompt_blocks * bsz
        ids = self._trim_ids(comp.tokens[lo:lo + comp.gen_blocks * bsz],
                             comp.params.eos_id)
        return RequestOutput(
            uid=comp.uid, text=self.tok.decode(ids), token_ids=ids,
            finish_reason=comp.finish_reason,
            prompt_blocks=comp.prompt_blocks,
            gen_blocks=comp.gen_blocks, gen_tokens=comp.gen_tokens,
            denoise_steps=comp.denoise_steps,
            admitted_tick=comp.admitted_tick,
            completed_tick=comp.completed_tick, params=comp.params,
            param_version=comp.param_version)

    @staticmethod
    def _trim_ids(ids: np.ndarray, eos_id: int) -> np.ndarray:
        """Cut a generated region at the first EOS token (exclusive)."""
        eos = np.flatnonzero(ids == eos_id)
        return ids[:eos[0]] if eos.size else ids

    def _trim_eos(self, ids: np.ndarray, eos_id: int | None = None) -> str:
        """Decode a completion, trimmed at the first EOS token."""
        if eos_id is None:
            eos_id = self.gen_cfg.eos_id
        return self.tok.decode(self._trim_ids(ids, eos_id))

    # ----------------------------------------------------- batch texts
    def generate_texts(self, prompts: Sequence[str], rng,
                       sampling=None) -> list[str]:
        bsz = self.model.cfg.block_size
        encs = [self._encode_prompt(p) for p in prompts]
        lp = max(e.shape[0] for e, _ in encs)
        toks = np.zeros((len(prompts), lp), np.int32)
        blocks = np.zeros((len(prompts),), np.int32)
        for i, (e, nb) in enumerate(encs):
            toks[i, :e.shape[0]] = e
            blocks[i] = nb
        # resolve once; generate_ids treats the normalised per-row list
        # as-is, so the params seen here and there cannot drift
        plist, _ = self._resolve_sampling(len(prompts), sampling, blocks)
        gen = self.generate_ids(toks, blocks, rng, sampling=plist)
        outs = []
        for i in range(len(prompts)):
            start = int(blocks[i]) * bsz
            end = start + int(gen["gen_blocks"][i]) * bsz
            outs.append(self._trim_eos(
                np.asarray(gen["tokens"][i, start:end]),
                eos_id=plist[i].eos_id))
        return outs
