"""Model server with in-place weight updates (paper §4.2, Fig. 5b).

The LMDeploy analogue: the rollout engine holds one live copy of the
(sharded) parameters; each RL step pushes the trainer's fresh params into
the server **in place** — a device-to-device donation, no file-system IO,
the server never reloads.  ``OfflineWeightStore`` is the Fig. 5a baseline
it replaces: every step saves a checkpoint and the "server" re-loads it
(twice, as the paper observes: once for rollout, once for training).
"""

from __future__ import annotations

import glob
import os
import tempfile
import time
from typing import Any

import jax

from repro.checkpoint.io import load_pytree, save_pytree


class StaleParamsError(RuntimeError):
    """A consumer asked for a param version the server no longer holds.

    ``update_weights`` donates the superseded buffers (and the trainer's
    next step donates the live ones it handed over), so a reference to
    an old version is not merely outdated — reading it can raise
    jax's "Array has been deleted" or silently alias fresh data.  The
    versioned read surface turns that latent hazard into this loud,
    named error at the *request* site instead.
    """


class ModelServer:
    """Keeps the live param pytree + a monotonically increasing version."""

    def __init__(self, params: Any, *, donate: bool = True):
        self._params = params
        self.version = 0
        self.donate = donate
        self.update_seconds = 0.0

    @property
    def params(self):
        return self._params

    def params_versioned(self) -> tuple[int, Any]:
        """One atomic read of ``(version, params)``.

        The pair is what a tick-granular consumer (the async rollout
        producer) must take together: reading ``.params`` and
        ``.version`` separately races with an ``update_weights`` landing
        in between, mis-stamping a whole block of rollouts.
        """
        return self.version, self._params

    def params_at(self, version: int):
        """Version-pinned read: the live params iff ``version`` is
        current, else ``StaleParamsError``.

        The server keeps exactly one version — older buffers were
        donated away — so a consumer that cached a version tag across an
        update cannot get the matching weights back; failing loudly here
        beats a post-donation read deep inside a jitted call.
        """
        if version != self.version:
            raise StaleParamsError(
                f"params version {version} requested but the server "
                f"holds only version {self.version}; older buffers were "
                "donated by update_weights — re-read params_versioned() "
                "instead of caching params across updates")
        return self._params

    def update_weights(self, new_params, *, sync: bool = True) -> int:
        """In-place push (the LMDeploy update API analogue).

        With donation the old buffers are released as the new ones land;
        there is no serialisation and no reload.  ``sync=False`` skips
        the readiness barrier: the version advances immediately and the
        new buffers are consumed through normal jax dataflow — the async
        RL loop uses this so a weight push never stalls the host between
        two pool ticks (``update_seconds`` then measures dispatch only).
        """
        t0 = time.perf_counter()
        if self.donate:
            old = self._params
            self._params = new_params
            del old
        else:
            self._params = jax.tree.map(lambda x: x, new_params)
        if sync:
            jax.block_until_ready(
                jax.tree_util.tree_leaves(self._params)[0])
        self.update_seconds = time.perf_counter() - t0
        self.version += 1
        return self.version


class OfflineWeightStore:
    """Fig. 5a baseline: checkpoint round-trip through the file system."""

    def __init__(self, params: Any, root: str | None = None):
        self.root = root or tempfile.mkdtemp(prefix="dirl_offline_")
        self.version = 0
        self._like = jax.tree.map(lambda x: x, params)
        self.save_seconds = 0.0
        self.load_seconds = 0.0
        self.update_weights(params)

    def _path(self, version: int) -> str:
        return os.path.join(self.root, f"ckpt_{version}.msgpack")

    def update_weights(self, new_params) -> int:
        t0 = time.perf_counter()
        self.version += 1
        save_pytree(self._path(self.version), new_params)
        self.save_seconds = time.perf_counter() - t0
        self._gc(keep=self.version)
        return self.version

    def _gc(self, keep: int) -> None:
        """Delete superseded checkpoints — an online RL run writes one
        per step, which is unbounded disk growth if never reaped."""
        for p in glob.glob(os.path.join(self.root, "ckpt_*.msgpack")):
            if p == self._path(keep):
                continue
            try:
                os.remove(p)
            except OSError:
                pass

    @property
    def params(self):
        """Every access loads from storage — the cost Fig. 6 eliminates."""
        t0 = time.perf_counter()
        p = load_pytree(self._path(self.version), self._like)
        jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
        self.load_seconds = time.perf_counter() - t0
        return p
