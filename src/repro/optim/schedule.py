"""LR schedules (the paper uses cosine annealing with warmup for SFT)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(max_lr: float, total_steps: int, *,
                    warmup_steps: int = 0, min_lr: float = 0.0):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = max_lr * c / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((c - warmup_steps) /
                     jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_lr + 0.5 * (max_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(c < warmup_steps, warm, cos)
    return fn


def constant_schedule(lr: float):
    def fn(count):
        return jnp.full((), lr, jnp.float32)
    return fn
