"""AdamW with global-norm clipping and shardable state.

Optimizer state mirrors the parameter pytree (m, v per leaf) so the
distributed layer can shard it with the identical PartitionSpecs (the
ZeRO-1 equivalent of the paper's DeepSpeed setup).  ``state_dtype``
selects f32 (default) or bf16 moments — the bf16 option is what lets the
398B jamba config fit a single v5e pod (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    state_dtype: str = "float32"
    # schedule: None -> constant lr
    schedule: Callable[[jax.Array], jax.Array] | None = None


def init_state(cfg: AdamWConfig, params: PyTree) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                  state: dict) -> tuple[PyTree, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.ones(())
    lr = cfg.schedule(count) if cfg.schedule is not None else cfg.lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        step = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
