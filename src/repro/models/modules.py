"""Minimal pure-JAX module substrate.

No flax/haiku available in this container, so the framework carries its own
functional module layer: parameters are nested dicts of jnp arrays, every
module is an (init, apply) pair of plain functions, and layer stacks are
jax.lax.scan-compatible (params stacked along a leading axis).

Conventions
-----------
* ``init_*`` functions take a PRNGKey first and return a param pytree.
* ``apply`` style functions take the param pytree first.
* dtype policy: ``param_dtype`` is the storage dtype, ``dtype`` the compute
  dtype; casts happen at module boundaries (MaxText-style).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = dict
PyTree = Any


# ---------------------------------------------------------------------------
# RNG helpers
# ---------------------------------------------------------------------------


def split_like(key: jax.Array, names: Sequence[str]) -> dict[str, jax.Array]:
    """Split ``key`` into one sub-key per name (order-stable)."""
    keys = jax.random.split(key, len(names))
    return {n: k for n, k in zip(names, keys)}


def fold_name(key: jax.Array, name: str) -> jax.Array:
    """Deterministically derive a sub-key from a string name."""
    h = hash(name) % (2**31 - 1)
    return jax.random.fold_in(key, h)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def lecun_init(key, shape, fan_in: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return normal_init(key, shape, scale, dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, *, dtype=jnp.float32,
                use_bias: bool = False, scale: float | None = None) -> Params:
    p = {"w": lecun_init(key, (d_in, d_out), d_in, dtype)
         if scale is None else normal_init(key, (d_in, d_out), scale, dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array, *, dtype=None) -> jax.Array:
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_embedding(key, vocab: int, d_model: int, *, dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (vocab, d_model), 0.02, dtype)}


def embed(p: Params, ids: jax.Array, *, dtype=None) -> jax.Array:
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, ids, axis=0)


def unembed(p: Params, x: jax.Array, *, dtype=None) -> jax.Array:
    """Tied unembedding: logits = x @ table.T (cast to f32 for stability)."""
    t = p["table"].astype(jnp.float32 if dtype is None else dtype)
    return x.astype(t.dtype) @ t.T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (split-half convention).

    x: (..., L, H, D); positions: broadcastable to (..., L) int32.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, d/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., L, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
    "relu": jax.nn.relu,
    "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
}


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------


def tree_size(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """Flatten to [('a/b/c', leaf), ...] path strings."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p).strip("."))
        out.append(("/".join(parts), leaf))
    return out


def stack_trees(trees: Sequence[PyTree]) -> PyTree:
    """Stack a list of identical pytrees along a new leading axis (for scan)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def count_params_by_prefix(params: PyTree) -> dict[str, int]:
    out: dict[str, int] = {}
    for path, leaf in tree_paths(params):
        head = path.split("/", 1)[0]
        out[head] = out.get(head, 0) + leaf.size
    return out
