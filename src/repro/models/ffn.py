"""Feed-forward sublayers: SwiGLU, RWKV6 channel-mix, and top-k MoE.

The MoE uses sort-based dropless-ish dispatch (capacity-clipped): gather
tokens into per-expert buffers via argsort, batched expert einsum, scatter
back with gate weights.  Compute is O(E * C * d * f) = O(active tokens),
never O(T * E) matmuls — the property the roofline analysis depends on.
Under expert-parallel sharding (experts on the ``model`` axis) GSPMD turns
the gather/scatter into all-to-all-style collectives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import BATCH, shard_hint
from .config import ModelConfig
from .modules import ACTIVATIONS, init_linear, linear, split_like


# ---------------------------------------------------------------------------
# dense SwiGLU
# ---------------------------------------------------------------------------


def init_swiglu(key, d: int, f: int, *, dtype) -> dict:
    ks = split_like(key, ["w_gate", "w_up", "w_down"])
    return {
        "w_gate": init_linear(ks["w_gate"], d, f, dtype=dtype),
        "w_up": init_linear(ks["w_up"], d, f, dtype=dtype),
        "w_down": init_linear(ks["w_down"], f, d, dtype=dtype),
    }


def swiglu(p: dict, x: jax.Array, *, act: str = "silu") -> jax.Array:
    a = ACTIVATIONS[act]
    return linear(p["w_down"], a(linear(p["w_gate"], x)) * linear(p["w_up"], x))


# ---------------------------------------------------------------------------
# RWKV6 channel mix (token-shifted FFN; needs the shift state in decode)
# ---------------------------------------------------------------------------


def init_rwkv_cm(key, d: int, f: int, *, dtype) -> dict:
    ks = split_like(key, ["wk", "wv", "wr"])
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": init_linear(ks["wk"], d, f, dtype=dtype),
        "wv": init_linear(ks["wv"], f, d, dtype=dtype),
        "wr": init_linear(ks["wr"], d, d, dtype=dtype),
    }


def rwkv_cm(p: dict, x: jax.Array, shifted: jax.Array) -> jax.Array:
    """x (B,T,d); ``shifted`` = the token-shifted stream (callers build it
    per execution mode — plain roll, duplicated-layout shift, or decode
    shift from the cached boundary hidden)."""
    xk = x + (shifted - x) * p["mu_k"].astype(x.dtype)
    xr = x + (shifted - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk)))
    kv = linear(p["wv"], k)
    return jax.nn.sigmoid(linear(p["wr"], xr)) * kv


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.resolved_moe_d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_like(key, ["router", "gate", "up", "down", "shared"])
    p = {
        "router": init_linear(ks["router"], d, E, dtype=jnp.float32),
        "experts": {
            "w_gate": jax.random.normal(ks["gate"], (E, d, f), jnp.float32)
            .astype(dt) * (d ** -0.5),
            "w_up": jax.random.normal(ks["up"], (E, d, f), jnp.float32)
            .astype(dt) * (d ** -0.5),
            "w_down": jax.random.normal(ks["down"], (E, f, d), jnp.float32)
            .astype(dt) * (f ** -0.5),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(ks["shared"], d,
                                  f * cfg.n_shared_experts, dtype=dt)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_group(xt, logits, cfg: ModelConfig, C: int):
    """Sort-based dispatch for ONE routing group.

    xt (n, d); logits (n, E).  Returns (buf (E, C, d), slot, sorted_token,
    sorted_gate, keep) — everything _combine_group needs."""
    n, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (n, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalise

    flat_expert = expert_ids.reshape(-1)                        # (n*k,)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    counts = jnp.zeros((E,), jnp.int32).at[sorted_expert].add(1)
    starts = jnp.cumsum(counts) - counts                        # (E,)
    rank = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_expert]
    keep = rank < C
    slot = sorted_expert * C + jnp.where(keep, rank, 0)
    slot = jnp.where(keep, slot, E * C)                         # trash row

    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(
        xt[sorted_token], mode="drop")
    return buf[:-1].reshape(E, C, d), slot, sorted_token, sorted_gate, keep


def _combine_group(y, slot, sorted_token, sorted_gate, keep, n: int):
    """Weighted scatter-back for one group.  y (E, C, d) -> out (n, d)."""
    d = y.shape[-1]
    y_flat = y.reshape(-1, d)
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.where(keep, slot, 0)], 0.0)
    return jnp.zeros((n, d), y.dtype).at[sorted_token].add(
        gathered * sorted_gate[:, None].astype(y.dtype))


def moe(p: dict, x: jax.Array, cfg: ModelConfig
        ) -> tuple[jax.Array, dict]:
    """Top-k mixture with GROUP-LOCAL sort-based dispatch.

    x: (B, T, d).  Tokens are routed within ``cfg.moe_groups`` independent
    groups (groups aligned with the data-parallel sharding), so the
    data-dependent scatter/gather permutes only *within* a shard and GSPMD
    never has to move the dispatch across devices — the only cross-device
    traffic is the expert weights (all-gather over the FSDP axis) and the
    standard output partial-sum.  §Perf iteration 2: the single-group
    global sort forced either full-capacity f32 all-reduces (112 GiB/layer
    on jamba-398B) or giant dispatch reshards; group-local routing removes
    both.  Returns (out, aux) with the Switch-style load-balance loss.
    """
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    n = B * T
    G = max(1, min(cfg.moe_groups, n // max(E, 1)))
    while n % G:
        G -= 1
    ng = n // G
    C = _capacity(ng, cfg)

    xt = x.reshape(n, d)
    logits = linear(p["router"], xt.astype(jnp.float32))        # (n, E)

    # ---- load-balance auxiliary (Switch eq. 4), computed globally ----
    probs = jax.nn.softmax(logits, axis=-1)
    _, expert_ids = jax.lax.top_k(probs, k)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (n * k))
    aux_loss = E * jnp.sum(me * ce) * cfg.router_aux_coef

    act = ACTIVATIONS[cfg.act]
    w = p["experts"]

    xg = shard_hint(xt.reshape(G, ng, d), BATCH, None, None)
    lg = shard_hint(logits.reshape(G, ng, E), BATCH, None, None)
    buf, slot, stok, sgate, keep = jax.vmap(
        lambda xi, li: _dispatch_group(xi, li, cfg, C))(xg, lg)

    # expert compute on the (G, E, C, d) buffer OUTSIDE the vmap, with the
    # group dim pinned to the batch axes: the d/f contractions then gather
    # the small weight shards instead of all-reducing full-capacity f32
    # activations (§Perf iter 2b).
    buf = shard_hint(buf, BATCH, None, None, None)
    h = act(jnp.einsum("gecd,edf->gecf", buf, w["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", buf, w["w_up"])
    h = shard_hint(h, BATCH, None, None, "model")
    y = jnp.einsum("gecf,efd->gecd", h, w["w_down"])
    y = shard_hint(y, BATCH, None, None, None)

    out = jax.vmap(lambda yi, sl, st, sg, kp: _combine_group(
        yi, sl, st, sg, kp, ng))(y, slot, stok, sgate, keep)
    out = shard_hint(out, BATCH, None, None).reshape(n, d)

    if "shared" in p:
        out = out + swiglu(p["shared"], xt, act=cfg.act)

    dropped = 1.0 - keep.mean()
    return out.reshape(B, T, d), {"aux_loss": aux_loss,
                                  "drop_fraction": dropped}


def moe_dense_ref(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """O(T*E) oracle for tests: run every expert on every token."""
    B, T, d = x.shape
    xt = x.reshape(B * T, d)
    logits = linear(p["router"], xt.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    act = ACTIVATIONS[cfg.act]
    w = p["experts"]
    h = act(jnp.einsum("td,edf->etf", xt, w["w_gate"])) * \
        jnp.einsum("td,edf->etf", xt, w["w_up"])
    y_all = jnp.einsum("etf,efd->etd", h, w["w_down"])          # (E, n, d)
    sel = jnp.zeros((xt.shape[0], cfg.n_experts), jnp.float32)
    sel = sel.at[jnp.arange(xt.shape[0])[:, None], expert_ids].add(gate_vals)
    out = jnp.einsum("te,etd->td", sel.astype(x.dtype), y_all)
    if "shared" in p:
        out = out + swiglu(p["shared"], xt, act=cfg.act)
    return out.reshape(B, T, d)
