"""Recurrent mixers: RWKV6 (Finch, data-dependent decay) and Mamba.

Block-diffusion semantics for recurrent layers (DESIGN.md §4): the
intra-block denoiser is causal, so

* the *clean* stream runs the ordinary causal recurrence, collecting the
  state at every diffusion-block boundary;
* each *noisy* block re-runs the recurrence from its boundary state
  (vmapped over blocks — exact and parallel).

Projections (r/k/v/w/g, Δ/B/C, convs) are computed for the whole sequence
in parallel outside the scan; only the cheap state recurrences are
sequential.  States are float32 regardless of compute dtype.

State pytrees:
  RWKV6: {"wkv": (B,H,Dk,Dv) f32, "shift": (B,d), "cm_shift": (B,d)}
  Mamba: {"ssm": (B,di,ds) f32, "conv": (B,W-1,di)}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .modules import init_linear, linear, split_like


# ---------------------------------------------------------------------------
# generic block-boundary scan helper
# ---------------------------------------------------------------------------


def scan_with_boundaries(step_scan, state0, xs, n_blocks: int | None):
    """Run ``step_scan(state, xs_block) -> (ys_block, state)`` over the whole
    sequence.  If n_blocks is given, xs are split into that many equal
    time-blocks and the state at the *entry* of each block is emitted.

    xs: pytree with leading (B, T, ...) axes.  Returns (ys, final_state,
    boundary_states | None) where boundary_states has leading (K, ...).
    """
    if n_blocks is None:
        ys, state = step_scan(state0, xs)
        return ys, state, None
    T = jax.tree_util.tree_leaves(xs)[0].shape[1]
    K = n_blocks
    bsz = T // K
    xb = jax.tree.map(
        lambda a: a.reshape(a.shape[0], K, bsz, *a.shape[2:]).swapaxes(0, 1),
        xs)

    def outer(state, xk):
        ys, new_state = step_scan(state, xk)
        return new_state, (ys, state)

    final, (ys, bounds) = jax.lax.scan(outer, state0, xb)
    ys = jax.tree.map(
        lambda a: a.swapaxes(0, 1).reshape(a.shape[1], T, *a.shape[3:]), ys)
    return ys, final, bounds


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


def rwkv6_zero_state(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.d_model // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    return {
        "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "shift": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "cm_shift": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def init_rwkv6(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    r = cfg.lora_rank
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_like(key, ["lora1", "lora2", "wlora1", "wlora2",
                          "wr", "wk", "wv", "wg", "wo"])
    targets = 5  # r, k, v, w, g token-shift deltas
    return {
        "mu_base": jnp.full((d,), 0.5, dt),
        "mu": jnp.full((targets, d), 0.5, dt),
        "lora_w1": init_linear(ks["lora1"], d, targets * r, dtype=dt),
        "lora_w2": (jax.random.normal(ks["lora2"], (targets, r, d),
                                      jnp.float32) * 0.01).astype(dt),
        "w0": jnp.full((d,), -6.0, dt),  # decay bias: exp(-exp(-6)) ~ slow
        "w_lora1": init_linear(ks["wlora1"], d, 64, dtype=dt),
        "w_lora2": init_linear(ks["wlora2"], 64, d, dtype=dt, scale=0.01),
        "u": jnp.zeros((H, dh), dt),     # per-channel bonus
        "wr": init_linear(ks["wr"], d, d, dtype=dt),
        "wk": init_linear(ks["wk"], d, d, dtype=dt),
        "wv": init_linear(ks["wv"], d, d, dtype=dt),
        "wg": init_linear(ks["wg"], d, d, dtype=dt),
        "wo": init_linear(ks["wo"], d, d, dtype=dt),
        "ln_scale": jnp.ones((H, dh), dt),
        "ln_bias": jnp.zeros((H, dh), dt),
    }


def _rwkv6_projections(p, x, shift_in, cfg: ModelConfig):
    """Data-dependent token shift + projections, fully parallel over T.

    x (B,T,d); shift_in (B,d).  Returns (r,k,v,w,g) each (B,T,H,dh) and the
    new shift state (B,d).
    """
    B, T, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    rank = cfg.lora_rank
    shifted = jnp.concatenate([shift_in[:, None, :].astype(x.dtype),
                               x[:, :-1, :]], axis=1)
    xx = shifted - x
    mix_base = x + xx * p["mu_base"].astype(x.dtype)
    lora = jnp.tanh(linear(p["lora_w1"], mix_base))             # (B,T,5r)
    lora = lora.reshape(B, T, 5, rank)
    delta = jnp.einsum("btcr,crd->btcd", lora.astype(jnp.float32),
                       p["lora_w2"].astype(jnp.float32)).astype(x.dtype)
    mu = p["mu"].astype(x.dtype)                                # (5, d)
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (mu + delta)  # (B,T,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = linear(p["wr"], xr).reshape(B, T, H, dh)
    k = linear(p["wk"], xk).reshape(B, T, H, dh)
    v = linear(p["wv"], xv).reshape(B, T, H, dh)
    g = linear(p["wg"], xg).reshape(B, T, H, dh)
    # data-dependent decay (the Finch headline feature)
    w_log = p["w0"].astype(jnp.float32) + linear(
        p["w_lora2"], jnp.tanh(linear(p["w_lora1"], xw))).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, T, H, dh)           # in (0,1)
    return r, k, v, w, g, x[:, -1, :].astype(jnp.float32)


def _wkv_scan(state0, r, k, v, w, u):
    """Linear recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T).   All f32 internally."""
    rf, kf, vf, wf = (a.astype(jnp.float32).swapaxes(0, 1)
                      for a in (r, k, v, w))  # (T,B,H,dh)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[..., :, None] * vt[..., None, :]                # (B,H,dk,dv)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None] [..., :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, y

    S, ys = jax.lax.scan(step, state0, (rf, kf, vf, wf))
    return ys.swapaxes(0, 1), S                                 # (B,T,H,dh)


def rwkv6_forward(p, x, state: dict, cfg: ModelConfig, *,
                  n_blocks: int | None = None):
    """Causal RWKV6 time-mix over x (B,T,d) from ``state``.

    Returns (y (B,T,d), new_state, boundary_states|None).  boundary_states
    (K-leading pytree of {"wkv","shift"}) are the states at each diffusion
    block entry, consumed by the noisy-block re-runs.
    """
    B, T, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    r, k, v, w, g, last_x = _rwkv6_projections(p, x, state["shift"], cfg)
    u = p["u"].astype(jnp.float32)

    def step_scan(S, xs_blk):
        rb, kb, vb, wb = xs_blk
        y, S_new = _wkv_scan(S, rb, kb, vb, wb, u)
        return y, S_new

    ys, S_final, wkv_bounds = scan_with_boundaries(
        step_scan, state["wkv"].astype(jnp.float32), (r, k, v, w), n_blocks)

    # per-head group norm
    yf = ys.astype(jnp.float32)
    mu_ = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu_) * jax.lax.rsqrt(var + 64e-5)
    yn = yn * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)
    y = (yn * jax.nn.silu(g.astype(jnp.float32))).reshape(B, T, d)
    out = linear(p["wo"], y.astype(x.dtype))

    new_state = {"wkv": S_final, "shift": last_x}
    bounds = None
    if n_blocks is not None:
        # shift state at each block entry = last clean token of prev block
        bsz = T // n_blocks
        ends = jnp.concatenate(
            [state["shift"][:, None, :],
             x[:, bsz - 1:T - 1:bsz, :].astype(jnp.float32)], axis=1)
        bounds = {"wkv": wkv_bounds,                       # (K,B,H,dh,dh)
                  "shift": ends.swapaxes(0, 1)}            # (K,B,d)
    return out, new_state, bounds


# ---------------------------------------------------------------------------
# Mamba (jamba's recurrent mixer)
# ---------------------------------------------------------------------------


def mamba_zero_state(cfg: ModelConfig, batch: int) -> dict:
    di = cfg.d_inner
    return {
        "ssm": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32),
    }


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, di, ds, W = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.conv_width
    dt_rank = max(16, d // 16)
    dtp = jnp.dtype(cfg.param_dtype)
    ks = split_like(key, ["in", "conv", "xdt", "dt", "B", "C", "out"])
    return {
        "in_proj": init_linear(ks["in"], d, 2 * di, dtype=dtp),
        "conv_w": (jax.random.normal(ks["conv"], (W, di), jnp.float32)
                   * (W ** -0.5)).astype(dtp),
        "conv_b": jnp.zeros((di,), dtp),
        "w_xdt": init_linear(ks["xdt"], di, dt_rank, dtype=dtp),
        "w_dt": init_linear(ks["dt"], dt_rank, di, dtype=dtp),
        "dt_bias": jnp.full((di,), -4.6, dtp),  # softplus^-1(0.01)
        "w_B": init_linear(ks["B"], di, ds, dtype=dtp),
        "w_C": init_linear(ks["C"], di, ds, dtype=dtp),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(dtp),
        "D": jnp.ones((di,), dtp),
        "out_proj": init_linear(ks["out"], di, d, dtype=dtp),
    }


def mamba_forward(p, x, state: dict, cfg: ModelConfig, *,
                  n_blocks: int | None = None):
    """Causal Mamba over x (B,T,d) from state; same contract as rwkv6."""
    B, T, d = x.shape
    di, ds, W = cfg.d_inner, cfg.d_state, cfg.conv_width
    xz = linear(p["in_proj"], x)
    xb, z = jnp.split(xz, 2, axis=-1)                           # (B,T,di)

    # depthwise causal conv with carried tail
    xpad = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)
    conv_in = xpad.transpose(0, 2, 1)                            # (B,di,T+W-1)
    kern = p["conv_w"].astype(xb.dtype).T[:, None, :]            # (di,1,W)
    xc = jax.lax.conv_general_dilated(
        conv_in, kern, window_strides=(1,), padding="VALID",
        feature_group_count=di)                                  # (B,di,T)
    xc = jax.nn.silu(xc.transpose(0, 2, 1) + p["conv_b"].astype(xb.dtype))

    dt = jax.nn.softplus(
        linear(p["w_dt"], linear(p["w_xdt"], xc)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                      # (B,T,di)
    Bc = linear(p["w_B"], xc).astype(jnp.float32)                # (B,T,ds)
    Cc = linear(p["w_C"], xc).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (di,ds)
    xcf = xc.astype(jnp.float32)

    def step_scan(h, xs_blk):
        dtb, Bb, Cb, xcb = (a.swapaxes(0, 1) for a in xs_blk)    # (t,B,...)

        def step(hs, inp):
            dt_t, B_t, C_t, x_t = inp
            dA = jnp.exp(dt_t[..., None] * A[None])              # (B,di,ds)
            dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]
            h_new = dA * hs + dBx
            y = jnp.einsum("bds,bs->bd", h_new, C_t)
            return h_new, y

        h_new, ys = jax.lax.scan(step, h, (dtb, Bb, Cb, xcb))
        return ys.swapaxes(0, 1), h_new

    ys, h_final, ssm_bounds = scan_with_boundaries(
        step_scan, state["ssm"], (dt, Bc, Cc, xcf), n_blocks)

    y = ys + xcf * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = linear(p["out_proj"], y)

    new_state = {"ssm": h_final,
                 "conv": xpad[:, T:, :].astype(jnp.float32)}
    bounds = None
    if n_blocks is not None:
        bsz = T // n_blocks
        # conv tail entering each block: last W-1 xb values before it
        tails = [xpad[:, k * bsz:k * bsz + W - 1, :].astype(jnp.float32)
                 for k in range(n_blocks)]
        bounds = {"ssm": ssm_bounds,                            # (K,B,di,ds)
                  "conv": jnp.stack(tails, axis=0)}             # (K,B,W-1,di)
    return out, new_state, bounds
