"""Execution context threaded through every layer.

One ``LayerCtx`` describes which of the three execution modes a forward
pass is in and carries the mode's inputs (mask metadata, caches, memory).

modes:
  ``dup``    — duplicated-sequence masked pass (SFT / DiPO logits);
  ``plain``  — committed block-causal pass (prefill; fills caches);
  ``decode`` — current-block denoise step against caches.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.masks import SeqMeta


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerCtx:
    mode: str = dataclasses.field(metadata={"static": True})
    # masked modes
    meta: SeqMeta | None = None
    dup_len: int | None = dataclasses.field(
        default=None, metadata={"static": True})
    strict: bool = dataclasses.field(
        default=False, metadata={"static": True})
    n_blocks: int | None = dataclasses.field(
        default=None, metadata={"static": True})
    # decode mode
    positions: jax.Array | None = None     # (B, n) absolute positions
    cache_limit: jax.Array | None = None   # scalar/(B,): cache pos < limit
    block_table: jax.Array | None = None   # (B, K): paged caches only
    write_cache: bool = dataclasses.field(
        default=False, metadata={"static": True})
    # decode KV layout (models.attention.resolve_kv_layout): "ref" =
    # dense concat / gathered-paged fallback, "pallas" = in-place
    # page-aware kernel on paged caches
    kv_kernel: str = dataclasses.field(
        default="ref", metadata={"static": True})
    # plain mode over paged caches (shared-prefix suffix prefill): read
    # the committed prefix through these pages, commit the computed
    # blocks into ``write_pages``
    context_table: jax.Array | None = None  # (B, Kp) shared prefix pages
    write_pages: jax.Array | None = None    # (B, T // block_size)
    # cross attention
    memory: jax.Array | None = None        # (B, Ne, d_model)
    memory_valid: jax.Array | None = None
    # whether plain mode should also emit per-block boundary states (replay)
    want_boundaries: bool = dataclasses.field(
        default=False, metadata={"static": True})

    @property
    def pos(self) -> jax.Array:
        return self.meta.pos if self.meta is not None else self.positions
