"""BlockDiffLM — the unified block-diffusion language model.

Wraps any assigned backbone (dense / MoE / SSM / hybrid / enc-dec / VLM)
with the paper's block-diffusion post-training semantics.  Three entry
points (see context.LayerCtx):

* ``forward_masked``  — full-sequence masked pass; with ``dup_len`` set it
  is the paper's duplicated-sequence unbiased-logit pass (§4.1), without
  it a committed block-causal pass (prefill — optionally filling caches
  and emitting SSM boundary states for trajectory replay).
* ``decode_step``     — one denoise forward of the current block against
  the caches (serve_step; also the building block of trajectory replay).

Layers are applied in repeating pattern groups via ``lax.scan`` with
optional remat, so 72-layer configs lower with compact HLO.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.masks import SeqMeta
from repro.distributed.ctx import BATCH, shard_hint
from . import attention as attn
from . import ffn as ffn_mod
from . import ssm as ssm_mod
from .config import LayerSpec, ModelConfig, layer_pattern
from .context import LayerCtx
from .modules import (embed, fold_name, init_embedding, init_linear,
                      init_rmsnorm, linear, rmsnorm, softcap, split_like,
                      unembed)

Params = Any


# ---------------------------------------------------------------------------
# token-shift helpers (RWKV channel mix)
# ---------------------------------------------------------------------------


def _shift_plain(x: jax.Array, prev: jax.Array) -> jax.Array:
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]],
                           axis=1)


def _shift_dup(x: jax.Array, L: int, bsz: int) -> jax.Array:
    """Token shift over the duplicated layout: the clean half shifts
    normally; each noisy block's first position shifts from the last clean
    hidden of the previous block."""
    B, T, d = x.shape
    K = L // bsz
    clean, noisy = x[:, :L], x[:, L:]
    zero = jnp.zeros((B, 1, d), x.dtype)
    sh_clean = jnp.concatenate([zero, clean[:, :-1]], axis=1)
    bounds = jnp.concatenate([zero, clean[:, bsz - 1:-1:bsz]], axis=1)
    noisy_b = noisy.reshape(B, K, bsz, d)
    sh_noisy = jnp.concatenate([bounds[:, :, None, :], noisy_b[:, :, :-1]],
                               axis=2).reshape(B, L, d)
    return jnp.concatenate([sh_clean, sh_noisy], axis=1)


def _fold_blocks(x, L, bsz):
    """(B, L, ...) -> (B*K, bsz, ...)"""
    B = x.shape[0]
    K = L // bsz
    return x.reshape(B, K, bsz, *x.shape[2:]).reshape(B * K, bsz,
                                                      *x.shape[2:])


def _unfold_blocks(x, B, L, bsz):
    return x.reshape(B, L // bsz, bsz, *x.shape[2:]).reshape(
        B, L, *x.shape[2:])


def _bounds_to_batch(bounds, B):
    """(K, B, ...) boundary pytree -> (B*K, ...) matching _fold_blocks."""
    return jax.tree.map(
        lambda a: a.swapaxes(0, 1).reshape(B * a.shape[0], *a.shape[2:]),
        bounds)


# ---------------------------------------------------------------------------
# single-layer application
# ---------------------------------------------------------------------------


def _ssm_forward_fn(spec: LayerSpec):
    return ssm_mod.rwkv6_forward if spec.mixer == "rwkv6" \
        else ssm_mod.mamba_forward


def _apply_ssm(cfg: ModelConfig, spec: LayerSpec, lp, h, ctx: LayerCtx,
               cache):
    fwd = _ssm_forward_fn(spec)
    key = "rwkv" if spec.mixer == "rwkv6" else "mamba"
    bsz = cfg.block_size
    if ctx.mode == "dup":
        B = h.shape[0]
        L = ctx.dup_len
        K = L // bsz
        zero = (ssm_mod.rwkv6_zero_state(cfg, B) if spec.mixer == "rwkv6"
                else ssm_mod.mamba_zero_state(cfg, B))
        zero = {k_: v for k_, v in zero.items() if k_ != "cm_shift"}
        y_clean, _, bounds = fwd(lp[key], h[:, :L], zero, cfg, n_blocks=K)
        binst = _bounds_to_batch(bounds, B)
        y_noisy, _, _ = fwd(lp[key], _fold_blocks(h[:, L:], L, bsz),
                            binst, cfg)
        y = jnp.concatenate([y_clean, _unfold_blocks(y_noisy, B, L, bsz)],
                            axis=1)
        return y, cache, None
    if ctx.mode == "plain":
        state = cache if cache is not None else _zero_ssm(cfg, spec,
                                                          h.shape[0])
        nb = h.shape[1] // bsz if ctx.want_boundaries else None
        state_in = {k_: v for k_, v in state.items() if k_ != "cm_shift"}
        y, new_state, bounds = fwd(lp[key], h, state_in, cfg, n_blocks=nb)
        if cache is not None and "cm_shift" in cache:
            new_state["cm_shift"] = cache["cm_shift"]
        return y, (new_state if cache is not None else cache), bounds
    # decode: run the block from the committed state
    state_in = {k_: v for k_, v in cache.items() if k_ != "cm_shift"}
    y, new_state, _ = fwd(lp[key], h, state_in, cfg)
    if ctx.write_cache:
        if "cm_shift" in cache:
            new_state["cm_shift"] = cache["cm_shift"]
        return y, new_state, None
    return y, cache, None


def _zero_ssm(cfg, spec, batch):
    return (ssm_mod.rwkv6_zero_state(cfg, batch) if spec.mixer == "rwkv6"
            else ssm_mod.mamba_zero_state(cfg, batch))


def _apply_mixer(cfg: ModelConfig, spec: LayerSpec, lp, h, ctx: LayerCtx,
                 cache):
    """Returns (y, new_cache, boundaries|None)."""
    if spec.mixer == "attn":
        masked_fn = attn.mla_masked if cfg.attn_kind == "mla" \
            else attn.gqa_masked
        decode_fn = attn.mla_decode if cfg.attn_kind == "mla" \
            else attn.gqa_decode
        if ctx.mode in ("dup", "plain"):
            if ctx.mode == "plain" and \
                    isinstance(cache, attn.PagedAttnCache):
                # shared-prefix suffix prefill: committed pass reading
                # the prefix through pages, committing into fresh pages
                paged_fn = attn.mla_plain_paged if cfg.attn_kind == "mla" \
                    else attn.gqa_plain_paged
                y, new_cache = paged_fn(
                    lp["attn"], h, ctx.meta, cache, cfg,
                    window=spec.window, context_table=ctx.context_table,
                    write_pages=ctx.write_pages, kernel=ctx.kv_kernel)
                return y, new_cache, None
            y, k, v = masked_fn(lp["attn"], h, ctx.meta, cfg,
                                window=spec.window, dup_len=ctx.dup_len,
                                strict=ctx.strict)
            new_cache = cache
            if cache is not None and ctx.mode == "plain":
                new_cache = attn.write_prefill_cache(cache, k, v,
                                                     ctx.meta.pos)
            return y, new_cache, None
        y, new_cache = decode_fn(lp["attn"], h, ctx.positions, cache, cfg,
                                 window=spec.window,
                                 write_cache=ctx.write_cache,
                                 cache_limit=ctx.cache_limit,
                                 block_table=ctx.block_table,
                                 kernel=ctx.kv_kernel)
        return y, new_cache, None
    if spec.mixer in ("rwkv6", "mamba"):
        return _apply_ssm(cfg, spec, lp, h, ctx, cache)
    if spec.mixer == "cross_attn":
        y = attn.cross_attn(lp["cross"], h, ctx.memory, cfg,
                            ctx.memory_valid)
        return y, cache, None
    raise ValueError(spec.mixer)


def _apply_ffn(cfg: ModelConfig, spec: LayerSpec, lp, h, ctx: LayerCtx,
               cache):
    """Returns (y, new_cache, aux_loss, boundaries|None)."""
    zero = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        return ffn_mod.swiglu(lp["ffn"], h, act=cfg.act), cache, zero, None
    if spec.ffn == "moe":
        y, aux = ffn_mod.moe(lp["moe"], h, cfg)
        return y, cache, aux["aux_loss"], None
    if spec.ffn == "rwkv_cm":
        if ctx.mode == "dup":
            shifted = _shift_dup(h, ctx.dup_len, cfg.block_size)
            y = ffn_mod.rwkv_cm(lp["cm"], h, shifted)
            return y, cache, zero, None
        prev = cache["cm_shift"] if (cache is not None and
                                     "cm_shift" in cache) \
            else jnp.zeros((h.shape[0], h.shape[-1]), h.dtype)
        shifted = _shift_plain(h, prev)
        y = ffn_mod.rwkv_cm(lp["cm"], h, shifted)
        new_cache = cache
        if cache is not None and (ctx.mode == "plain" or ctx.write_cache):
            new_cache = dict(cache)
            new_cache["cm_shift"] = h[:, -1, :].astype(jnp.float32)
        bounds = None
        if ctx.mode == "plain" and ctx.want_boundaries:
            bsz = cfg.block_size
            cm_b = jnp.concatenate(
                [prev[:, None, :].astype(jnp.float32),
                 h[:, bsz - 1:-1:bsz, :].astype(jnp.float32)], axis=1)
            bounds = {"cm_shift": cm_b.swapaxes(0, 1)}   # (K, B, d)
        return y, new_cache, zero, bounds
    raise ValueError(spec.ffn)


def _apply_layer(cfg: ModelConfig, spec: LayerSpec, lp, x, ctx: LayerCtx,
                 cache):
    """Pre-norm residual layer.  Returns (x, new_cache, aux, boundaries)."""
    h = rmsnorm(lp["attn_norm"], x, eps=cfg.norm_eps)
    y, new_cache, bounds = _apply_mixer(cfg, spec, lp, h, ctx, cache)
    if cfg.sandwich_norm:
        y = rmsnorm(lp["post_attn_norm"], y, eps=cfg.norm_eps)
    x = x + shard_hint(y, BATCH, None, None)

    if spec.cross and ctx.memory is not None:
        hc = rmsnorm(lp["cross_norm"], x, eps=cfg.norm_eps)
        x = x + attn.cross_attn(lp["cross"], hc, ctx.memory, cfg,
                                ctx.memory_valid)

    h = rmsnorm(lp["ffn_norm"], x, eps=cfg.norm_eps)
    y, new_cache, aux, ffn_bounds = _apply_ffn(cfg, spec, lp, h, ctx,
                                               new_cache)
    if cfg.sandwich_norm:
        y = rmsnorm(lp["post_ffn_norm"], y, eps=cfg.norm_eps)
    x = x + shard_hint(y, BATCH, None, None)
    # dict|None truthiness: pytree *structure*, static under jit
    if ffn_bounds:  # dirlint: ok(trace-branch)
        bounds = {**(bounds or {}), **ffn_bounds}
    return x, new_cache, aux, bounds


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = split_like(key, ["mixer", "cross", "ffn"])
    p: dict = {"attn_norm": init_rmsnorm(d, dtype=dt),
               "ffn_norm": init_rmsnorm(d, dtype=dt)}
    if cfg.sandwich_norm:
        p["post_attn_norm"] = init_rmsnorm(d, dtype=dt)
        p["post_ffn_norm"] = init_rmsnorm(d, dtype=dt)

    if spec.mixer == "attn":
        p["attn"] = attn.init_mla(ks["mixer"], cfg) \
            if cfg.attn_kind == "mla" else attn.init_gqa(ks["mixer"], cfg)
    elif spec.mixer == "rwkv6":
        p["rwkv"] = ssm_mod.init_rwkv6(ks["mixer"], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks["mixer"], cfg)
    elif spec.mixer == "cross_attn":
        p["cross"] = attn.init_cross(ks["mixer"], cfg, gated=True)

    if spec.cross:
        p["cross_norm"] = init_rmsnorm(d, dtype=dt)
        p["cross"] = attn.init_cross(ks["cross"], cfg, gated=False)

    if spec.ffn == "dense":
        f = spec.d_ff or cfg.d_ff
        p["ffn"] = ffn_mod.init_swiglu(ks["ffn"], d, f, dtype=dt)
    elif spec.ffn == "moe":
        p["moe"] = ffn_mod.init_moe(ks["ffn"], cfg)
    elif spec.ffn == "rwkv_cm":
        p["cm"] = ffn_mod.init_rwkv_cm(ks["ffn"], d, cfg.d_ff, dtype=dt)
    return p


def _layer_cache_struct(cfg: ModelConfig, spec: LayerSpec, batch: int,
                        cache_len: int, ring: bool = True):
    dt = jnp.dtype(cfg.dtype)
    if spec.mixer == "attn":
        S = min(cache_len, spec.window) if (spec.window and ring) \
            else cache_len
        if cfg.attn_kind == "mla":
            return attn.make_attn_cache(
                batch, S, 1, cfg.kv_lora_rank + cfg.qk_rope_dim,
                cfg.kv_lora_rank, dt)
        return attn.make_attn_cache(batch, S, cfg.n_kv_heads,
                                    cfg.resolved_head_dim,
                                    cfg.resolved_head_dim, dt)
    if spec.mixer == "rwkv6":
        return ssm_mod.rwkv6_zero_state(cfg, batch)
    if spec.mixer == "mamba":
        st = ssm_mod.mamba_zero_state(cfg, batch)
        return st
    return None  # cross_attn layers keep no cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class BlockDiffLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.prefix_specs, self.group_specs, self.n_groups = \
            layer_pattern(cfg)

    # ------------------------------------------------------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = split_like(key, ["embed", "prefix", "groups", "head", "proj",
                              "enc"])
        params: dict = {
            "embed": init_embedding(ks["embed"], cfg.vocab_size, cfg.d_model,
                                    dtype=dt),
            "final_norm": init_rmsnorm(cfg.d_model, dtype=dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_linear(ks["head"], cfg.d_model,
                                            cfg.vocab_size, dtype=dt)
        if self.prefix_specs:
            pk = jax.random.split(ks["prefix"], len(self.prefix_specs))
            params["prefix"] = {
                f"l{i}": _init_layer(pk[i], cfg, s)
                for i, s in enumerate(self.prefix_specs)}

        def init_group(gkey):
            lk = jax.random.split(gkey, len(self.group_specs))
            return {f"l{j}": _init_layer(lk[j], cfg, s)
                    for j, s in enumerate(self.group_specs)}

        gkeys = jax.random.split(ks["groups"], self.n_groups)
        params["groups"] = jax.vmap(init_group)(gkeys)

        if cfg.n_extra_tokens:
            params["projector"] = init_linear(
                ks["proj"], cfg.extra_embed_dim or cfg.d_model, cfg.d_model,
                dtype=dt)
        if cfg.encoder_layers:
            enc_cfg = cfg.replace(arch_type="dense", n_layers=cfg.encoder_layers,
                                  n_experts=0, first_k_dense=0,
                                  sliding_window=0, local_global=False)
            enc_spec = enc_cfg.layer_spec(0)

            def init_enc(gkey):
                return {"l0": _init_layer(gkey, enc_cfg, enc_spec)}

            ekeys = jax.random.split(ks["enc"], cfg.encoder_layers)
            params["encoder"] = {
                "groups": jax.vmap(init_enc)(ekeys),
                "final_norm": init_rmsnorm(cfg.d_model, dtype=dt),
            }
        return params

    # --------------------------------------------------------- plumbing
    def _embed(self, params, ids):
        x = embed(params["embed"], ids, dtype=jnp.dtype(self.cfg.dtype))
        if self.cfg.embed_scale:
            x = x * jnp.asarray(self.cfg.d_model ** 0.5, x.dtype)
        return shard_hint(x, BATCH, None, None)

    def _logits(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = linear(params["lm_head"], x,
                            dtype=jnp.float32)
        if cfg.final_logit_softcap:
            logits = softcap(logits, cfg.final_logit_softcap)
        return shard_hint(logits, BATCH, None, "model")

    def _run_stack(self, params, x, ctx: LayerCtx, caches):
        """prefix layers then scanned groups.

        caches: {"prefix": {...}|None, "groups": stacked-G pytree|None}.
        Returns (x, new_caches, aux_sum, boundaries).
        """
        cfg = self.cfg
        aux_sum = jnp.zeros((), jnp.float32)
        new_prefix = {}
        prefix_bounds = {}
        for i, spec in enumerate(self.prefix_specs):
            c = None if caches is None else caches["prefix"][f"l{i}"]
            x, nc, aux, bd = _apply_layer(cfg, spec,
                                          params["prefix"][f"l{i}"], x,
                                          ctx, c)
            new_prefix[f"l{i}"] = nc
            prefix_bounds[f"l{i}"] = bd
            aux_sum = aux_sum + aux

        gcaches = None if caches is None else caches["groups"]

        def body(carry, xs):
            x, aux_acc = carry
            x = shard_hint(x, BATCH, None, None)
            gp, gc = xs
            new_gc = {}
            bnds = {}
            for j, spec in enumerate(self.group_specs):
                c = None if gc is None else gc[f"l{j}"]
                x, nc, aux, bd = _apply_layer(cfg, spec, gp[f"l{j}"], x,
                                              ctx, c)
                new_gc[f"l{j}"] = nc
                bnds[f"l{j}"] = bd
                aux_acc = aux_acc + aux
            return (x, aux_acc), (new_gc, bnds)

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        (x, aux_sum), (new_gcaches, gbounds) = jax.lax.scan(
            body, (x, aux_sum), (params["groups"], gcaches))

        new_caches = None
        if caches is not None:
            new_caches = {"prefix": new_prefix, "groups": new_gcaches}
        bounds = {"prefix": prefix_bounds, "groups": gbounds}
        return x, new_caches, aux_sum, bounds

    # ------------------------------------------------------ public API
    def compute_memory(self, params, extra_embeds, extra_valid=None):
        """Project (and for enc-dec, encode) modality-frontend embeddings."""
        cfg = self.cfg
        if extra_embeds is None:
            return None
        mem = linear(params["projector"],
                     extra_embeds.astype(jnp.dtype(cfg.dtype)))
        if cfg.encoder_layers:
            B, Ne, _ = mem.shape
            # bidirectional: all positions share block 0
            meta = SeqMeta(copy=jnp.zeros((B, Ne), jnp.int32),
                           block=jnp.zeros((B, Ne), jnp.int32),
                           step=jnp.zeros((B, Ne), jnp.int32),
                           pos=jnp.broadcast_to(
                               jnp.arange(Ne, dtype=jnp.int32), (B, Ne)),
                           valid=(extra_valid if extra_valid is not None
                                  else jnp.ones((B, Ne), bool)))
            ctx = LayerCtx(mode="plain", meta=meta)
            enc_cfg = cfg.replace(arch_type="dense",
                                  n_layers=cfg.encoder_layers, n_experts=0,
                                  first_k_dense=0, sliding_window=0,
                                  local_global=False)
            enc_spec = enc_cfg.layer_spec(0)

            def body(carry, gp):
                h, _ = carry
                h, _, _, _ = _apply_layer(enc_cfg, enc_spec, gp["l0"], h,
                                          ctx, None)
                return (h, 0.0), None

            (x, _), _ = jax.lax.scan(
                body, (mem, 0.0), params["encoder"]["groups"])
            mem = rmsnorm(params["encoder"]["final_norm"], x,
                          eps=cfg.norm_eps)
        return mem

    def forward_masked(self, params, input_ids, meta: SeqMeta, *,
                       dup_len: int | None = None, strict: bool = False,
                       memory=None, memory_valid=None, caches=None,
                       want_boundaries: bool = False,
                       logits_from: int | None = None):
        """Masked full-sequence forward.

        ``logits_from``: unembed only positions [logits_from:] — on
        duplicated layouts the clean copy never carries loss, and at a
        256k vocab skipping its logits halves the biggest activation of
        the train step.

        Returns (logits, {"aux_loss", "caches", "boundaries"}).
        """
        ctx = LayerCtx(mode="dup" if dup_len is not None else "plain",
                       meta=meta, dup_len=dup_len, strict=strict,
                       memory=memory, memory_valid=memory_valid,
                       want_boundaries=want_boundaries)
        x = self._embed(params, input_ids)
        x, new_caches, aux, bounds = self._run_stack(params, x, ctx, caches)
        if logits_from is not None:
            x = x[:, logits_from:]
        logits = self._logits(params, x)
        return logits, {"aux_loss": aux, "caches": new_caches,
                        "boundaries": bounds}

    def decode_step(self, params, block_ids, positions, caches, *,
                    cache_limit=None, block_table=None, memory=None,
                    memory_valid=None, write: bool = False,
                    kv_kernel: str = "ref"):
        """One denoise forward of the current block (serve_step).

        block_ids/positions: (B, block_size).  Returns (logits, caches).
        ``block_table`` (B, K) is required iff the attention caches are
        paged (``make_paged_caches``); dense caches ignore it.
        ``kv_kernel`` picks the decode KV layout (attention.
        resolve_kv_layout): ``"ref"`` = dense concat / gathered-paged
        fallback, ``"pallas"`` = the in-place page-aware kernel.
        """
        ctx = LayerCtx(mode="decode", positions=positions,
                       cache_limit=cache_limit, block_table=block_table,
                       write_cache=write, kv_kernel=kv_kernel,
                       memory=memory, memory_valid=memory_valid)
        x = self._embed(params, block_ids)
        x, new_caches, _, _ = self._run_stack(params, x, ctx, caches)
        logits = self._logits(params, x)
        return logits, new_caches

    def prefill_suffix(self, params, suffix_ids, meta: SeqMeta, caches, *,
                       context_table, write_pages,
                       kv_kernel: str = "ref"):
        """Committed pass over a prompt suffix through paged caches.

        ``suffix_ids`` (B, T) with ``meta`` carrying *absolute*
        positions; attention layers read the already-committed prefix
        through ``context_table`` (B, Kp) shared pages and commit the
        suffix blocks into ``write_pages`` (B, T // block_size).  Skips
        the logits (prefill only needs caches).  Attention-only stacks:
        recurrent layers carry per-slot state that pages cannot share
        (the scheduler gates prefix caching off for them).

        ``kv_kernel`` picks the prefill KV layout (attention.
        resolve_kv_layout): ``"ref"`` gathers the hit-prefix pages into
        a dense-width copy once per admission, ``"pallas"`` streams
        them in place (``kernels.paged_attn.paged_prefill_attention``),
        so admission pays zero transient KV bytes.  Both produce
        bitwise-identical suffix KV.
        """
        ctx = LayerCtx(mode="plain", meta=meta,
                       context_table=context_table,
                       write_pages=write_pages, kv_kernel=kv_kernel)
        x = self._embed(params, suffix_ids)
        _, new_caches, _, _ = self._run_stack(params, x, ctx, caches)
        return new_caches

    def make_caches(self, batch: int, cache_len: int, *,
                    ring: bool = True):
        """Zero caches for ``batch`` sequences with ``cache_len`` capacity.

        ``ring=True`` bounds sliding-window layers' buffers to the window
        (correct for sequential serving, where only the last W committed
        keys are live).  Pass ``ring=False`` for replay-style random
        access over a fully prefilled sequence (every block revisited).
        """
        prefix = {f"l{i}": _layer_cache_struct(self.cfg, s, batch,
                                               cache_len, ring)
                  for i, s in enumerate(self.prefix_specs)}
        one = {f"l{j}": _layer_cache_struct(self.cfg, s, batch, cache_len,
                                            ring)
               for j, s in enumerate(self.group_specs)}
        return {"prefix": prefix, "groups": self._stack_groups(one)}

    def make_paged_caches(self, batch: int, n_pages: int):
        """Paged decode caches for ``batch`` slots over ``n_pages`` pages.

        Attention layers get a shared ``PagedAttnCache`` pool of
        block-size pages (page 0 is the null page — the allocator must
        never hand it out); recurrent/conv states are O(1) per sequence
        and stay per-slot exactly as in ``make_caches``.  Reads/writes go
        through the (batch, n_blocks) block table in ``GenState.table``.
        """
        prefix = {f"l{i}": self._paged_layer_cache_struct(s, batch, n_pages)
                  for i, s in enumerate(self.prefix_specs)}
        one = {f"l{j}": self._paged_layer_cache_struct(s, batch, n_pages)
               for j, s in enumerate(self.group_specs)}
        return {"prefix": prefix, "groups": self._stack_groups(one)}

    def _paged_layer_cache_struct(self, spec: LayerSpec, batch: int,
                                  n_pages: int):
        cfg = self.cfg
        if spec.mixer == "attn":
            dt = jnp.dtype(cfg.dtype)
            if cfg.attn_kind == "mla":
                return attn.make_paged_attn_cache(
                    n_pages, cfg.block_size, 1,
                    cfg.kv_lora_rank + cfg.qk_rope_dim, cfg.kv_lora_rank,
                    dt)
            return attn.make_paged_attn_cache(
                n_pages, cfg.block_size, cfg.n_kv_heads,
                cfg.resolved_head_dim, cfg.resolved_head_dim, dt)
        # recurrent / conv / no-cache layers: per-slot, unchanged
        return _layer_cache_struct(cfg, spec, batch, cfg.block_size)

    def _stack_groups(self, one):
        """Stack a single group's cache struct G times (pos sentinel
        preserved)."""
        groups = jax.tree.map(
            lambda a: jnp.zeros((self.n_groups,) + a.shape, a.dtype), one)
        # restore pos = -1 sentinel
        groups = jax.tree.map(
            lambda z, o: jnp.broadcast_to(o[None], z.shape).astype(z.dtype)
            if o.dtype == jnp.int32 else z, groups, one)
        return groups

    def param_count(self, params) -> int:
        return sum(p.size for p in jax.tree_util.tree_leaves(params))
