"""Attention sublayers: GQA (+SWA, softcap), absorbed MLA, cross-attention.

Every mixer supports three execution modes (see model.py):

* ``dup``    — one fused pass over the duplicated sequence under the
               block-diffusion mask (the paper's §4.1 fast path);
* ``plain``  — committed-context (block-causal) pass; optionally fills the
               KV cache (prefill / block commit);
* ``decode`` — current-block queries against (cache ++ self-block) keys,
               the inference denoise step.

KV caches store *rotated* keys with explicit position ids so sliding-window
ring buffers and sequence-sharded caches need no extra bookkeeping:
``pos < 0`` marks unfilled slots.

Every paged-KV attention pass — per-step decode *and* admission-time
suffix prefill — dispatches through one **KV-layout object**
(``resolve_kv_layout``), the strategy that decides how a layer's
cached keys reach the attention math:

* ``dense``     (``AttnCache``) — every sequence owns a contiguous
                (S, ...) region (prefill, replay, one-shot generate,
                and the scheduler's ``cache="dense"``); decode
                concatenates (cache ++ self) and runs the masked
                reference.
* ``gathered``  (``PagedAttnCache``, ``kernel="ref"``) — the shared
                page pool is gathered through the per-sequence block
                table into a dense-width copy; decode runs the *same*
                concat path as ``dense`` and suffix prefill runs the
                full-prefill chunked kernel over (gathered prefix ++
                suffix) keys — the portable fallback, byte-identical
                to the dense paths by construction.
* ``paged``     (``PagedAttnCache``, ``kernel="pallas"``) — the
                ``kernels.paged_attn`` family reads the pool
                **in place**: ``paged_decode_attention`` for the
                denoise step and ``paged_prefill_attention`` for the
                shared-prefix suffix prefill, each streaming one page
                per grid step via the scalar-prefetched block table.
                No dense-width K/V copy is ever materialized, so
                transient decode memory stops scaling with
                slots x K*bsz and admission-time transient bytes drop
                to zero (off-TPU the kernels run under
                ``interpret=True``, so CPU CI exercises the real
                path; sub-tile page shapes are zero-padded to the
                (8, 128) f32 tile so real TPUs stay on the compiled
                path — see ``kernels.paged_attn.plan_exec``).

All layouts implement the same masking contract — null page 0,
``pos = -1`` empty slots, per-row ``cache_limit``, sliding window, and
the MLA latent-MQA form — and produce byte-identical decode tokens and
suffix-prefill activations (tests/test_paged_attn.py).
``transient_kv_bytes`` quantifies the per-decode-step copy each layout
pays and ``prefill_transient_kv_bytes`` the admission-time gather
width (both 0 for the in-place kernels); ``kernel_exec_plan`` reports
whether the Pallas path would compile or interpret on this backend,
and why.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.masks import SeqMeta, visibility
from repro.kernels import ops as kops
from repro.kernels.ref import mha_reference, NEG_INF
from .config import ModelConfig
from .modules import apply_rope, init_linear, linear, rmsnorm, split_like


class AttnCache(NamedTuple):
    k: jax.Array    # (B, S, Hkv, Dk) rotated
    v: jax.Array    # (B, S, Hkv, Dv)
    pos: jax.Array  # (B, S) int32, -1 = empty


class PagedAttnCache(NamedTuple):
    """A shared pool of ``block_size``-token KV pages (vLLM-style).

    Sequences do not own contiguous cache rows; a per-sequence *block
    table* (carried in ``GenState.table``, shape (B, n_blocks)) maps each
    sequence's block index to the page holding its keys.  Table entry -1
    means "no page": reads of such blocks are masked invalid and writes
    are dumped into page 0 — the *null page*, which an allocator must
    never hand out and whose ``pos`` is forced to -1 on every dump so it
    can never leak into attention.

    Pages store rotated keys with *absolute* position ids, so a prompt
    page is content-addressed: any sequence whose prompt contains the
    same tokens at the same positions can map the page into its table
    and read it verbatim (``serving.prefix_cache``).  Shared pages are
    read-only by construction — a live sequence's commit cursor never
    re-enters its prompt region, and evicted slots dump their idempotent
    re-commits into the null page — so sharing needs refcounts but no
    copy-on-write.
    """
    k: jax.Array    # (P, bsz, Hkv, Dk) rotated
    v: jax.Array    # (P, bsz, Hkv, Dv)
    pos: jax.Array  # (P, bsz) int32, -1 = empty


def make_attn_cache(batch: int, seq: int, n_kv: int, dk: int, dv: int,
                    dtype) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, seq, n_kv, dk), dtype),
        v=jnp.zeros((batch, seq, n_kv, dv), dtype),
        pos=jnp.full((batch, seq), -1, jnp.int32))


def make_paged_attn_cache(n_pages: int, block_size: int, n_kv: int,
                          dk: int, dv: int, dtype) -> PagedAttnCache:
    return PagedAttnCache(
        k=jnp.zeros((n_pages, block_size, n_kv, dk), dtype),
        v=jnp.zeros((n_pages, block_size, n_kv, dv), dtype),
        pos=jnp.full((n_pages, block_size), -1, jnp.int32))


def paged_gather(cache: PagedAttnCache, table: jax.Array):
    """Gather each sequence's pages into key order.

    table (B, K) int32 -> (k, v, pos) with a (B, K*bsz, ...) layout that
    matches a dense full-length cache row block-for-block; unallocated
    blocks (table -1) read the null page with ``pos`` forced to -1, so
    the ordinary pos-validity mask hides them.

    This materializes a dense-width K/V copy, so it survives only where
    that is cheap or unavoidable: the ``kernel="ref"`` decode fallback
    (portability / parity oracle) and the shared-prefix suffix prefill
    (admission-time one-off whose gather width is just the hit prefix).
    The per-step decode path reads the pool in place instead
    (``kernels.paged_attn`` via ``resolve_kv_layout``).
    """
    B, K = table.shape
    idx = jnp.maximum(table, 0)                    # -1 -> null page 0
    k, v, pos = cache.k[idx], cache.v[idx], cache.pos[idx]
    pos = jnp.where(table[:, :, None] >= 0, pos, -1)
    bsz = cache.k.shape[1]
    return (k.reshape(B, K * bsz, *cache.k.shape[2:]),
            v.reshape(B, K * bsz, *cache.v.shape[2:]),
            pos.reshape(B, K * bsz))


def paged_cache_write(cache: PagedAttnCache, k: jax.Array, v: jax.Array,
                      positions: jax.Array,
                      table: jax.Array) -> PagedAttnCache:
    """Commit one block-aligned block per sequence into its own page.

    ``positions`` (B, bsz) must cover exactly one block per row.  Rows
    whose block has no page (table -1 — e.g. an evicted slot idempotently
    re-committing its frozen block) are dumped into the null page with
    ``pos`` = -1, so they can never corrupt a live sequence's page.
    """
    bsz = cache.k.shape[1]
    rows = jnp.arange(k.shape[0], dtype=jnp.int32)
    page = table[rows, positions[:, 0] // bsz]     # (B,)
    safe = jnp.maximum(page, 0)
    pos_w = jnp.where(page[:, None] >= 0, positions.astype(jnp.int32), -1)
    return PagedAttnCache(
        k=cache.k.at[safe].set(k.astype(cache.k.dtype)),
        v=cache.v.at[safe].set(v.astype(cache.v.dtype)),
        pos=cache.pos.at[safe].set(pos_w))


def write_prompt_pages(cache: PagedAttnCache, row: AttnCache,
                       pages: jax.Array) -> PagedAttnCache:
    """Scatter a B=1 dense prefill row into freshly allocated pages.

    ``row`` leaves are (1, L, ...) with L a block multiple (a ring-free
    prefill); ``pages`` (Kp,) holds the page ids for the first Kp blocks.
    """
    bsz = cache.k.shape[1]
    Kp = pages.shape[0]

    def blocks(a):
        L = a.shape[1]
        return a.reshape(L // bsz, bsz, *a.shape[2:])[:Kp]

    return PagedAttnCache(
        k=cache.k.at[pages].set(blocks(row.k).astype(cache.k.dtype)),
        v=cache.v.at[pages].set(blocks(row.v).astype(cache.v.dtype)),
        pos=cache.pos.at[pages].set(blocks(row.pos)))


def write_prompt_pages_grouped(cache: PagedAttnCache, row: AttnCache,
                               pages: jax.Array) -> PagedAttnCache:
    """``write_prompt_pages`` for G-stacked group caches: pool leaves are
    (G, P, bsz, ...) and the prefill row's are (G, 1, L, ...)."""
    bsz = cache.k.shape[2]
    Kp = pages.shape[0]

    def blocks(a):
        G, _, L = a.shape[:3]
        return a.reshape(G, L // bsz, bsz, *a.shape[3:])[:, :Kp]

    return PagedAttnCache(
        k=cache.k.at[:, pages].set(blocks(row.k).astype(cache.k.dtype)),
        v=cache.v.at[:, pages].set(blocks(row.v).astype(cache.v.dtype)),
        pos=cache.pos.at[:, pages].set(blocks(row.pos)))


def write_suffix_pages(cache: PagedAttnCache, k: jax.Array, v: jax.Array,
                       positions: jax.Array,
                       pages: jax.Array) -> PagedAttnCache:
    """Commit block-aligned suffix K/V into per-row pages.

    k/v (B, T, ...), positions (B, T) with T a block multiple; ``pages``
    (B, T // bsz) holds each row's freshly allocated page ids (the
    shared-prefix *suffix* of its prompt).  Unlike ``paged_cache_write``
    this writes several blocks per row in one shot and has no null-page
    escape: suffix pages are always freshly allocated.
    """
    bsz = cache.k.shape[1]
    B, T = positions.shape
    Ks = T // bsz

    def blocks(a):
        return a.reshape(B, Ks, bsz, *a.shape[2:]).reshape(
            B * Ks, bsz, *a.shape[2:])

    idx = pages.reshape(-1)
    return PagedAttnCache(
        k=cache.k.at[idx].set(blocks(k).astype(cache.k.dtype)),
        v=cache.v.at[idx].set(blocks(v).astype(cache.v.dtype)),
        pos=cache.pos.at[idx].set(blocks(positions.astype(jnp.int32))))


def wipe_pages(cache: PagedAttnCache, pages: jax.Array, *,
               grouped: bool) -> PagedAttnCache:
    """Force ``pos = -1`` on ``pages`` (free-list / reclaim hygiene).

    A page leaving the prefix index or returning to the free list must
    look empty until its next owner writes it: stale positions could
    otherwise pass the ``pos < cache_limit`` validity mask of a page
    that is table-mapped before it is first written.
    """
    pos = cache.pos.at[:, pages].set(-1) if grouped \
        else cache.pos.at[pages].set(-1)
    return cache._replace(pos=pos)


def _paged_context_kv(cache: PagedAttnCache, context_table: jax.Array,
                      k_self: jax.Array, v_self: jax.Array, meta: SeqMeta,
                      block_size: int):
    """(keys, vals, k_meta) = gathered shared-prefix pages ++ suffix self.

    The gather width is exactly the hit prefix (``context_table`` has no
    -1 padding), so the combined key array reproduces the full-prefill
    key layout byte-for-byte: prefix keys at [0, Kp*bsz), suffix keys
    after, no interleaved invalid slots.  That layout equality is what
    makes the chunked kernel's chunk boundaries — and therefore its
    bits — match the full plain pass (see core.decoding.prefill_suffix).
    """
    ck, cv, cpos = paged_gather(cache, context_table)
    keys = jnp.concatenate([ck.astype(k_self.dtype), k_self], axis=1)
    vals = jnp.concatenate([cv.astype(v_self.dtype), v_self], axis=1)
    cvalid = cpos >= 0
    cmeta = SeqMeta(copy=jnp.zeros(cpos.shape, jnp.int32),
                    block=jnp.where(cvalid, cpos // block_size, -1),
                    step=jnp.zeros(cpos.shape, jnp.int32),
                    pos=cpos, valid=cvalid)
    k_meta = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=-1),
                          cmeta, meta)
    return keys, vals, k_meta


def cache_write(cache: AttnCache, k: jax.Array, v: jax.Array,
                positions: jax.Array) -> AttnCache:
    """Write a block of (rotated) keys at ``positions`` (B, n).

    Full caches write at index == position; ring caches (S < max positions)
    write at position % S — both are the same modulo op.
    """
    S = cache.k.shape[1]
    idx = positions % S  # (B, n)
    bidx = jnp.arange(k.shape[0], dtype=jnp.int32)[:, None]
    return AttnCache(
        k=cache.k.at[bidx, idx].set(k.astype(cache.k.dtype)),
        v=cache.v.at[bidx, idx].set(v.astype(cache.v.dtype)),
        pos=cache.pos.at[bidx, idx].set(positions.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_like(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": init_linear(ks["wq"], d, H * Dh, dtype=dt),
        "wk": init_linear(ks["wk"], d, Hkv * Dh, dtype=dt),
        "wv": init_linear(ks["wv"], d, Hkv * Dh, dtype=dt),
        "wo": init_linear(ks["wo"], H * Dh, d, dtype=dt),
    }


def _gqa_scale(cfg: ModelConfig) -> float:
    return cfg.query_scale or cfg.resolved_head_dim ** -0.5


def gqa_qkv(p, x, positions, cfg: ModelConfig):
    B, T, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(B, T, H, Dh)
    k = linear(p["wk"], x).reshape(B, T, Hkv, Dh)
    v = linear(p["wv"], x).reshape(B, T, Hkv, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_masked(p, x, meta: SeqMeta, cfg: ModelConfig, *,
               window: int | None, dup_len: int | None,
               strict: bool = False
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """dup / plain modes: mask comes from SeqMeta.

    Returns (out, k, v) so prefill can write the cache."""
    B, T, _ = x.shape
    q, k, v = gqa_qkv(p, x, meta.pos, cfg)
    softcap = cfg.attn_logit_softcap or None
    o = kops.attention(
        q, k, v, meta, meta,
        impl=cfg.attn_impl,
        scale=_gqa_scale(cfg), softcap=softcap, window=window,
        strict=strict, dup_len=dup_len, block_size=cfg.block_size)
    return linear(p["wo"], o.reshape(B, T, -1)), k, v


def gqa_plain_paged(p, x, meta: SeqMeta, cache: PagedAttnCache,
                    cfg: ModelConfig, *, window: int | None,
                    context_table: jax.Array, write_pages: jax.Array,
                    kernel: str = "ref"
                    ) -> tuple[jax.Array, PagedAttnCache]:
    """Plain committed pass over a prompt *suffix* against shared pages.

    ``x``/``meta`` cover only the suffix rows (absolute positions);
    attention keys are the shared-prefix pages behind ``context_table``
    followed by the suffix's own K/V — the same key layout and masking
    as the full plain pass, so the computed suffix KV (committed into
    ``write_pages``) is bitwise identical to what a full prefill would
    have produced (when the cache dtype equals the activation dtype;
    see core.decoding.prefill_suffix).  ``kernel`` picks how the prefix
    pages are read: ``"ref"`` gathers them into a dense-width copy,
    ``"pallas"`` streams them in place (``paged_prefill_attention``),
    eliminating the admission-time transient.
    """
    B, T, _ = x.shape
    q, k, v = gqa_qkv(p, x, meta.pos, cfg)
    o = resolve_kv_layout(cache, kernel).prefill_attend(
        q, k, v, meta, cache,
        context_table=context_table, block_size=cfg.block_size,
        impl=cfg.attn_impl, scale=_gqa_scale(cfg),
        softcap=cfg.attn_logit_softcap or None, window=window)
    new_cache = write_suffix_pages(cache, k, v, meta.pos, write_pages)
    return linear(p["wo"], o.reshape(B, T, -1)), new_cache


def _cache_decode_attention(q, keys, vals, key_pos, key_valid, q_pos, *,
                            scale, softcap, window):
    """q (B,n,H,Dk) vs gathered keys (B,S',Hkv,Dk) with validity mask."""
    mask = key_valid[:, None, :]                       # (B, 1, S')
    mask = jnp.broadcast_to(mask, (q.shape[0], q.shape[1], keys.shape[1]))
    if window is not None:
        mask = mask & ((q_pos[:, :, None] - key_pos[:, None, :]) < window)
    return mha_reference(q, keys, vals, mask, scale=scale, softcap=softcap)


def _decode_key_mask(cache_pos, positions, cache_limit):
    """validity of (cache ++ self) keys given a per-sequence cache limit."""
    cvalid = cache_pos >= 0
    if cache_limit is not None:
        lim = jnp.asarray(cache_limit)
        if lim.ndim == 0:
            lim = lim[None]
        cvalid = cvalid & (cache_pos < lim[:, None])
    svalid = jnp.ones(positions.shape, bool)
    return jnp.concatenate([cvalid, svalid], axis=1)


# ---------------------------------------------------------------------------
# KV layouts — how decode attention reads a layer's cached keys
# ---------------------------------------------------------------------------


class KVLayout:
    """Strategy object behind ``gqa_decode``/``mla_decode`` and the
    ``*_plain_paged`` suffix-prefill passes.

    One layout = one answer to "how do the committed keys reach the
    attention math": read the dense buffer, gather the page pool into a
    dense-width copy, or run the page-aware kernels over the pool in
    place.  Two entry points per layout — ``attend`` (decode step) and
    ``prefill_attend`` (plain pass over a prompt suffix against
    shared-prefix pages).  All layouts share the masking contract
    (``pos = -1`` empty, ``cache_limit``, sliding window, null page)
    and the commit path's write discipline; ``transient_bytes`` /
    ``prefill_transient_bytes`` report the cache-KV copy each pass
    materializes outside the resident cache (the capacity tax the
    in-place kernels remove).
    """

    kind = "?"

    def attend(self, q, k_self, v_self, positions, cache, *, block_table,
               cache_limit, scale, softcap, window):
        raise NotImplementedError

    def prefill_attend(self, q, k_self, v_self, meta, cache, *,
                       context_table, block_size, impl, scale, softcap,
                       window):
        """Plain-mode pass of suffix queries over (shared-prefix pages
        ++ suffix self keys); must be bitwise equal to the full-prefill
        chunked kernel over the same key layout (the
        ``serving.prefix_cache`` invariant)."""
        raise NotImplementedError

    def commit(self, cache, k_self, v_self, positions, block_table):
        if isinstance(cache, PagedAttnCache):
            return paged_cache_write(cache, k_self, v_self, positions,
                                     block_table)
        return cache_write(cache, k_self, v_self, positions)

    @staticmethod
    def _concat_attend(ck, cv, cpos, q, k_self, v_self, positions, *,
                       cache_limit, scale, softcap, window):
        """The shared (cache ++ self) reference path."""
        keys = jnp.concatenate([ck.astype(k_self.dtype), k_self], axis=1)
        vals = jnp.concatenate([cv.astype(v_self.dtype), v_self], axis=1)
        key_pos = jnp.concatenate(
            [cpos, positions.astype(jnp.int32)], axis=1)
        key_valid = _decode_key_mask(cpos, positions, cache_limit)
        return _cache_decode_attention(
            q, keys, vals, key_pos, key_valid, positions,
            scale=scale, softcap=softcap, window=window)

    @staticmethod
    def transient_bytes(cache, n_rows: int, n_blocks: int) -> int:
        return 0

    @staticmethod
    def prefill_transient_bytes(cache, n_rows: int,
                                n_ctx_blocks: int) -> int:
        return 0


class _DenseKV(KVLayout):
    """Contiguous per-sequence cache rows; decode concatenates the row
    with the self block (one cache-width copy per layer per step)."""

    kind = "dense"

    def attend(self, q, k_self, v_self, positions, cache, *, block_table,
               cache_limit, scale, softcap, window):
        return self._concat_attend(
            cache.k, cache.v, cache.pos, q, k_self, v_self, positions,
            cache_limit=cache_limit, scale=scale, softcap=softcap,
            window=window)

    @staticmethod
    def transient_bytes(cache, n_rows: int, n_blocks: int) -> int:
        S = cache.k.shape[-3]
        return n_rows * S * _kv_token_bytes(cache)


class _GatheredPagedKV(KVLayout):
    """``kernel="ref"``: gather the pool through the block table into a
    dense-width copy, then run the identical concat / full-prefill
    paths — the portable fallback and the parity oracle for the
    in-place kernels."""

    kind = "gathered"

    def attend(self, q, k_self, v_self, positions, cache, *, block_table,
               cache_limit, scale, softcap, window):
        ck, cv, cpos = paged_gather(cache, block_table)
        return self._concat_attend(
            ck, cv, cpos, q, k_self, v_self, positions,
            cache_limit=cache_limit, scale=scale, softcap=softcap,
            window=window)

    def prefill_attend(self, q, k_self, v_self, meta, cache, *,
                       context_table, block_size, impl, scale, softcap,
                       window):
        keys, vals, k_meta = _paged_context_kv(
            cache, context_table, k_self, v_self, meta, block_size)
        return kops.attention(
            q, keys, vals, meta, k_meta, impl=impl, scale=scale,
            softcap=softcap, window=window, strict=False, dup_len=None,
            block_size=block_size)

    @staticmethod
    def transient_bytes(cache, n_rows: int, n_blocks: int) -> int:
        bsz = cache.k.shape[-3]
        return n_rows * n_blocks * bsz * _kv_token_bytes(cache)

    @staticmethod
    def prefill_transient_bytes(cache, n_rows: int,
                                n_ctx_blocks: int) -> int:
        bsz = cache.k.shape[-3]
        return n_rows * n_ctx_blocks * bsz * _kv_token_bytes(cache)


class _InplacePagedKV(KVLayout):
    """``kernel="pallas"``: the page-aware kernels read the pool in
    place (one page per grid step via the scalar-prefetched block
    table) — no dense-width K/V copy exists at any point, decode or
    admission."""

    kind = "paged"

    def attend(self, q, k_self, v_self, positions, cache, *, block_table,
               cache_limit, scale, softcap, window):
        from repro.kernels.paged_attn import paged_decode_attention
        B = q.shape[0]
        if cache_limit is None:
            lim = jnp.full((B,), jnp.iinfo(jnp.int32).max, jnp.int32)
        else:
            lim = jnp.broadcast_to(
                jnp.asarray(cache_limit, jnp.int32).reshape(-1), (B,))
        return paged_decode_attention(
            q, cache.k, cache.v, cache.pos, block_table,
            k_self, v_self, positions, lim,
            scale=scale, softcap=softcap, window=window)

    def prefill_attend(self, q, k_self, v_self, meta, cache, *,
                       context_table, block_size, impl, scale, softcap,
                       window):
        from repro.kernels.paged_attn import paged_prefill_attention
        return paged_prefill_attention(
            q, cache.k, cache.v, cache.pos, context_table,
            k_self, v_self, meta.pos,
            scale=scale, softcap=softcap, window=window)


_KV_LAYOUTS = {
    ("dense", "ref"): _DenseKV(),
    ("dense", "pallas"): _DenseKV(),   # dense rows: nothing to gather
    ("paged", "ref"): _GatheredPagedKV(),
    ("paged", "pallas"): _InplacePagedKV(),
}


def _kv_token_bytes(cache) -> int:
    """Per-token bytes of one (k, v, pos) cache entry."""
    hkv, dk = cache.k.shape[-2], cache.k.shape[-1]
    dv = cache.v.shape[-1]
    return hkv * (dk * cache.k.dtype.itemsize
                  + dv * cache.v.dtype.itemsize) + 4


def resolve_kv_layout(cache, kernel: str = "ref") -> KVLayout:
    """Pick the decode KV layout for ``cache`` under ``kernel``.

    ``kernel="ref"`` — gathered fallback on paged caches, plain concat
    on dense; ``kernel="pallas"`` — the in-place page-aware kernel on
    paged caches (dense caches have no pages to gather, so the choice
    is a no-op there).
    """
    if kernel not in ("ref", "pallas"):
        raise ValueError(f"kernel must be ref|pallas, got {kernel!r}")
    store = "paged" if isinstance(cache, PagedAttnCache) else "dense"
    return _KV_LAYOUTS[(store, kernel)]


def transient_kv_bytes(cache, n_rows: int, n_blocks: int,
                       kernel: str = "ref") -> int:
    """Per-decode-step cache-KV bytes a layout copies out of the
    resident cache for one layer (the ``paged_gather`` / dense-concat
    transient); 0 for the in-place kernel path."""
    return resolve_kv_layout(cache, kernel).transient_bytes(
        cache, n_rows, n_blocks)


def prefill_transient_kv_bytes(cache, n_rows: int, n_ctx_blocks: int,
                               kernel: str = "ref") -> int:
    """Admission-time cache-KV bytes one layer's suffix prefill copies
    out of the resident cache: the shared-prefix gather width
    (``n_rows`` admitted rows x ``n_ctx_blocks`` hit pages) for the
    gathered layout, 0 for the in-place prefill kernel."""
    return resolve_kv_layout(cache, kernel).prefill_transient_bytes(
        cache, n_rows, n_ctx_blocks)


def kernel_exec_plan(cache, kernel: str = "ref"):
    """How the paged kernels would execute on this cache: a
    ``kernels.paged_attn.KernelPlan`` (mode ``compiled``/``interpret``
    plus the reason — backend vs tile shape vs padding), or ``None``
    when the layout never launches a Pallas kernel (``kernel="ref"`` or
    a dense cache)."""
    if kernel != "pallas" or not isinstance(cache, PagedAttnCache):
        return None
    from repro.kernels.paged_attn import plan_exec
    bsz = cache.k.shape[-3]
    return plan_exec(bsz, cache.k.shape[-1], cache.v.shape[-1])


def gqa_decode(p, x, positions, cache, cfg: ModelConfig, *,
               window: int | None, write_cache: bool,
               cache_limit=None, block_table=None, kernel: str = "ref"):
    """decode mode: block queries vs cache ++ self-block (bidirectional).

    ``cache`` is a dense per-sequence ``AttnCache`` or a shared
    ``PagedAttnCache`` (then ``block_table`` (B, K) maps block -> page).
    ``kernel`` selects the KV layout on paged caches: ``"ref"`` gathers
    pages into a dense-width copy, ``"pallas"`` reads the pool in place.
    """
    B, n, _ = x.shape
    q, k_self, v_self = gqa_qkv(p, x, positions, cfg)
    layout = resolve_kv_layout(cache, kernel)
    o = layout.attend(
        q, k_self, v_self, positions, cache, block_table=block_table,
        cache_limit=cache_limit, scale=_gqa_scale(cfg),
        softcap=cfg.attn_logit_softcap or None, window=window)
    new_cache = layout.commit(cache, k_self, v_self, positions,
                              block_table) if write_cache else cache
    return linear(p["wo"], o.reshape(B, n, -1)), new_cache


def write_prefill_cache(cache: AttnCache, k, v, positions) -> AttnCache:
    """Write a full prefill's keys into a (possibly ring) cache buffer.

    If the buffer is shorter than the sequence (sliding-window ring), only
    the last S entries are written (earlier ones would be overwritten
    anyway, and .at[].set with duplicate indices is unspecified)."""
    S = cache.k.shape[1]
    if k.shape[1] > S:
        k, v, positions = k[:, -S:], v[:, -S:], positions[:, -S:]
    return cache_write(cache, k, v, positions)


# ---------------------------------------------------------------------------
# MLA (absorbed form — attention runs over the 576-d latent, MQA-style)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    r, nope, rope, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                         cfg.qk_rope_dim, cfg.v_head_dim)
    dt = jnp.dtype(cfg.param_dtype)
    names = ["wq_a", "wq_b", "w_dkv", "w_kb", "w_vb", "wo"]
    ks = split_like(key, names)
    qin = cfg.q_lora_rank or d
    p = {
        "w_dkv": init_linear(ks["w_dkv"], d, r + rope, dtype=dt),
        "ckv_norm": {"scale": jnp.zeros((r,), dt)},
        "w_kb": init_linear(ks["w_kb"], r, H * nope, dtype=dt),
        "w_vb": init_linear(ks["w_vb"], r, H * dv, dtype=dt),
        "wo": init_linear(ks["wo"], H * dv, d, dtype=dt),
        "wq_b": init_linear(ks["wq_b"], qin, H * (nope + rope), dtype=dt),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = init_linear(ks["wq_a"], d, cfg.q_lora_rank, dtype=dt)
        p["q_norm"] = {"scale": jnp.zeros((cfg.q_lora_rank,), dt)}
    return p


def _mla_q_latent(p, x, positions, cfg: ModelConfig):
    """Absorbed queries: q' = [q_nope @ W_kb^T, rope(q_rope)], (B,T,H,r+rope)."""
    B, T, _ = x.shape
    H, r = cfg.n_heads, cfg.kv_lora_rank
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    xq = x
    if cfg.q_lora_rank:
        xq = rmsnorm(p["q_norm"], linear(p["wq_a"], x), eps=cfg.norm_eps)
    q = linear(p["wq_b"], xq).reshape(B, T, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    wkb = p["w_kb"]["w"].reshape(r, H, nope)
    q_lat = jnp.einsum("bthn,rhn->bthr", q_nope.astype(jnp.float32),
                       wkb.astype(jnp.float32)).astype(x.dtype)
    return jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,T,H,r+rope)


def _mla_kv_latent(p, x, positions, cfg: ModelConfig):
    """Latent keys/values: k' = [rms(ckv), rope(k_rope)] (B,T,1,r+rope), v' = ckv."""
    r, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv = linear(p["w_dkv"], x)
    c, k_rope = ckv[..., :r], ckv[..., r:]
    c = rmsnorm(p["ckv_norm"], c, eps=cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_lat = jnp.concatenate([c[:, :, None, :], k_rope], axis=-1)
    return k_lat, c[:, :, None, :]  # (B,T,1,r+rope), (B,T,1,r)


def _mla_out(p, o, cfg: ModelConfig):
    """o (B,T,H,r) -> absorb W_vb then W_o."""
    B, T, H, r = o.shape
    wvb = p["w_vb"]["w"].reshape(r, H, cfg.v_head_dim)
    ov = jnp.einsum("bthr,rhv->bthv", o.astype(jnp.float32),
                    wvb.astype(jnp.float32))
    return linear(p["wo"], ov.reshape(B, T, -1).astype(o.dtype))


def _mla_scale(cfg: ModelConfig) -> float:
    return (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5


def mla_masked(p, x, meta: SeqMeta, cfg: ModelConfig, *,
               window: int | None, dup_len: int | None,
               strict: bool = False
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = _mla_q_latent(p, x, meta.pos, cfg)
    k, v = _mla_kv_latent(p, x, meta.pos, cfg)
    o = kops.attention(
        q, k, v, meta, meta,
        impl=cfg.attn_impl,
        scale=_mla_scale(cfg), softcap=None, window=window,
        strict=strict, dup_len=dup_len, block_size=cfg.block_size)
    return _mla_out(p, o, cfg), k, v


def mla_plain_paged(p, x, meta: SeqMeta, cache: PagedAttnCache,
                    cfg: ModelConfig, *, window: int | None,
                    context_table: jax.Array, write_pages: jax.Array,
                    kernel: str = "ref"
                    ) -> tuple[jax.Array, PagedAttnCache]:
    """``gqa_plain_paged`` for the absorbed-MLA mixer (latent KV pages):
    the latent MQA form (Hkv = 1, Dk = r+rope != Dv = r) rides the same
    prefill KV layouts."""
    B, T, _ = x.shape
    q = _mla_q_latent(p, x, meta.pos, cfg)
    k, v = _mla_kv_latent(p, x, meta.pos, cfg)
    o = resolve_kv_layout(cache, kernel).prefill_attend(
        q, k, v, meta, cache,
        context_table=context_table, block_size=cfg.block_size,
        impl=cfg.attn_impl, scale=_mla_scale(cfg), softcap=None,
        window=window)
    new_cache = write_suffix_pages(cache, k, v, meta.pos, write_pages)
    return _mla_out(p, o, cfg), new_cache


def mla_decode(p, x, positions, cache, cfg: ModelConfig, *,
               window: int | None, write_cache: bool,
               cache_limit=None, block_table=None, kernel: str = "ref"):
    """``gqa_decode`` for the absorbed-MLA mixer: the latent MQA form
    (Hkv = 1 over the r+rope latent) rides the same KV layouts — the
    page-aware kernel sees it as one shared kv head."""
    q = _mla_q_latent(p, x, positions, cfg)
    k_self, v_self = _mla_kv_latent(p, x, positions, cfg)
    layout = resolve_kv_layout(cache, kernel)
    o = layout.attend(
        q, k_self, v_self, positions, cache, block_table=block_table,
        cache_limit=cache_limit, scale=_mla_scale(cfg), softcap=None,
        window=window)
    new_cache = layout.commit(cache, k_self, v_self, positions,
                              block_table) if write_cache else cache
    return _mla_out(p, o, cfg), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers / enc-dec memory)
# ---------------------------------------------------------------------------


def init_cross(key, cfg: ModelConfig, *, gated: bool) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_like(key, ["wq", "wk", "wv", "wo"])
    p = {
        "wq": init_linear(ks["wq"], d, H * Dh, dtype=dt),
        "wk": init_linear(ks["wk"], d, Hkv * Dh, dtype=dt),
        "wv": init_linear(ks["wv"], d, Hkv * Dh, dtype=dt),
        "wo": init_linear(ks["wo"], H * Dh, d, dtype=dt),
    }
    if gated:  # llama-3.2-vision tanh gates
        p["gate"] = jnp.zeros((), dt)
    return p


def cross_attn(p, x, memory, cfg: ModelConfig,
               memory_valid: jax.Array | None = None) -> jax.Array:
    """x (B,T,d) queries attend to memory (B,Ne,d); no positional rotation
    on memory keys (frontend embeddings carry their own positions)."""
    B, T, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(B, T, H, Dh)
    k = linear(p["wk"], memory).reshape(B, memory.shape[1], Hkv, Dh)
    v = linear(p["wv"], memory).reshape(B, memory.shape[1], Hkv, Dh)
    mask = None
    if memory_valid is not None:
        mask = jnp.broadcast_to(memory_valid[:, None, :],
                                (B, T, memory.shape[1]))
    o = mha_reference(q, k, v, mask, scale=Dh ** -0.5)
    y = linear(p["wo"], o.reshape(B, T, -1))
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return y
