"""Unified model configuration covering every assigned architecture.

A single ``ModelConfig`` describes dense / MoE / SSM / hybrid / enc-dec /
VLM decoder stacks; ``layer_pattern`` derives the per-layer structure and
the scan grouping (layers are stacked and scanned in repeating "pattern
groups" so 56-72-layer configs lower with small HLO).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class LayerSpec:
    mixer: str                    # attn | rwkv6 | mamba | cross_attn
    window: int | None = None     # sliding window for this layer
    ffn: str = "dense"            # dense | moe | rwkv_cm
    cross: bool = False           # additional cross-attn sublayer (enc-dec)
    d_ff: int = 0                 # 0 -> cfg.d_ff (prefix dense layers differ)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    arch_type: str = "dense"      # dense|moe|ssm|hybrid|encdec|vlm
    source: str = ""              # citation for the assigned config

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 512
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False     # gemma: x *= sqrt(d_model)
    sandwich_norm: bool = False   # gemma2 post-norms

    # attention
    attn_kind: str = "gqa"        # gqa | mla
    rope_theta: float = 10000.0
    sliding_window: int = 0       # 0 -> disabled
    local_global: bool = False    # gemma2: even layers local, odd global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    query_scale: float = 0.0      # 0 -> 1/sqrt(head_dim)

    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0             # 0 -> d_ff
    moe_every: int = 1
    moe_offset: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0           # for first_k_dense layers; 0 -> d_ff
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 1           # routing groups (align with data shards)

    # SSM / hybrid
    ssm_kind: str = ""            # rwkv6 | mamba
    attn_every: int = 0           # hybrid: attn layer where i%attn_every==attn_offset
    attn_offset: int = 4
    d_state: int = 16
    conv_width: int = 4
    expand: int = 2
    rwkv_head_dim: int = 64
    lora_rank: int = 32           # rwkv6 data-dependence rank

    # enc-dec / vlm (modality frontends are stubs; these describe the
    # backbone that consumes precomputed frame/patch embeddings)
    encoder_layers: int = 0
    cross_attn_every: int = 0     # vlm: cross layer where i%every==cross_offset
    cross_offset: int = 3
    n_extra_tokens: int = 0       # audio frames / image patches
    extra_embed_dim: int = 0      # frontend output dim (projector input)

    # block diffusion (the paper's post-training wrapper)
    block_size: int = 32
    mask_token_id: int = -1       # -1 -> vocab_size - 1

    # compute policy
    dtype: str = "float32"
    param_dtype: str = "float32"
    # ref | structured | chunked | pallas | pallas_interpret; all are
    # differentiable (pallas via the custom-VJP flash backward kernels),
    # so any of them is a valid training impl
    attn_impl: str = "structured"
    remat: bool = False
    remat_policy: str = "nothing"  # nothing | dots
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_mask_token(self) -> int:
        return self.mask_token_id if self.mask_token_id >= 0 else self.vocab_size - 1

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def resolved_dense_d_ff(self) -> int:
        return self.dense_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def layer_spec(self, i: int) -> LayerSpec:
        if self.arch_type == "ssm":
            mixer = self.ssm_kind
        elif self.arch_type == "hybrid":
            mixer = "attn" if (self.attn_every and
                               i % self.attn_every == self.attn_offset) \
                else self.ssm_kind
        elif self.arch_type == "vlm":
            mixer = "cross_attn" if (self.cross_attn_every and
                                     i % self.cross_attn_every == self.cross_offset) \
                else "attn"
        else:
            mixer = "attn"

        window = None
        if mixer == "attn":
            if self.local_global:
                window = self.sliding_window if i % 2 == 0 else None
            elif self.sliding_window:
                window = self.sliding_window

        if i < self.first_k_dense:
            ffn = "dense"
        elif self.n_experts and (i % self.moe_every == self.moe_offset):
            ffn = "moe"
        elif self.ssm_kind == "rwkv6" and self.arch_type == "ssm":
            ffn = "rwkv_cm"
        else:
            ffn = "dense"

        cross = (self.arch_type == "encdec")
        d_ff = self.resolved_dense_d_ff if (i < self.first_k_dense) else 0
        return LayerSpec(mixer=mixer, window=window, ffn=ffn, cross=cross,
                         d_ff=d_ff)


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def layer_pattern(cfg: ModelConfig
                  ) -> tuple[list[LayerSpec], list[LayerSpec], int]:
    """Returns (prefix_specs, group_specs, n_groups).

    prefix = ``first_k_dense`` unscanned layers; the rest is ``n_groups``
    repeats of the ``group_specs`` pattern (identical structure and param
    shapes in every repeat — scannable).
    """
    prefix = [cfg.layer_spec(i) for i in range(cfg.first_k_dense)]
    rest = cfg.n_layers - cfg.first_k_dense

    period = 1
    if cfg.local_global:
        period = _lcm(period, 2)
    if cfg.arch_type == "hybrid" and cfg.attn_every:
        period = _lcm(period, cfg.attn_every)
    if cfg.arch_type == "vlm" and cfg.cross_attn_every:
        period = _lcm(period, cfg.cross_attn_every)
    if cfg.n_experts and cfg.moe_every > 1:
        period = _lcm(period, cfg.moe_every)
    if not cfg.scan_layers:
        period = rest
    assert rest % max(period, 1) == 0, \
        f"{cfg.name}: {rest} layers not divisible by pattern period {period}"

    group = [cfg.layer_spec(cfg.first_k_dense + j) for j in range(period)]
    # verify periodicity holds across the whole stack
    for i in range(rest):
        assert cfg.layer_spec(cfg.first_k_dense + i) == group[i % period], \
            f"{cfg.name}: layer {i} breaks the pattern period {period}"
    return prefix, group, rest // period
