"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596].

[audio] 12L(+12L encoder) d_model=1024 16H d_ff=4096 vocab=256206.
The mel+conv audio frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, n_frames, 1024); the bidirectional encoder + the
block-diffusion decoder with per-layer cross-attention are fully
implemented.  long_500k: SKIPPED (full attention; DESIGN.md §4).
"""

from repro.models.config import ModelConfig

N_FRAMES = 1024          # stub audio frames per utterance
FRAME_DIM = 1024         # frontend embedding dim


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", arch_type="encdec",
        source="arXiv:2308.11596",
        n_layers=12, encoder_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
        # vocab padded 256206 -> 256256 (multiple of 256) so the
        # embedding/logits shard over the 16-way model axis; the pool's
        # true vocab is 256206 (padding rows are never produced).
        vocab_size=256256, tie_embeddings=False,
        n_extra_tokens=N_FRAMES, extra_embed_dim=FRAME_DIM,
        block_size=32, **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="seamless-smoke", n_layers=2, encoder_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        n_extra_tokens=16, extra_embed_dim=64, block_size=8, **kw)
