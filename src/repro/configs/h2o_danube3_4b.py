"""h2o-danube-3-4b — llama+mistral mix, SWA [arXiv:2401.16818].

[dense] 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000,
sliding window 4096.  long_500k: RUNS (SWA ring cache).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", arch_type="dense",
        source="arXiv:2401.16818",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
        d_ff=10240, vocab_size=32000, sliding_window=4096,
        rope_theta=10000.0, tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="danube3-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        sliding_window=32, block_size=8, **kw)
