"""rwkv6-1.6b — Finch, data-dependent decay [arXiv:2404.05892].

[ssm] 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
Block-diffusion applicability: recurrent — trained via the clean-pass +
boundary-state noisy re-runs (DESIGN.md §4); RL logits via replay.
long_500k: RUNS (O(1)-state decode).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", arch_type="ssm", ssm_kind="rwkv6",
        source="arXiv:2404.05892",
        n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536,
        n_heads=32, n_kv_heads=32,            # unused (attention-free)
        rwkv_head_dim=64, lora_rank=32,
        tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="rwkv6-smoke", n_layers=2, d_model=128, d_ff=256,
        vocab_size=512, rwkv_head_dim=32, lora_rank=8, block_size=8, **kw)
