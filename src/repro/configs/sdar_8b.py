"""sdar-8b — the paper's own backbone family (SDAR-8B-Chat,
arXiv:2510.06303; Qwen3-8B-derived blockwise dLLM).

DiRL-8B-Instruct is SDAR-8B-Chat post-trained with the DiRL SFT->DiPO
pipeline.  SDAR uses a small diffusion block (4); we keep it faithful
here (the kernel handles sub-tile blocks via partial tiles).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="sdar-8b", arch_type="dense", source="arXiv:2510.06303",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=12288, vocab_size=151936,
        rope_theta=1e6, tie_embeddings=False, block_size=4,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="sdar-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        block_size=4, **kw)
