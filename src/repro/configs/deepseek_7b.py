"""deepseek-7b — llama-arch [arXiv:2401.02954].

[dense] 30L d_model=4096 32H (MHA, kv=32) d_ff=11008 vocab=102400.
long_500k: SKIPPED (pure full attention; DESIGN.md §4).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", arch_type="dense", source="arXiv:2401.02954",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        head_dim=128, d_ff=11008, vocab_size=102400,
        tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="deepseek7b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        block_size=8, **kw)
