"""gemma2-27b — local+global alternating, logit softcap [arXiv:2408.00118].

[dense] 46L d_model=4608 32H (GQA kv=16) head_dim=128 d_ff=36864
vocab=256000; even layers sliding-window 4096, odd layers global;
attention softcap 50, final softcap 30, query scale 1/sqrt(144)? — HF
config query_pre_attn_scalar = d_model/n_heads = 144; sandwich norms;
embeddings scaled by sqrt(d) and tied.
long_500k: RUNS with the alternating pattern — local layers keep a 4096
ring cache, global layers a full sequence-sharded cache (decode is O(L)
per step); noted as partially-windowed in DESIGN.md.
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", arch_type="dense", source="arXiv:2408.00118",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256000,
        local_global=True, sliding_window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        query_scale=144.0 ** -0.5, sandwich_norm=True, embed_scale=True,
        act="gelu", tie_embeddings=True, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="gemma2-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        sliding_window=32, query_scale=32.0 ** -0.5, block_size=8, **kw)
