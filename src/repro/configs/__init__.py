"""Architecture registry (``--arch <id>``) + assigned input shapes.

Every entry cites its source in the module docstring; ``get_config(name)``
returns the exact assigned configuration, ``get_smoke_config(name)`` the
reduced same-family variant exercised on CPU by tests/test_arch_smoke.py.
"""

from __future__ import annotations

import dataclasses
import importlib

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1_6b",
    "mixtral-8x22b": "mixtral_8x22b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma2-27b": "gemma2_27b",
    "deepseek-7b": "deepseek_7b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "sdar-8b": "sdar_8b",
    "tiny": "tiny",
}

ASSIGNED_ARCHS = [k for k in _MODULES if k not in ("tiny",)]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs with a sub-quadratic decode path (SSM state / SWA ring cache);
# pure full-attention archs skip long_500k (DESIGN.md §4).
LONG_CONTEXT_ARCHS = {
    "rwkv6-1.6b", "jamba-1.5-large-398b", "mixtral-8x22b",
    "h2o-danube-3-4b", "gemma2-27b",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, **kw):
    return _module(name).config(**kw)


def get_smoke_config(name: str, **kw):
    return _module(name).smoke_config(**kw)


def arch_shape_pairs() -> list[tuple[str, str]]:
    """All (arch, shape) baseline dry-run combinations (skips noted)."""
    pairs = []
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            pairs.append((arch, shape))
    return pairs
