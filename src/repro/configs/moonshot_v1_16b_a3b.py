"""moonshot-v1-16b-a3b — Moonlight-16B-A3B
[hf:moonshotai/Moonlight-16B-A3B].

Pool tags it [dense] but specifies "MoE 64e top-6"; per the Moonlight
model card we implement the MoE: 48L d_model=2048 16H (kv=16) expert
d_ff=1408, 64 routed top-6 + 2 shared experts, first layer dense
(d_ff=11264), vocab=163840.  long_500k: SKIPPED (full attention).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", arch_type="moe",
        source="hf:moonshotai/Moonlight-16B-A3B",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1408, moe_d_ff=1408,
        first_k_dense=1, dense_d_ff=11264,
        n_experts=64, n_shared_experts=2, top_k=6,
        vocab_size=163840, tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="moonshot-smoke", n_layers=3, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=64, moe_d_ff=64, dense_d_ff=256,
        n_experts=4, n_shared_experts=1, top_k=2, vocab_size=512,
        block_size=8, **kw)
