"""llama-3.2-vision-11b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

[vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; gated
cross-attention layers interleaved every 5th layer (8 total) attend to
image-patch embeddings.  The ViT vision encoder is a STUB: input_specs()
provides precomputed patch embeddings (B, 1600, 1280) which the built-in
projector maps to d_model.  long_500k: SKIPPED (full attention).
"""

from repro.models.config import ModelConfig

N_PATCHES = 1600
PATCH_DIM = 1280


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", arch_type="vlm",
        source="hf:meta-llama/Llama-3.2-11B-Vision",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=128256,
        cross_attn_every=5, cross_offset=3,
        n_extra_tokens=N_PATCHES, extra_embed_dim=PATCH_DIM,
        rope_theta=500000.0, tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="llama32v-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        cross_attn_every=2, cross_offset=1, n_extra_tokens=16,
        extra_embed_dim=64, block_size=8, **kw)
