"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

[moe] 60L d_model=5120 128H, MLA (q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128), expert d_ff=1536, first dense layer d_ff=12288,
vocab=102400.  Attention is implemented in the absorbed-MLA form (the
latent 576-d cache is what decode shapes carry).
long_500k: SKIPPED (full attention; MLA compresses the cache but not the
quadratic scan — DESIGN.md §4).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", arch_type="moe", attn_kind="mla",
        source="arXiv:2405.04434",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128,
        d_ff=1536, moe_d_ff=1536, first_k_dense=1, dense_d_ff=12288,
        n_experts=160, n_shared_experts=2, top_k=6,
        vocab_size=102400, tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="dsv2-smoke", n_layers=3, d_model=128, n_heads=4,
        n_kv_heads=4, q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16, d_ff=64, moe_d_ff=64,
        dense_d_ff=256, n_experts=4, n_shared_experts=1, top_k=2,
        vocab_size=512, block_size=8, **kw)
