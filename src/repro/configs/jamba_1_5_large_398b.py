"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

[hybrid] 72L d_model=8192: attention at layer index 4 of every 8-layer
Jamba block (1:7 ratio), 64H (GQA kv=8); Mamba elsewhere (d_state 16,
conv 4, expand 2); MoE 16e top-2 on every second layer, d_ff=24576,
vocab=65536.  long_500k: RUNS (Mamba state decode + 1/8 attention layers
with a sequence-sharded cache).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", arch_type="hybrid", ssm_kind="mamba",
        source="arXiv:2403.19887",
        n_layers=72, d_model=8192, attn_every=8, attn_offset=4,
        n_heads=64, n_kv_heads=8, head_dim=128,
        d_state=16, conv_width=4, expand=2,
        d_ff=24576, moe_d_ff=24576, n_experts=16, top_k=2,
        moe_every=2, moe_offset=1,
        vocab_size=65536, tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="jamba-smoke", n_layers=2, d_model=128, attn_every=2,
        attn_offset=1, n_heads=4, n_kv_heads=2, head_dim=32, d_state=8,
        d_ff=256, moe_d_ff=256, n_experts=4, moe_every=2, moe_offset=1,
        vocab_size=512, block_size=8, **kw)
