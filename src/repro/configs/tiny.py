"""tiny — CPU-trainable config for the end-to-end examples and tests."""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=384, block_size=16,
        attn_impl="structured", **kw)


def smoke_config(**kw) -> ModelConfig:
    return config(**kw)
