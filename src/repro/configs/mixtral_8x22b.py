"""mixtral-8x22b — 8 experts top-2, SWA [arXiv:2401.04088].

[moe] 56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768,
MoE 8e top-2, sliding window 4096 (per the assignment pool).
long_500k: RUNS (window-bounded ring KV cache).
"""

from repro.models.config import ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", arch_type="moe", source="arXiv:2401.04088",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, moe_d_ff=16384, vocab_size=32768,
        n_experts=8, top_k=2, rope_theta=1e6,
        sliding_window=4096, tie_embeddings=False, block_size=32,
        **kw)


def smoke_config(**kw) -> ModelConfig:
    return config().replace(
        name="mixtral-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, moe_d_ff=256, vocab_size=512,
        n_experts=4, sliding_window=32, block_size=8, **kw)
