"""SFT trainer: blockwise-diffusion NELBO with the fused dup-layout pass."""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.analysis.guards import TraceGuard
from repro.core.block_diffusion import sft_loss
from repro.core.masks import dirl_layout, sample_sft_noise
from repro.kernels.ops import layout_tile_stats
from repro.obs import profile
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.optim import adamw


@dataclasses.dataclass
class SFTConfig:
    steps: int = 100
    log_every: int = 10
    layout: str = "dirl"   # dirl | tracer (Fig 4a baseline)


class SFTTrainer:
    """Supervised trainer over the fused NELBO step.

    Observability: each ``train_step`` is bracketed by an obs span
    (track ``"trainer"``; shared with the serving stack when a caller
    passes an engine's tracer via ``tracer=``), and step wall times
    aggregate into the ``dirl_trainer`` metrics namespace.  The span
    interval includes the deliberate post-step sync, so
    ``step_seconds`` keeps measuring the real device step.
    """

    def __init__(self, model, opt_cfg: adamw.AdamWConfig, params, *,
                 layout: str = "dirl", tracer: Tracer | None = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.params = params
        self.opt_state = adamw.init_state(opt_cfg, params)
        self.layout = layout
        self.step_seconds: list[float] = []
        self.tracer = tracer if tracer is not None else Tracer(
            enabled=False)
        self.metrics = MetricsRegistry("dirl_trainer")
        self._phase_seconds = self.metrics.histogram(
            "phase_seconds", "per-phase wall time per train step",
            labelnames=("phase",))
        self._steps_total = self.metrics.counter(
            "steps", "train steps executed")
        self._step_traces = self.metrics.gauge(
            "step_traces", "compilations of the fused SFT step")
        # tile-map sparsity of this step's attention mask — the exact
        # fraction the pallas kernels visit/skip (layer-window effects
        # excluded, so these are per-step upper bounds)
        self._tile_gauges = {
            f: self.metrics.gauge(
                f"attn_tile_{f}",
                f"attention tile-map {f.replace('_', ' ')} this step")
            for f in ("visit_fraction", "partial_fraction",
                      "full_fraction")}

        def step_fn(params, opt_state, batch, rng):
            def loss_fn(p):
                return sft_loss(model, p, batch, rng, layout=layout)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt_state, om = adamw.apply_updates(
                opt_cfg, params, grads, opt_state)
            metrics = {**metrics, **om, "loss": loss}
            return params, opt_state, metrics

        # zero-retrace witness: fixed batch/rng shapes keep this at 1
        self._step = TraceGuard(step_fn, donate_argnums=(0, 1),
                                name="sft_step")

    def train_step(self, batch: dict, rng) -> dict:
        with self.tracer.span("sft_step", cat="trainer",
                              track="trainer") as sp:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            with profile.annotate("sft_step"):
                self.params, self.opt_state, metrics = self._step(
                    self.params, self.opt_state, batch, rng)
            # deliberate: step_seconds must measure the real step, and
            # metrics are pulled to host right below anyway
            jax.block_until_ready(metrics["loss"])  # dirlint: ok(hot-sync)
        self.step_seconds.append(sp.dur)
        self._phase_seconds.labels(phase="train").observe(sp.dur)
        self._steps_total.inc()
        self._step_traces.set(self._step.n_traces)
        out = {k: float(v) for k, v in metrics.items()}
        out["step_traces"] = self._step.n_traces
        out.update(self._tile_stats(batch, rng))
        return out

    def _tile_stats(self, batch: dict, rng) -> dict:
        """Host-side replay of this step's layout (same rng, so the same
        sampled noise) -> tile-map sparsity gauges."""
        if self.layout != "dirl":
            return {}
        cfg = self.model.cfg
        steps, _, _ = sample_sft_noise(
            rng, batch["tokens"], batch["prompt_mask"], batch["valid"],
            block_size=cfg.block_size)
        _, meta, _ = dirl_layout(
            batch["tokens"], steps, batch["valid"],
            block_size=cfg.block_size, mask_token=cfg.resolved_mask_token,
            noised=True)
        stats = layout_tile_stats(meta)
        out = {}
        for f, g in self._tile_gauges.items():
            g.set(stats[f])
            out[f"attn_tile_{f}"] = stats[f]
        return out

    def run(self, batches: Iterator, steps: int, rng, *,
            log_every: int = 10, verbose: bool = True) -> list[dict]:
        history = []
        for i in range(steps):
            rng, k = jax.random.split(rng)
            m = self.train_step(next(batches).asdict(), k)
            history.append(m)
            if verbose and (i % log_every == 0 or i == steps - 1):
                print(f"[sft {i:4d}] loss={m['loss']:.4f} "
                      f"ce={m['masked_ce']:.4f} gnorm={m['grad_norm']:.3f}")
        return history
