"""msgpack pytree checkpointing.

This is deliberately a *real* file-system serialisation path: the paper's
Fig. 5a/6 baseline round-trips checkpoints through storage every RL step
(2 loads + 1 save), and benchmarks/fig6 measures exactly this against the
in-place server update.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> dict:
    a = np.asarray(x)
    # str(dtype) round-trips ml_dtypes names ("bfloat16") that
    # numpy's .str protocol does not
    return {b"dtype": str(a.dtype), b"shape": list(a.shape),
            b"data": a.tobytes()}


def _decode_leaf(d) -> np.ndarray:
    dt = jnp.dtype(d[b"dtype"].decode() if isinstance(d[b"dtype"], bytes)
                   else d[b"dtype"])
    return np.frombuffer(d[b"data"], dtype=dt).reshape(
        d[b"shape"]).copy()


def save_pytree(path: str, tree) -> int:
    """Serialise a pytree of arrays.  Returns bytes written."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_encode_leaf(l) for l in leaves],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    blob = msgpack.packb(payload)
    with open(path, "wb") as f:
        f.write(blob)
    return len(blob)


def load_pytree(path: str, like):
    """Load into the structure of ``like`` (shape/dtype-checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves = [_decode_leaf(d) for d in payload[b"leaves"]]
    ref_leaves, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves) == len(ref_leaves), \
        f"leaf count mismatch: {len(leaves)} vs {len(ref_leaves)}"
    out = []
    for got, ref in zip(leaves, ref_leaves):
        assert tuple(got.shape) == tuple(ref.shape), (got.shape, ref.shape)
        out.append(jnp.asarray(got, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
