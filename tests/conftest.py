"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
repro.launch.dryrun sets up the 512 placeholder devices (in its own
process).

Tiering: heavyweight system / arch-zoo tests are marked ``slow`` and
deselected from a plain ``pytest -q`` (tier-1, fast); run them with
``pytest -m slow`` (or any explicit ``-m`` expression, which disables
the default deselection).
"""

import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavyweight system/arch-zoo test; deselected from plain "
        "runs, select with -m slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m") or config.getoption("-k"):
        return  # explicit -m/-k expression: user controls selection
    if any("::" in a for a in config.invocation_params.args):
        return  # explicit node id: run exactly what was asked for
    skip = pytest.mark.skip(reason="slow — run with `pytest -m slow`")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
