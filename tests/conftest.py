"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
repro.launch.dryrun sets up the 512 placeholder devices (in its own
process)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
