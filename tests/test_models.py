"""Model-substrate unit tests: RoPE, norms, MoE, caches, SSM invariants."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip file when absent
from hypothesis import given, settings, strategies as st

from repro.models import attention as attn
from repro.models import ffn, ssm
from repro.models.config import ModelConfig
from repro.models.modules import apply_rope, rmsnorm, init_rmsnorm


# --------------------------- RoPE ------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), shift=st.integers(0, 64))
def test_rope_relative_position_invariance(seed, shift):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(5 + shift, 3 + shift),
                                         rel=1e-4, abs=1e-4)


def test_rope_norm_preserving():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


# --------------------------- RMSNorm ---------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.5, 4.0))
def test_rmsnorm_scale_invariance(seed, scale):
    # exact only in the eps -> 0 limit, so keep |x| well above sqrt(eps)
    p = init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 16)) + 0.5
    np.testing.assert_allclose(np.asarray(rmsnorm(p, x)),
                               np.asarray(rmsnorm(p, x * scale)),
                               atol=1e-3)


# --------------------------- MoE -------------------------------------------


@pytest.mark.parametrize("E,groups", [(4, 1), (4, 4), (16, 2)])
def test_moe_matches_dense_oracle(E, groups):
    cfg = ModelConfig(arch_type="moe", n_experts=E, top_k=2, moe_d_ff=32,
                      d_model=16, capacity_factor=8.0, moe_groups=groups,
                      n_shared_experts=1, vocab_size=64)
    p = ffn.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y, aux = ffn.moe(p, x, cfg)
    y_ref = ffn.moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    assert float(aux["drop_fraction"]) == 0.0
    assert float(aux["aux_loss"]) > 0


def test_moe_capacity_drops_reported():
    cfg = ModelConfig(arch_type="moe", n_experts=8, top_k=2, moe_d_ff=16,
                      d_model=16, capacity_factor=0.6, vocab_size=64)
    p = ffn.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    _, aux = ffn.moe(p, x, cfg)
    assert float(aux["drop_fraction"]) > 0


# --------------------------- caches ----------------------------------------


def test_ring_cache_wraps():
    cache = attn.make_attn_cache(1, 4, 1, 8, 8, jnp.float32)
    k = jnp.ones((1, 2, 1, 8))
    c1 = attn.cache_write(cache, k * 1, k * 1, jnp.array([[0, 1]]))
    c2 = attn.cache_write(c1, k * 2, k * 2, jnp.array([[4, 5]]))  # wraps
    np.testing.assert_array_equal(np.asarray(c2.pos[0]), [4, 5, 2**31 - 1 if False else -1, -1])
    assert float(c2.k[0, 0, 0, 0]) == 2.0


def test_write_prefill_cache_tail_only():
    cache = attn.make_attn_cache(1, 4, 1, 8, 8, jnp.float32)
    k = jnp.arange(6, dtype=jnp.float32)[None, :, None, None] * jnp.ones((1, 6, 1, 8))
    pos = jnp.arange(6, dtype=jnp.int32)[None]
    c = attn.write_prefill_cache(cache, k, k, pos)
    # ring of 4 holds the last 4 positions (2..5) at idx pos%4
    got = sorted(int(p) for p in np.asarray(c.pos[0]))
    assert got == [2, 3, 4, 5]


# --------------------------- SSM invariants --------------------------------


@pytest.mark.parametrize("kind", ["rwkv6", "mamba"])
def test_ssm_boundary_state_consistency(kind):
    """Running [block0 ++ block1] in one scan == running block1 from the
    boundary state collected after block0."""
    cfg = ModelConfig(arch_type="ssm", ssm_kind=kind, d_model=32,
                      rwkv_head_dim=8, d_state=8, vocab_size=64,
                      block_size=8)
    fwd = ssm.rwkv6_forward if kind == "rwkv6" else ssm.mamba_forward
    init = (ssm.init_rwkv6 if kind == "rwkv6" else ssm.init_mamba)(
        jax.random.PRNGKey(0), cfg)
    zero = (ssm.rwkv6_zero_state if kind == "rwkv6"
            else ssm.mamba_zero_state)(cfg, 2)
    zero = {k: v for k, v in zero.items() if k != "cm_shift"}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    y_full, _, bounds = fwd(init, x, zero, cfg, n_blocks=2)
    state1 = jax.tree.map(lambda a: a[1], bounds)   # entry of block 1
    y_blk1, _, _ = fwd(init, x[:, 8:], state1, cfg)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]),
                               np.asarray(y_blk1), atol=2e-4)


def test_rwkv6_decay_in_unit_interval():
    cfg = ModelConfig(arch_type="ssm", ssm_kind="rwkv6", d_model=32,
                      rwkv_head_dim=8, vocab_size=64)
    p = ssm.init_rwkv6(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32)) * 5
    r, k, v, w, g, _ = ssm._rwkv6_projections(p, x,
                                              jnp.zeros((1, 32)), cfg)
    assert bool((w > 0).all() and (w < 1).all())
