"""Shared-prefix KV cache: refcounted page sharing across group rollouts.

Pins the third serving-cache layer (slots -> pages -> shared pages):
admission-time sharing across a DiPO G-group, the refcount lifecycle
(pages return to the free list only at refcount 0), LRU reclamation of
idle index entries under page pressure (never a live page), stale-key
hygiene on reclaimed pages, and — the acceptance criterion — byte-exact
token parity between prefix_cache on / off / dense under churn.
"""

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.prefix_cache import PrefixIndex, chain_keys
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import ModelServer

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, block_size=8,
                  attn_impl="structured")
BSZ = CFG.block_size
MAX_LEN = 48
K = MAX_LEN // BSZ


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts():
    """Four 2-block prompts: 0 and 1 share block 0 (partial-prefix pair),
    2 and 3 are unrelated."""
    k = jax.random.PRNGKey(1)
    shared = np.asarray(jax.random.randint(k, (BSZ,), 4, 100), np.int32)
    tails = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (4, BSZ), 4, 100), np.int32)
    p0 = np.concatenate([shared, tails[0]])
    p1 = np.concatenate([shared, tails[1]])
    p2 = np.concatenate([tails[2], tails[3]])
    p3 = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (2 * BSZ,), 4, 100), np.int32)
    return [p0, p1, p2, p3]


def _run_sched(model, params, submissions, **kw):
    """Drain a request list [(prompt, pblocks, key, budget)] and return
    ({uid: completion}, scheduler)."""
    sched = SlotScheduler(model, n_slots=kw.pop("n_slots", 3),
                          max_len=MAX_LEN, s_max=3, mode="dynamic",
                          tau=0.7, temperature=1.0, eos_id=1, **kw)
    for prompt, pb, key, budget in submissions:
        sched.submit(prompt, pb, key, max_new_blocks=budget)
    comps = {c.uid: c for c in sched.run(params)}
    return comps, sched


def _assert_identical(a, b):
    assert sorted(a) == sorted(b)
    for uid in a:
        ca, cb = a[uid], b[uid]
        assert ca.gen_blocks == cb.gen_blocks
        assert ca.denoise_steps == cb.denoise_steps
        hi = (ca.prompt_blocks + ca.gen_blocks) * BSZ
        np.testing.assert_array_equal(ca.tokens[:hi], cb.tokens[:hi])
        np.testing.assert_array_equal(ca.steps[:hi], cb.steps[:hi])


# ---------------------------------------------------------------- index
def test_index_longest_match_and_chaining():
    """Chained keys commit to the absolute prefix: equal blocks at
    different depths get different keys, and match() returns the longest
    contiguous cached chain."""
    idx = PrefixIndex()
    p = np.arange(3 * BSZ, dtype=np.int32)
    keys = chain_keys(p, BSZ)
    assert len(keys) == 3 and len(set(keys)) == 3
    # same block content, different prefix -> different key
    q = np.concatenate([p[BSZ:2 * BSZ], p[BSZ:2 * BSZ]])
    qkeys = chain_keys(q, BSZ)
    assert qkeys[0] != keys[1]
    idx.register(keys, 0, [5, 6, 7])
    assert [e.page for e in idx.match(keys)] == [5, 6, 7]
    assert [e.page for e in idx.match(keys[:2])] == [5, 6]
    assert idx.match(qkeys) == []
    # a hole can never match past it
    longer = chain_keys(np.arange(4 * BSZ, dtype=np.int32), BSZ)
    assert [e.page for e in idx.match(longer)] == [5, 6, 7]


def test_index_refcounts_and_leaf_first_lru():
    """Live-referenced entries are never reclaimed; idle ones go
    leaf-first in LRU order so the trie never dangles."""
    idx = PrefixIndex()
    a = chain_keys(np.arange(2 * BSZ, dtype=np.int32), BSZ)
    b = chain_keys(np.arange(2 * BSZ, dtype=np.int32) + 1, BSZ)
    idx.register(a, 0, [1, 2])       # refs 1 each
    idx.register(b, 0, [3, 4])
    idx.release(b)                    # b idle, a live
    assert idx.n_active == 2 and idx.n_idle == 2
    # only b is reclaimable, leaf (deeper entry) first
    assert idx.evict_lru() == 4
    assert idx.evict_lru() == 3
    assert idx.evict_lru() is None    # a is live: never evicted
    idx.release(a)
    assert idx.evict_lru() == 2       # leaf-first again
    idx2 = PrefixIndex()
    idx2.register(a, 0, [1, 2])
    idx2.release(a)
    hit = idx2.match(a)
    idx2.acquire(hit)                 # re-acquired idle entries are live
    assert idx2.evict_lru() is None


# ------------------------------------------------------- group sharing
def test_group_admission_shares_pages(setup):
    """A G-group of identical prompts prefills once: G-1 admissions are
    full hits mapping the same pages, and tokens are byte-identical to
    the dense layout."""
    model, params = setup
    G = 4
    prompt = _prompts()[2]
    keys = jax.random.split(jax.random.PRNGKey(7), G)
    subs = [(prompt, 2, keys[i], 2) for i in range(G)]
    got, sched = _run_sched(model, params, subs, n_slots=G,
                            cache="paged")
    ref, _ = _run_sched(model, params, subs, n_slots=G, cache="dense")
    _assert_identical(got, ref)
    s = sched.stats
    assert s.prefix_miss_blocks == 2            # one prefill per prompt
    assert s.prefix_hit_blocks == (G - 1) * 2   # every other member hits
    assert s.prefill_blocks == 2
    assert s.shared_pages == 2                  # both prompt pages shared
    # pool footprint: 2 shared prompt pages + G private gen regions,
    # instead of G * 2 prompt pages
    assert s.peak_pages_live <= 2 + G * 2
    # after drain the prompt pages stay cached (idle), nothing live
    assert sched.prefix.n_idle == 2 and sched.prefix.n_active == 0
    assert sched.pages_live == 0 and sched.pages_in_use == 2


def test_sharer_eviction_keeps_survivors_byte_identical(setup):
    """Evicting one sharer decrements refcounts; survivors keep reading
    the shared pages and finish byte-identical to dense.  The shared
    page returns to the free list only at refcount 0 — and with
    retention, not even then (it waits for LRU pressure)."""
    model, params = setup
    prompt = _prompts()[3]
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    budgets = [1, 3, 3]      # member 0 finishes (and evicts) first
    subs = [(prompt, 2, keys[i], budgets[i]) for i in range(3)]

    sched = SlotScheduler(model, n_slots=3, max_len=MAX_LEN, s_max=3,
                          mode="dynamic", tau=0.7, temperature=1.0,
                          eos_id=1, cache="paged")
    for p, pb, k, b in subs:
        sched.submit(p, pb, k, max_new_blocks=b)
    shared_page = None
    comps = {}
    while sched.has_work:
        for c in sched.step(params):
            comps[c.uid] = c
        if shared_page is None and sched.prefix is not None \
                and len(sched.prefix) > 0:
            shared_page = sched.prefix.entry(
                chain_keys(prompt[:2 * BSZ], BSZ)[0]).page
        if sched.n_active > 0:
            # while any sharer lives the page must never be freed
            assert shared_page not in sched._free_pages
    assert len(comps) == 3
    # refcount 0 now, but retention keeps the page cached (not free)
    assert shared_page not in sched._free_pages
    assert sched.prefix.entry(
        chain_keys(prompt[:2 * BSZ], BSZ)[0]).refs == 0
    ref, _ = _run_sched(model, params, subs, n_slots=3, cache="dense")
    _assert_identical(comps, ref)


# --------------------------------------------- pressure / LRU / reuse
def test_lru_reclaim_under_pressure_and_stale_key_hygiene(setup):
    """A tight pool forces LRU reclamation of idle cached pages (extends
    the PR-2 pos-wipe test: a reclaimed page is reused by a *different*
    prompt and must not leak its old keys), and a later partial hit on
    the surviving entry exercises the suffix-only prefill — all
    byte-identical to dense."""
    model, params = setup
    p0, p1, p2, p3 = _prompts()
    keys = jax.random.split(jax.random.PRNGKey(11), 4)
    # usable pages = 5; each request worst-cases 2 prompt + 2 gen
    subs = [(p3, 2, keys[0], 2), (p2, 2, keys[1], 2),
            (p0, 2, keys[2], 2), (p1, 2, keys[3], 2)]
    got, sched = _run_sched(model, params, subs, n_slots=1,
                            cache="paged", n_pages=6)
    ref, _ = _run_sched(model, params, subs, n_slots=1, cache="dense")
    _assert_identical(got, ref)
    s = sched.stats
    # the 5-page pool cannot retain three 2-block prompts + 2 gen pages:
    # idle entries were reclaimed (and their pos wiped before reuse)
    assert s.prefix_evictions > 0
    assert sched.prefix.n_active == 0
    # p1 arrived after p0 and shares only block 0: if that entry
    # survived the pressure it was a partial (suffix-prefill) hit
    assert s.prefix_hit_blocks >= 1
    # invariant at drain: nothing live, free + idle covers the pool
    assert sched.pages_live == 0
    assert len(sched._free_pages) + sched.prefix.n_idle \
        == sched.n_usable_pages


def test_pressure_defers_instead_of_evicting_live_pages(setup):
    """When the pool cannot cover a new request on top of *live*
    references, admission defers — the LRU can only reclaim refcount-0
    entries, so a live slot's pages are untouchable."""
    model, params = setup
    p2, p3 = _prompts()[2], _prompts()[3]
    keys = jax.random.split(jax.random.PRNGKey(13), 2)
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                          mode="dynamic", tau=0.7, temperature=1.0,
                          eos_id=1, cache="paged", n_pages=7)
    # usable 6: first request worst-cases 2+2, second cannot fit 4 more
    sched.submit(p2, 2, keys[0], max_new_blocks=2)
    sched.submit(p3, 2, keys[1], max_new_blocks=2)
    comps = {}
    while sched.has_work:
        for c in sched.step(params):
            comps[c.uid] = c
        if sched.stats.deferred and sched.n_active:
            # the live request's entries must still be referenced
            assert sched.prefix.n_active == 2
    assert sched.stats.deferred > 0
    assert len(comps) == 2
    subs = [(p2, 2, keys[0], 2), (p3, 2, keys[1], 2)]
    ref, _ = _run_sched(model, params, subs, n_slots=2, cache="dense")
    _assert_identical(comps, ref)


# -------------------------------------------------- parity (criterion)
def test_token_parity_on_off_dense_under_group_churn(setup):
    """Acceptance criterion: same rng => byte-identical tokens and step
    maps across prefix_cache on / off / dense, under mixed-length
    admission + eviction churn including a G-group and partial-prefix
    overlaps, on a pool tight enough to defer and reclaim."""
    model, params = setup
    p0, p1, p2, p3 = _prompts()
    G = 4
    keys = jax.random.split(jax.random.PRNGKey(17), G + 5)
    subs = [(p2, 2, keys[i], [2, None, 3][i % 3]) for i in range(G)]
    subs += [(p0, 2, keys[G], 2), (p1, 2, keys[G + 1], None),
             (p3, 2, keys[G + 2], 1), (p0, 1, keys[G + 3], 2),
             (p2, 2, keys[G + 4], 2)]
    runs = {}
    for name, kw in [("dense", dict(cache="dense")),
                     ("off", dict(cache="paged", n_pages=13,
                                  prefix_cache=False)),
                     ("on", dict(cache="paged", n_pages=13,
                                 prefix_cache=True))]:
        runs[name], sched = _run_sched(model, params, list(subs),
                                       n_slots=3, **kw)
        if name == "on":
            s = sched.stats
            assert s.prefix_hit_blocks > 0
            assert s.prefill_blocks \
                == sum(pb for _, pb, _, _ in subs) - s.prefix_hit_blocks
    _assert_identical(runs["dense"], runs["off"])
    _assert_identical(runs["dense"], runs["on"])


def test_engine_group_rollout_prefix_stats(setup):
    """generate_group_ids through a paged+prefix engine matches the
    static path bit-for-bit and reports the G-group hit rate."""
    model, params = setup
    P, G = 2, 3
    prompts = np.stack([_prompts()[2], _prompts()[3]])
    pblocks = np.array([2, 2], np.int32)
    rng = jax.random.PRNGKey(23)
    static = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, mode="dynamic", tau=0.7,
        temperature=1.0, batching="static"))
    a = static.generate_group_ids(prompts, pblocks, rng, G)
    cont = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, mode="dynamic", tau=0.7,
        temperature=1.0, batching="continuous", n_slots=3,
        cache="paged"))
    b = cont.generate_group_ids(prompts, pblocks, rng, G)
    for k in ["gen_blocks", "denoise_steps", "done", "prompt_blocks"]:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    for i in range(P * G):
        hi = int((a["prompt_blocks"][i] + a["gen_blocks"][i]) * BSZ)
        np.testing.assert_array_equal(np.asarray(a["tokens"][i, :hi]),
                                      np.asarray(b["tokens"][i, :hi]))
    # each group's first member misses, the other G-1 hit
    assert cont.stats.prefix_miss_blocks == int(pblocks.sum())
    assert cont.stats.prefix_hit_blocks == (G - 1) * int(pblocks.sum())
    assert cont.stats.prefix_hit_rate == pytest.approx((G - 1) / G)
    assert cont.last_call["prefix_hit_rate"] == pytest.approx(
        (G - 1) / G)
