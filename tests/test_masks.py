"""Mask algebra: visibility predicate invariants (hypothesis) + layouts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip file when absent
from hypothesis import given, settings, strategies as st

from repro.core import masks as M
from repro.kernels import ops as kops


def _rand_inputs(seed, B, L, bsz, s_max=4, prompt_blocks=1):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, L), 4, 100)
    steps = jax.random.randint(jax.random.fold_in(key, 1), (B, L), 0, s_max)
    valid = jnp.ones((B, L), bool)
    return tokens, steps, valid


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), strict=st.booleans(),
       bsz=st.sampled_from([4, 8]))
def test_no_leakage_invariants(seed, strict, bsz):
    """Core soundness: no query may see (a) copy-A keys of FUTURE blocks,
    (b) same-block copy-A keys revealed at or after its own step, or
    (c) copy-B keys of other blocks."""
    B, L = 1, 32
    tokens, steps, valid = _rand_inputs(seed, B, L, bsz)
    ids, meta, _ = M.dirl_layout(tokens, steps, valid, block_size=bsz,
                                 mask_token=101)
    vis = np.asarray(M.visibility(meta, meta, strict=strict))[0]
    copy = np.asarray(meta.copy)[0]
    blk = np.asarray(meta.block)[0]
    stp = np.asarray(meta.step)[0]
    T = 2 * L
    for q in range(L, T):          # copy-B queries
        for k in range(T):
            if not vis[q, k]:
                continue
            if copy[k] == 0:
                assert blk[k] <= blk[q], "future-block leak"
                if blk[k] == blk[q]:
                    assert not strict, "strict mode must not see A same-block"
                    assert stp[k] < stp[q], "same/later-step A leak"
            else:
                assert blk[k] == blk[q], "cross-block B leak"
                if strict:
                    assert stp[k] == stp[q]
                else:
                    assert stp[k] >= stp[q]
    # copy-A queries are block-causal over copy A only
    for q in range(0, L):
        for k in range(T):
            if vis[q, k]:
                assert copy[k] == 0 and blk[k] <= blk[q]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), window=st.sampled_from([4, 8, 16]))
def test_window_composes(seed, window):
    B, L, bsz = 1, 32, 4
    tokens, steps, valid = _rand_inputs(seed, B, L, bsz)
    _, meta, _ = M.dirl_layout(tokens, steps, valid, block_size=bsz,
                               mask_token=101)
    vis = np.asarray(M.visibility(meta, meta, window=window))[0]
    pos = np.asarray(meta.pos)[0]
    q_idx, k_idx = np.nonzero(vis)
    assert ((pos[q_idx] - pos[k_idx]) < window).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), tq=st.sampled_from([4, 8, 16]),
       strict=st.booleans(), window=st.sampled_from([None, 8]))
def test_tile_map_conservative_and_full(seed, tq, strict, window):
    """Every visible element lies in a visited tile; 'full' tiles are
    fully visible (the kernel's skip logic can never drop real work)."""
    B, L, bsz = 2, 32, 8
    tokens, steps, valid = _rand_inputs(seed, B, L, bsz)
    _, meta, _ = M.dirl_layout(tokens, steps, valid, block_size=bsz,
                               mask_token=101)
    qm = kops.pack_meta(meta)
    tm = np.asarray(kops.build_tile_map(qm, qm, tq, tq, window=window))
    vis = np.asarray(M.visibility(meta, meta, strict=strict, window=window))
    T = 2 * L
    vt = vis.reshape(B, T // tq, tq, T // tq, tq)
    any_vis = vt.any(axis=(2, 4))
    all_vis = vt.all(axis=(2, 4))
    assert ((tm > 0) | ~any_vis).all(), "tile map missed visible work"
    # full tiles claimed by the non-strict map must be full in non-strict
    if not strict:
        assert (all_vis | (tm != 2)).all(), "false 'full' tile"


def test_sft_noise_statistics():
    """Masked fraction tracks the sampled block noise level t."""
    key = jax.random.PRNGKey(0)
    B, L, bsz = 64, 128, 16
    tokens = jnp.zeros((B, L), jnp.int32)
    pm = jnp.zeros((B, L), bool)
    valid = jnp.ones((B, L), bool)
    steps, w, t_blk = M.sample_sft_noise(key, tokens, pm, valid,
                                         block_size=bsz)
    frac = steps.reshape(B, L // bsz, bsz).mean(axis=-1)
    err = jnp.abs(frac - t_blk).mean()
    assert float(err) < 0.15
    # weights are 1/t exactly on masked tokens
    w_blk = w.reshape(B, L // bsz, bsz)
    t_rep = jnp.repeat(t_blk[..., None], bsz, axis=-1)
    sel = w_blk > 0
    assert float(jnp.abs(jnp.where(sel, w_blk - 1.0 / t_rep, 0)).max()) < 1e-5


def test_packed_layout_roundtrip():
    B, L, bsz, s_max = 2, 32, 8, 4
    tokens, steps, valid = _rand_inputs(3, B, L, bsz, s_max)
    ids, meta, sel, blk_tok = M.packed_layout(
        tokens, steps, valid, block_size=bsz, mask_token=101, s_max=s_max)
    assert ids.shape == (B, L * (1 + s_max))
    # every valid position selected exactly once across steps
    assert bool((np.asarray(sel).sum(axis=2) == 1).all())
    # copy (k, s) shows token i iff steps[i] < s
    K = L // bsz
    copies = np.asarray(ids[:, L:]).reshape(B, K, s_max, bsz)
    st_ = np.asarray(steps).reshape(B, K, bsz)
    tk = np.asarray(tokens).reshape(B, K, bsz)
    for s in range(s_max):
        shown = copies[:, :, s, :]
        expect = np.where(st_ < s, tk, 101)
        assert (shown == expect).all()
