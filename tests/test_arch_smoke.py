"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED same-family config
(2-3 layers, d_model <= 512, <= 4 experts) and run one forward + one SFT
train step on CPU, asserting output shapes and the absence of NaNs.
Decode-capable archs additionally run one serve_step.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.block_diffusion import sft_loss
from repro.core.masks import plain_layout
from repro.models.model import BlockDiffLM

# the full arch zoo is heavyweight (minutes of compile on CPU): only the
# tiny config stays in tier-1; the rest run under `pytest -m slow`
ARCHS = [pytest.param(a, marks=pytest.mark.slow)
         for a in configs.ASSIGNED_ARCHS + ["sdar-8b"]] + ["tiny"]


def _extra_embeds(cfg, batch):
    if not cfg.n_extra_tokens:
        return None
    return jax.random.normal(
        jax.random.PRNGKey(9),
        (batch, cfg.n_extra_tokens, cfg.extra_embed_dim), jnp.float32)


def _batch(cfg, B=2, n_blocks=4):
    L = cfg.block_size * n_blocks
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, L), 4, cfg.vocab_size - 2)
    prompt_mask = jnp.arange(L)[None, :] < cfg.block_size
    valid = jnp.ones((B, L), bool)
    return {"tokens": tokens, "prompt_mask": prompt_mask, "valid": valid}, L


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 4
    assert cfg.n_experts <= 4
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, L = _batch(cfg)
    mem = _extra_embeds(cfg, 2)
    if mem is not None:
        batch["memory"] = model.compute_memory(params, mem)

    def loss_fn(p):
        return sft_loss(model, p, batch, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn), f"{arch}: non-finite grads"
    assert float(metrics["masked_frac"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step(arch):
    cfg = configs.get_smoke_config(arch)
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, bsz = 2, cfg.block_size
    L = bsz * 4
    batch, _ = _batch(cfg)
    mem = _extra_embeds(cfg, B)
    memory = model.compute_memory(params, mem) if mem is not None else None

    meta = plain_layout(batch["tokens"], batch["valid"],
                        block_size=cfg.block_size)
    caches = model.make_caches(B, L)
    logits_p, out = model.forward_masked(params, batch["tokens"], meta,
                                         caches=caches, memory=memory)
    assert logits_p.shape == (B, L, cfg.vocab_size)
    assert bool(jnp.isfinite(logits_p).all())

    blk = jnp.full((B, bsz), cfg.resolved_mask_token, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(L, L + bsz, dtype=jnp.int32), (B, bsz))
    # cache buffers sized L: decode the "next" block via ring semantics is
    # out of range here, so decode block L-bsz instead (recompute last)
    pos = pos - bsz
    lg, _ = model.decode_step(params, blk, pos, out["caches"],
                              cache_limit=jnp.full((B,), L - bsz),
                              memory=memory)
    assert lg.shape == (B, bsz, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), f"{arch}: non-finite decode logits"
