"""Continuous-batching scheduler: parity with one-shot generate, plus
admission/eviction invariants under ragged arrival order."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decoding
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import ModelServer

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, block_size=8,
                  attn_impl="structured")
BSZ = CFG.block_size
MAX_LEN = 48


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    return model, params, prompt, pblocks


def test_parity_with_one_shot_generate(setup):
    """A 2-slot pool serving 4 requests (forcing queueing + admission
    mid-flight) reproduces one-shot generate token-for-token, step-map
    included, under the same per-sequence rng keys and temperature
    sampling — the DiPO-exactness property."""
    model, params, prompt, pblocks = setup
    rng = jax.random.PRNGKey(7)
    gen = decoding.generate(model, params, jnp.asarray(prompt),
                            jnp.asarray(pblocks), rng, max_len=MAX_LEN,
                            s_max=4, mode="dynamic", tau=0.6,
                            temperature=1.0, eos_id=1)

    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=4,
                          mode="dynamic", tau=0.6, temperature=1.0,
                          eos_id=1)
    keys = jax.random.split(rng, 4)
    max_new = (MAX_LEN - prompt.shape[1]) // BSZ
    for i in range(4):
        sched.submit(prompt[i], pblocks[i], keys[i],
                     max_new_blocks=max_new)
    comps = {c.uid: c for c in sched.run(params)}
    assert sorted(comps) == [0, 1, 2, 3]
    for i in range(4):
        c = comps[i]
        gb = int(gen["gen_blocks"][i])
        assert c.gen_blocks == gb
        hi = (int(pblocks[i]) + gb) * BSZ
        np.testing.assert_array_equal(c.tokens[:hi],
                                      np.asarray(gen["tokens"][i, :hi]))
        np.testing.assert_array_equal(c.steps[:hi],
                                      np.asarray(gen["steps"][i, :hi]))
        assert c.denoise_steps == int(gen["denoise_steps"][i])


def test_engine_static_continuous_identical(setup):
    """The engine's two batching paths agree on the full gen dict."""
    model, params, prompt, pblocks = setup
    rng = jax.random.PRNGKey(11)
    outs = {}
    for mode in ["static", "continuous"]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=4, mode="dynamic", tau=0.6,
            temperature=1.0, batching=mode, n_slots=3))
        outs[mode] = eng.generate_ids(prompt, pblocks, rng)
    a, b = outs["static"], outs["continuous"]
    for k in ["gen_blocks", "denoise_steps", "done", "prompt_blocks"]:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    for i in range(4):
        hi = int((pblocks[i] + a["gen_blocks"][i]) * BSZ)
        np.testing.assert_array_equal(np.asarray(a["tokens"][i, :hi]),
                                      np.asarray(b["tokens"][i, :hi]))
        np.testing.assert_array_equal(np.asarray(a["steps"][i, :hi]),
                                      np.asarray(b["steps"][i, :hi]))


def test_admission_eviction_invariants(setup):
    """Ragged arrival order on a small pool: every request completes
    exactly once, prompts survive verbatim, slot occupancy never exceeds
    the pool, and the utilization counters add up."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                          mode="dynamic", tau=0.9, eos_id=1)
    key = jax.random.PRNGKey(3)
    submitted = {}
    completions = []
    arrivals = [2, 0, 0, 1, 3, 0, 1]   # requests arriving per tick
    t = 0
    while arrivals or sched.has_work:
        n_new = arrivals.pop(0) if arrivals else 0
        for _ in range(n_new):
            key, k = jax.random.split(key)
            i = len(submitted) % 4
            uid = sched.submit(prompt[i], pblocks[i], k)
            submitted[uid] = i
        assert sched.n_active <= sched.n_slots
        completions.extend(sched.step(params))
        t += 1
        assert t < 200

    # exactly-once completion, in-order uids
    uids = [c.uid for c in completions]
    assert sorted(uids) == sorted(submitted)
    assert len(set(uids)) == len(uids)

    for c in completions:
        i = submitted[c.uid]
        # prompt region preserved verbatim
        np.testing.assert_array_equal(
            c.tokens[:int(pblocks[i]) * BSZ],
            prompt[i, :int(pblocks[i]) * BSZ])
        # generated region fully revealed (no MASK left)
        lo = c.prompt_blocks * BSZ
        hi = lo + c.gen_blocks * BSZ
        assert (c.tokens[lo:hi] != CFG.resolved_mask_token).all()
        assert 0 < c.gen_blocks <= sched.n_blocks_total - c.prompt_blocks
        assert c.admitted_tick <= c.completed_tick

    st = sched.stats
    assert st.admitted == st.completed == len(submitted)
    assert st.slot_ticks == st.ticks * sched.n_slots
    assert 0 < st.active_slot_ticks <= st.slot_ticks
    assert st.gen_tokens == sum(c.gen_blocks for c in completions) * BSZ
    assert st.denoise_steps == sum(c.denoise_steps for c in completions)
    # pool drained: all slots free again
    assert sched.n_active == 0 and sched.n_queued == 0


def test_zero_budget_request_completes_without_slot(setup):
    """A prompt that already fills the cache (or a zero block budget)
    completes immediately with gen_blocks=0 and never occupies a slot —
    matching one-shot generate's zero-iteration behaviour."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3)
    full = np.full((MAX_LEN,), 5, np.int32)
    sched.submit(full, MAX_LEN // BSZ, jax.random.PRNGKey(0))
    sched.submit(prompt[0], pblocks[0], jax.random.PRNGKey(1),
                 max_new_blocks=0)
    comps = list(sched.run(params))
    assert [c.gen_blocks for c in comps] == [0, 0]
    np.testing.assert_array_equal(comps[0].tokens, full)
    assert sched.stats.ticks == 0 and sched.n_active == 0


def test_generate_texts_trims_at_eos(setup, monkeypatch):
    """Completions are cut at the first EOS, not the block-padded tail."""
    model, params, _, _ = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=4, mode="dynamic", tau=0.6,
        batching="continuous", n_slots=2))
    # craft a completion: prompt block, then "ok" ++ EOS ++ junk tail
    tokens = np.full((1, MAX_LEN), 5, np.int32)
    gen_row = eng.tok.encode("ok") + [eng.tok.eos_id] + \
        eng.tok.encode("JUNKJUNKJUNK")
    tokens[0, BSZ:BSZ + len(gen_row)] = gen_row
    fake = {"tokens": jnp.asarray(tokens),
            "steps": jnp.zeros((1, MAX_LEN), jnp.int32),
            "gen_blocks": jnp.asarray([2], jnp.int32),
            "prompt_blocks": jnp.asarray([1], jnp.int32),
            "done": jnp.asarray([True]),
            "denoise_steps": jnp.asarray([2], jnp.int32)}
    monkeypatch.setattr(eng, "generate_ids", lambda *a, **k: fake)
    out, = eng.generate_texts(["x"], jax.random.PRNGKey(5))
    assert out == "ok"          # junk beyond the first EOS is trimmed


def test_stream_abandoned_midway_keeps_undelivered(setup):
    """Taking only the first result from stream() must not lose the
    rest — undelivered completions stay pending for the next call."""
    model, params, prompt, pblocks = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, mode="dynamic", tau=0.9,
        batching="continuous", n_slots=3))
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    uids = {eng.submit(f"q{i}", keys[i]) for i in range(3)}
    first = next(eng.stream())          # abandon the generator here
    rest = dict(eng.stream())
    assert {first[0], *rest} == uids
    assert len(rest) == 2


def test_zero_budget_done_flag_matches_static(setup):
    """A prompt filling the cache: both paths return done=False (the
    one-shot loop runs zero trips and never flags it)."""
    model, params, _, _ = setup
    full = np.full((2, MAX_LEN), 5, np.int32)
    pb = np.full((2,), MAX_LEN // BSZ, np.int32)
    rng = jax.random.PRNGKey(0)
    outs = {}
    for mode in ["static", "continuous"]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=3, batching=mode, n_slots=2))
        outs[mode] = eng.generate_ids(full, pb, rng)
    for k in ["done", "gen_blocks", "tokens"]:
        np.testing.assert_array_equal(np.asarray(outs["static"][k]),
                                      np.asarray(outs["continuous"][k]))
    assert not np.asarray(outs["continuous"]["done"]).any()


def test_stream_request_survives_batch_drain(setup):
    """A streaming submit() that finishes while generate_ids drains the
    shared pool is buffered and still delivered by the next stream()."""
    model, params, prompt, pblocks = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, mode="dynamic", tau=0.9,
        batching="continuous", n_slots=2))
    uid = eng.submit("hi", jax.random.PRNGKey(0))
    eng.generate_ids(prompt, pblocks, jax.random.PRNGKey(1))
    got = dict(eng.stream())
    assert uid in got and isinstance(got[uid], str)


def test_offline_store_gc(tmp_path, setup):
    """Superseded checkpoints are reaped; only the latest survives."""
    import os
    from repro.serving.server import OfflineWeightStore
    model, params, _, _ = setup
    store = OfflineWeightStore(params, root=str(tmp_path))
    for _ in range(3):
        store.update_weights(params)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".msgpack")]
    assert files == [f"ckpt_{store.version}.msgpack"]
    # latest is still loadable
    assert store.params is not None
