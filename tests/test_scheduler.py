"""Continuous-batching scheduler: parity with one-shot generate, plus
admission/eviction invariants under ragged arrival order."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decoding
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import ModelServer

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, block_size=8,
                  attn_impl="structured")
BSZ = CFG.block_size
MAX_LEN = 48


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    return model, params, prompt, pblocks


def test_parity_with_one_shot_generate(setup):
    """A 2-slot pool serving 4 requests (forcing queueing + admission
    mid-flight) reproduces one-shot generate token-for-token, step-map
    included, under the same per-sequence rng keys and temperature
    sampling — the DiPO-exactness property."""
    model, params, prompt, pblocks = setup
    rng = jax.random.PRNGKey(7)
    gen = decoding.generate(model, params, jnp.asarray(prompt),
                            jnp.asarray(pblocks), rng, max_len=MAX_LEN,
                            s_max=4, mode="dynamic", tau=0.6,
                            temperature=1.0, eos_id=1)

    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=4,
                          mode="dynamic", tau=0.6, temperature=1.0,
                          eos_id=1)
    keys = jax.random.split(rng, 4)
    for i in range(4):
        sched.submit(prompt[i], pblocks[i], keys[i])
    comps = {c.uid: c for c in sched.run(params)}
    assert sorted(comps) == [0, 1, 2, 3]
    for i in range(4):
        c = comps[i]
        gb = int(gen["gen_blocks"][i])
        assert c.gen_blocks == gb
        hi = (int(pblocks[i]) + gb) * BSZ
        np.testing.assert_array_equal(c.tokens[:hi],
                                      np.asarray(gen["tokens"][i, :hi]))
        np.testing.assert_array_equal(c.steps[:hi],
                                      np.asarray(gen["steps"][i, :hi]))
        assert c.denoise_steps == int(gen["denoise_steps"][i])


def test_engine_static_continuous_identical(setup):
    """The engine's two batching paths agree on the full gen dict."""
    model, params, prompt, pblocks = setup
    rng = jax.random.PRNGKey(11)
    outs = {}
    for mode in ["static", "continuous"]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=4, mode="dynamic", tau=0.6,
            temperature=1.0, batching=mode, n_slots=3))
        outs[mode] = eng.generate_ids(prompt, pblocks, rng)
    a, b = outs["static"], outs["continuous"]
    for k in ["gen_blocks", "denoise_steps", "done", "prompt_blocks"]:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    for i in range(4):
        hi = int((pblocks[i] + a["gen_blocks"][i]) * BSZ)
        np.testing.assert_array_equal(np.asarray(a["tokens"][i, :hi]),
                                      np.asarray(b["tokens"][i, :hi]))
        np.testing.assert_array_equal(np.asarray(a["steps"][i, :hi]),
                                      np.asarray(b["steps"][i, :hi]))


def test_admission_eviction_invariants(setup):
    """Ragged arrival order on a small pool: every request completes
    exactly once, prompts survive verbatim, slot occupancy never exceeds
    the pool, and the utilization counters add up."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                          mode="dynamic", tau=0.9, eos_id=1)
    key = jax.random.PRNGKey(3)
    submitted = {}
    completions = []
    arrivals = [2, 0, 0, 1, 3, 0, 1]   # requests arriving per tick
    t = 0
    while arrivals or sched.has_work:
        n_new = arrivals.pop(0) if arrivals else 0
        for _ in range(n_new):
            key, k = jax.random.split(key)
            i = len(submitted) % 4
            uid = sched.submit(prompt[i], pblocks[i], k)
            submitted[uid] = i
        assert sched.n_active <= sched.n_slots
        completions.extend(sched.step(params))
        t += 1
        assert t < 200

    # exactly-once completion, in-order uids
    uids = [c.uid for c in completions]
    assert sorted(uids) == sorted(submitted)
    assert len(set(uids)) == len(uids)

    for c in completions:
        i = submitted[c.uid]
        # prompt region preserved verbatim
        np.testing.assert_array_equal(
            c.tokens[:int(pblocks[i]) * BSZ],
            prompt[i, :int(pblocks[i]) * BSZ])
        # generated region fully revealed (no MASK left)
        lo = c.prompt_blocks * BSZ
        hi = lo + c.gen_blocks * BSZ
        assert (c.tokens[lo:hi] != CFG.resolved_mask_token).all()
        assert 0 < c.gen_blocks <= sched.n_blocks_total - c.prompt_blocks
        assert c.admitted_tick <= c.completed_tick

    st = sched.stats
    assert st.admitted == st.completed == len(submitted)
    assert st.slot_ticks == st.ticks * sched.n_slots
    assert 0 < st.active_slot_ticks <= st.slot_ticks
    assert st.gen_tokens == sum(c.gen_tokens for c in completions)
    for c in completions:
        # gen_tokens is cut at the first EOS inclusive, never padded
        assert 0 < c.gen_tokens <= c.gen_blocks * BSZ
        region = c.tokens[c.prompt_blocks * BSZ:
                          (c.prompt_blocks + c.gen_blocks) * BSZ]
        eos = np.flatnonzero(region == 1)
        assert c.gen_tokens == (eos[0] + 1 if eos.size else region.size)
    assert st.denoise_steps == sum(c.denoise_steps for c in completions)
    # pool drained: all slots free again
    assert sched.n_active == 0 and sched.n_queued == 0


def test_zero_budget_request_completes_without_slot(setup):
    """A prompt that already fills the cache (or a zero block budget)
    completes immediately with gen_blocks=0 and never occupies a slot —
    matching one-shot generate's zero-iteration behaviour."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3)
    full = np.full((MAX_LEN,), 5, np.int32)
    sched.submit(full, MAX_LEN // BSZ, jax.random.PRNGKey(0))
    sched.submit(prompt[0], pblocks[0], jax.random.PRNGKey(1),
                 max_new_blocks=0)
    comps = list(sched.run(params))
    assert [c.gen_blocks for c in comps] == [0, 0]
    np.testing.assert_array_equal(comps[0].tokens, full)
    assert sched.stats.ticks == 0 and sched.n_active == 0


def test_generate_texts_trims_at_eos(setup, monkeypatch):
    """Completions are cut at the first EOS, not the block-padded tail."""
    model, params, _, _ = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=4, mode="dynamic", tau=0.6,
        batching="continuous", n_slots=2))
    # craft a completion: prompt block, then "ok" ++ EOS ++ junk tail
    tokens = np.full((1, MAX_LEN), 5, np.int32)
    gen_row = eng.tok.encode("ok") + [eng.tok.eos_id] + \
        eng.tok.encode("JUNKJUNKJUNK")
    tokens[0, BSZ:BSZ + len(gen_row)] = gen_row
    fake = {"tokens": jnp.asarray(tokens),
            "steps": jnp.zeros((1, MAX_LEN), jnp.int32),
            "gen_blocks": jnp.asarray([2], jnp.int32),
            "prompt_blocks": jnp.asarray([1], jnp.int32),
            "done": jnp.asarray([True]),
            "denoise_steps": jnp.asarray([2], jnp.int32)}
    monkeypatch.setattr(eng, "generate_ids", lambda *a, **k: fake)
    out, = eng.generate_texts(["x"], jax.random.PRNGKey(5))
    assert out == "ok"          # junk beyond the first EOS is trimmed


def test_stream_abandoned_midway_keeps_undelivered(setup):
    """Taking only the first result from stream() must not lose the
    rest — undelivered completions stay pending for the next call."""
    model, params, prompt, pblocks = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, mode="dynamic", tau=0.9,
        batching="continuous", n_slots=3))
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    uids = {eng.submit(f"q{i}", keys[i]) for i in range(3)}
    first = next(eng.stream())          # abandon the generator here
    rest = {out.uid: out for out in eng.stream()}
    assert {first.uid, *rest} == uids
    assert len(rest) == 2
    for out in rest.values():           # structured streaming records
        assert out.finish_reason in ("eos", "length")
        assert out.latency_ticks == out.completed_tick - out.admitted_tick
        assert isinstance(out.text, str)


def test_zero_budget_done_flag_matches_static(setup):
    """A prompt filling the cache: both paths return done=False (the
    one-shot loop runs zero trips and never flags it)."""
    model, params, _, _ = setup
    full = np.full((2, MAX_LEN), 5, np.int32)
    pb = np.full((2,), MAX_LEN // BSZ, np.int32)
    rng = jax.random.PRNGKey(0)
    outs = {}
    for mode in ["static", "continuous"]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=3, batching=mode, n_slots=2))
        outs[mode] = eng.generate_ids(full, pb, rng)
    for k in ["done", "gen_blocks", "tokens"]:
        np.testing.assert_array_equal(np.asarray(outs["static"][k]),
                                      np.asarray(outs["continuous"][k]))
    assert not np.asarray(outs["continuous"]["done"]).any()


def test_stream_request_survives_batch_drain(setup):
    """A streaming submit() that finishes while generate_ids drains the
    shared pool is buffered and still delivered by the next stream()."""
    model, params, prompt, pblocks = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, mode="dynamic", tau=0.9,
        batching="continuous", n_slots=2))
    uid = eng.submit("hi", jax.random.PRNGKey(0))
    eng.generate_ids(prompt, pblocks, jax.random.PRNGKey(1))
    got = {out.uid: out for out in eng.stream()}
    assert uid in got and isinstance(got[uid].text, str)


def _drive_interleaved(model, params, sched, prompt, pblocks, keys,
                       arrivals, budgets):
    """Submit requests on a fixed arrival schedule and drain the pool."""
    submitted = {}
    completions = []
    while arrivals or sched.has_work:
        n_new = arrivals.pop(0) if arrivals else 0
        for _ in range(n_new):
            i = len(submitted)
            uid = sched.submit(prompt[i % 4], pblocks[i % 4], keys[i],
                               max_new_blocks=budgets[i % len(budgets)])
            submitted[uid] = i
        completions.extend(sched.step(params))
        assert sched.stats.ticks < 500
    return submitted, completions


def test_paged_matches_dense_under_churn(setup):
    """Paged and dense caches are byte-identical — tokens, step maps and
    denoise counts — for the same per-request rng keys, under
    mixed-length admission and eviction churn (the acceptance-criterion
    parity contract).  The paged pool is sized so admissions get
    deferred mid-run, forcing page reuse across requests."""
    model, params, prompt, pblocks = setup
    keys = jax.random.split(jax.random.PRNGKey(13), 10)
    arrivals = [3, 0, 2, 1, 0, 2, 2]
    budgets = [3, None, 2, None]        # mixed block budgets
    outs = {}
    for cache in ["dense", "paged"]:
        # prefix_cache=False: this test pins the PR-2 exclusive-page
        # allocator lifecycle (allocs == frees, no retention);
        # tests/test_prefix_cache.py covers the shared-page variant
        kw = dict(n_pages=13, prefix_cache=False) \
            if cache == "paged" else {}
        sched = SlotScheduler(model, n_slots=3, max_len=MAX_LEN, s_max=4,
                              mode="dynamic", tau=0.6, temperature=1.0,
                              eos_id=1, cache=cache, **kw)
        submitted, comps = _drive_interleaved(
            model, params, sched, prompt, pblocks, keys, list(arrivals),
            budgets)
        assert sorted(c.uid for c in comps) == sorted(submitted)
        outs[cache] = ({c.uid: c for c in comps}, sched.stats)
    dense, paged = outs["dense"][0], outs["paged"][0]
    for uid in dense:
        d, p = dense[uid], paged[uid]
        assert d.gen_blocks == p.gen_blocks
        assert d.denoise_steps == p.denoise_steps
        assert d.gen_tokens == p.gen_tokens
        hi = (d.prompt_blocks + d.gen_blocks) * BSZ
        np.testing.assert_array_equal(d.tokens[:hi], p.tokens[:hi])
        np.testing.assert_array_equal(d.steps[:hi], p.steps[:hi])
    pstats = outs["paged"][1]
    assert pstats.deferred > 0          # the tight pool really churned
    assert pstats.page_allocs == pstats.page_frees > 0
    assert pstats.peak_pages_in_use <= 12


@pytest.mark.parametrize("variant", ["hybrid", "swa", "hybrid-pallas"])
def test_paged_matches_dense_hybrid_and_window(variant):
    """Paged caching must also hold for per-slot recurrent states
    (hybrid SSM layers scatter into the slot row while attention layers
    scatter into pages) and sliding-window layers (dense uses a ring
    buffer, paged holds all pages and masks by window).  The
    ``hybrid-pallas`` variant runs the paged side through the in-place
    page-aware kernel — attention layers read the pool in place while
    SSM layers keep per-slot state (tests/test_paged_attn.py covers the
    pure-attention kernel grid)."""
    if variant.startswith("hybrid"):
        cfg = CFG.replace(name="h", arch_type="hybrid", ssm_kind="mamba",
                          attn_every=2)
    else:
        cfg = CFG.replace(name="w", sliding_window=16)
    kernel = "pallas" if variant.endswith("pallas") else "ref"
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    keys = jax.random.split(jax.random.PRNGKey(6), 6)
    outs = {}
    for cache in ["dense", "paged"]:
        kw = dict(n_pages=13, kernel=kernel) if cache == "paged" else {}
        sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                              mode="dynamic", tau=0.8, eos_id=1,
                              cache=cache, **kw)
        for i in range(6):
            sched.submit(prompt[i % 4], pblocks[i % 4], keys[i],
                         max_new_blocks=[2, None, 3][i % 3])
        outs[cache] = {c.uid: c for c in sched.run(params)}
    assert sorted(outs["dense"]) == sorted(outs["paged"])
    for uid, d in outs["dense"].items():
        p = outs["paged"][uid]
        assert d.gen_blocks == p.gen_blocks
        hi = (d.prompt_blocks + d.gen_blocks) * BSZ
        np.testing.assert_array_equal(d.tokens[:hi], p.tokens[:hi])
        np.testing.assert_array_equal(d.steps[:hi], p.steps[:hi])


def test_paged_out_of_pages_defers_and_recovers(setup):
    """A pool too small for two concurrent requests defers the second
    (no crash), admits it once the first eviction frees pages, and both
    complete with the exact tokens a roomy pool produces."""
    model, params, prompt, pblocks = setup
    keys = jax.random.split(jax.random.PRNGKey(4), 2)
    K = MAX_LEN // BSZ

    def run(n_pages):
        # prefix_cache=False: asserts every page returns to the free
        # list at drain, which retention deliberately violates (idle
        # cached pages); the prefix-on deferral/recovery behaviour is
        # covered in tests/test_prefix_cache.py
        sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                              mode="dynamic", tau=0.9, eos_id=1,
                              cache="paged", n_pages=n_pages,
                              prefix_cache=False)
        for i in range(2):
            sched.submit(prompt[i], pblocks[i], keys[i])
        comps = {c.uid: c for c in sched.run(params)}
        return comps, sched

    # each request may need up to K pages -> one at a time
    tight, sched_t = run(K + 1)
    roomy, _ = run(2 * K + 1)
    assert sched_t.stats.deferred > 0
    assert sched_t.stats.peak_active == 1       # never ran concurrently
    assert sorted(tight) == sorted(roomy) == [0, 1]
    for uid in tight:
        t, r = tight[uid], roomy[uid]
        assert t.gen_blocks == r.gen_blocks
        hi = (t.prompt_blocks + t.gen_blocks) * BSZ
        np.testing.assert_array_equal(t.tokens[:hi], r.tokens[:hi])
        np.testing.assert_array_equal(t.steps[:hi], r.steps[:hi])
    # every page returned to the free list
    assert sched_t.pages_in_use == 0


def test_paged_unservable_request_raises(setup):
    """A request whose worst case exceeds the whole pool can never be
    admitted: that's a configuration error, not backpressure."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=1, max_len=MAX_LEN, s_max=3,
                          cache="paged", n_pages=3)
    sched.submit(prompt[0], pblocks[0], jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="pages"):
        sched.step(params)


def test_engine_paged_continuous_matches_static(setup):
    """The engine's paged-continuous path keeps the generate_ids
    contract bit-for-bit against the one-shot static path."""
    model, params, prompt, pblocks = setup
    rng = jax.random.PRNGKey(17)
    outs = {}
    for mode, cache in [("static", "dense"), ("continuous", "paged")]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=4, mode="dynamic", tau=0.6,
            temperature=1.0, batching=mode, n_slots=3, cache=cache))
        outs[mode] = eng.generate_ids(prompt, pblocks, rng)
        stats = eng.stats
    a, b = outs["static"], outs["continuous"]
    for k in ["gen_blocks", "denoise_steps", "done", "prompt_blocks"]:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    for i in range(4):
        hi = int((pblocks[i] + a["gen_blocks"][i]) * BSZ)
        np.testing.assert_array_equal(np.asarray(a["tokens"][i, :hi]),
                                      np.asarray(b["tokens"][i, :hi]))
        np.testing.assert_array_equal(np.asarray(a["steps"][i, :hi]),
                                      np.asarray(b["steps"][i, :hi]))
    assert stats.total_tokens > 0


def test_offline_store_gc(tmp_path, setup):
    """Superseded checkpoints are reaped; only the latest survives."""
    import os
    from repro.serving.server import OfflineWeightStore
    model, params, _, _ = setup
    store = OfflineWeightStore(params, root=str(tmp_path))
    for _ in range(3):
        store.update_weights(params)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".msgpack")]
    assert files == [f"ckpt_{store.version}.msgpack"]
    # latest is still loadable
    assert store.params is not None
