"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import dirl_layout, packed_layout, sample_sft_noise
from repro.kernels import ops


def _setup(B, L, H, Hkv, D, Dv, bsz, dtype, seed=0, s_max=4, kind="sft"):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, L), 4, 100)
    valid = jnp.ones((B, L), bool)
    if kind == "sft":
        pm = jnp.arange(L)[None, :] < bsz
        steps, _, _ = sample_sft_noise(key, tokens, pm, valid,
                                       block_size=bsz)
        _, meta, _ = dirl_layout(tokens, steps, valid, block_size=bsz,
                                 mask_token=101, noised=True)
        strict = False
    else:
        steps = jax.random.randint(jax.random.fold_in(key, 1), (B, L),
                                   0, s_max)
        _, meta, _, _ = packed_layout(tokens, steps, valid, block_size=bsz,
                                      mask_token=101, s_max=s_max)
        strict = True
    T = meta.length
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dv)).astype(dtype)
    return q, k, v, meta, strict


SHAPES = [
    # B, L, H, Hkv, D, Dv, bsz
    (1, 32, 4, 4, 16, 16, 8),       # MHA
    (2, 64, 4, 2, 16, 16, 8),       # GQA
    (1, 64, 4, 1, 32, 24, 16),      # MQA + Dv != D (absorbed MLA shape)
    (2, 32, 8, 2, 8, 8, 4),         # small block
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_kernel_matches_oracle_sft(shape, dtype):
    B, L, H, Hkv, D, Dv, bsz = shape
    q, k, v, meta, strict = _setup(B, L, H, Hkv, D, Dv, bsz,
                                   jnp.dtype(dtype))
    o_ref = ops.attention(q, k, v, meta, meta, impl="ref", strict=strict)
    o_pal = ops.attention(q, k, v, meta, meta, impl="pallas_interpret",
                          strict=strict, tq=16, tk=16)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [None, 8, 24])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_kernel_window_softcap(window, softcap):
    q, k, v, meta, _ = _setup(2, 64, 4, 2, 16, 16, 8, jnp.float32)
    kw = dict(window=window, softcap=softcap)
    o_ref = ops.attention(q, k, v, meta, meta, impl="ref", **kw)
    o_pal = ops.attention(q, k, v, meta, meta, impl="pallas_interpret",
                          tq=16, tk=16, **kw)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kind", ["sft", "packed"])
def test_all_impls_agree(kind):
    q, k, v, meta, strict = _setup(2, 64, 4, 2, 16, 16, 8, jnp.float32,
                                   kind=kind)
    o_ref = ops.attention(q, k, v, meta, meta, impl="ref", strict=strict)
    o_chk = ops.attention(q, k, v, meta, meta, impl="chunked",
                          strict=strict)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_chk),
                               atol=2e-5, rtol=2e-5)
    if kind == "sft":
        o_str = ops.attention(q, k, v, meta, meta, impl="structured",
                              dup_len=64, block_size=8)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_str),
                                   atol=2e-5, rtol=2e-5)


def test_tile_skip_fraction():
    """The kernel's block-sparse map visits ~1/4 of dense tiles on the SFT
    layout (the FLOP saving the paper gets from FlexAttention)."""
    q, k, v, meta, _ = _setup(1, 128, 4, 2, 16, 16, 16, jnp.float32)
    qm = ops.pack_meta(meta)
    tm = ops.build_tile_map(qm, qm, 16, 16)
    stats = ops.tile_map_stats(tm)
    assert stats["visit_fraction"] < 0.45, stats
