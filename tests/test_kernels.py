"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import dirl_layout, packed_layout, sample_sft_noise
from repro.kernels import ops


def _setup(B, L, H, Hkv, D, Dv, bsz, dtype, seed=0, s_max=4, kind="sft"):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, L), 4, 100)
    valid = jnp.ones((B, L), bool)
    if kind == "sft":
        pm = jnp.arange(L)[None, :] < bsz
        steps, _, _ = sample_sft_noise(key, tokens, pm, valid,
                                       block_size=bsz)
        _, meta, _ = dirl_layout(tokens, steps, valid, block_size=bsz,
                                 mask_token=101, noised=True)
        strict = False
    else:
        steps = jax.random.randint(jax.random.fold_in(key, 1), (B, L),
                                   0, s_max)
        _, meta, _, _ = packed_layout(tokens, steps, valid, block_size=bsz,
                                      mask_token=101, s_max=s_max)
        strict = True
    T = meta.length
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dv)).astype(dtype)
    return q, k, v, meta, strict


SHAPES = [
    # B, L, H, Hkv, D, Dv, bsz
    (1, 32, 4, 4, 16, 16, 8),       # MHA
    (2, 64, 4, 2, 16, 16, 8),       # GQA
    (1, 64, 4, 1, 32, 24, 16),      # MQA + Dv != D (absorbed MLA shape)
    (2, 32, 8, 2, 8, 8, 4),         # small block
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_kernel_matches_oracle_sft(shape, dtype):
    B, L, H, Hkv, D, Dv, bsz = shape
    q, k, v, meta, strict = _setup(B, L, H, Hkv, D, Dv, bsz,
                                   jnp.dtype(dtype))
    o_ref = ops.attention(q, k, v, meta, meta, impl="ref", strict=strict)
    o_pal = ops.attention(q, k, v, meta, meta, impl="pallas_interpret",
                          strict=strict, tq=16, tk=16)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [None, 8, 24])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_kernel_window_softcap(window, softcap):
    q, k, v, meta, _ = _setup(2, 64, 4, 2, 16, 16, 8, jnp.float32)
    kw = dict(window=window, softcap=softcap)
    o_ref = ops.attention(q, k, v, meta, meta, impl="ref", **kw)
    o_pal = ops.attention(q, k, v, meta, meta, impl="pallas_interpret",
                          tq=16, tk=16, **kw)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pal),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kind", ["sft", "packed"])
def test_all_impls_agree(kind):
    q, k, v, meta, strict = _setup(2, 64, 4, 2, 16, 16, 8, jnp.float32,
                                   kind=kind)
    o_ref = ops.attention(q, k, v, meta, meta, impl="ref", strict=strict)
    o_chk = ops.attention(q, k, v, meta, meta, impl="chunked",
                          strict=strict)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_chk),
                               atol=2e-5, rtol=2e-5)
    if kind == "sft":
        o_str = ops.attention(q, k, v, meta, meta, impl="structured",
                              dup_len=64, block_size=8)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_str),
                                   atol=2e-5, rtol=2e-5)


def test_tile_skip_fraction():
    """The kernel's block-sparse map visits ~1/4 of dense tiles on the SFT
    layout (the FLOP saving the paper gets from FlexAttention)."""
    q, k, v, meta, _ = _setup(1, 128, 4, 2, 16, 16, 16, jnp.float32)
    qm = ops.pack_meta(meta)
    tm = ops.build_tile_map(qm, qm, 16, 16)
    stats = ops.tile_map_stats(tm)
    assert stats["visit_fraction"] < 0.45, stats
    assert stats["partial_fraction"] + stats["full_fraction"] == \
        pytest.approx(stats["visit_fraction"])


# --------------------------- gradients (custom VJP) ------------------------


GRAD_TOL = 5e-4  # f32, vs autodiff through the ref/structured oracles


def _grads(impl, q, k, v, meta, strict, **kw):
    """value+grads of a nontrivial scalar through ``ops.attention``."""
    def f(q, k, v):
        o = ops.attention(q, k, v, meta, meta, impl=impl, strict=strict,
                          **kw)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))
    loss, grads = jax.jit(
        jax.value_and_grad(f, argnums=(0, 1, 2)))(q, k, v)
    return loss, grads


def _assert_grads_close(a, b, tol=GRAD_TOL):
    la, ga = a
    lb, gb = b
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=tol,
                               rtol=tol)
    for x, y in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", ["sft", "packed"])
def test_grad_parity_vs_ref(shape, kind):
    """pallas VJP == autodiff through the dense oracle (MHA/GQA/MQA+MLA
    head groupings x sft/strict-packed layouts)."""
    B, L, H, Hkv, D, Dv, bsz = shape
    q, k, v, meta, strict = _setup(B, L, H, Hkv, D, Dv, bsz, jnp.float32,
                                   kind=kind)
    ref = _grads("ref", q, k, v, meta, strict)
    pal = _grads("pallas_interpret", q, k, v, meta, strict, tq=16, tk=16)
    _assert_grads_close(ref, pal)


@pytest.mark.parametrize("window", [None, 8, 24])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_grad_parity_window_softcap(window, softcap):
    """The backward's score chain rule handles softcap's tanh and the
    window term (which enters only through the mask)."""
    q, k, v, meta, strict = _setup(2, 64, 4, 2, 16, 16, 8, jnp.float32)
    kw = dict(window=window, softcap=softcap, tq=16, tk=16)
    ref = _grads("ref", q, k, v, meta, strict, window=window,
                 softcap=softcap)
    pal = _grads("pallas_interpret", q, k, v, meta, strict, **kw)
    _assert_grads_close(ref, pal)


def test_grad_parity_vs_structured():
    """pallas VJP == autodiff through the structured dup-layout fast
    path (the impl the trainers used before the kernel became
    differentiable)."""
    q, k, v, meta, strict = _setup(2, 64, 4, 2, 16, 16, 8, jnp.float32)
    st = _grads("structured", q, k, v, meta, strict, dup_len=64,
                block_size=8)
    pal = _grads("pallas_interpret", q, k, v, meta, strict, tq=16, tk=16)
    _assert_grads_close(st, pal)


def test_grad_zero_at_invalid_padding():
    """INVALID_COPY (padding) positions — empty tile rows included — get
    *exactly* zero gradients on both the query and key/value sides."""
    from repro.core.masks import dirl_layout, sample_sft_noise

    B, L, H, Hkv, D, Dv, bsz = 2, 64, 4, 2, 16, 16, 8
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, L), 4, 100)
    # a 16-token invalid tail: its tiles are provably empty and the
    # kernel never touches them in forward or backward
    valid = jnp.broadcast_to(jnp.arange(L)[None, :] < (L - 16), (B, L))
    pm = jnp.arange(L)[None, :] < bsz
    steps, _, _ = sample_sft_noise(key, tokens, pm, valid, block_size=bsz)
    _, meta, _ = dirl_layout(tokens, steps, valid, block_size=bsz,
                             mask_token=101, noised=True)
    T = meta.length
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, Dv))
    _, (dq, dk, dv) = _grads("pallas_interpret", q, k, v, meta, False,
                             tq=16, tk=16)
    invalid = ~jnp.asarray(meta.valid)
    assert float(jnp.max(jnp.abs(dq[invalid]))) == 0.0
    assert float(jnp.max(jnp.abs(dk[invalid]))) == 0.0
    assert float(jnp.max(jnp.abs(dv[invalid]))) == 0.0
    # and the valid region still trains
    assert float(jnp.max(jnp.abs(dq))) > 0.0


# --------------------------- trainer integration ---------------------------


def _tiny_cfg(attn_impl, **kw):
    from repro.models.config import ModelConfig
    return ModelConfig(d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                       d_ff=64, vocab_size=64, block_size=8,
                       attn_impl=attn_impl, **kw)


def _sft_batch(B=2, L=32, seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "tokens": jax.random.randint(key, (B, L), 4, 60),
        "prompt_mask": jnp.broadcast_to(jnp.arange(L)[None, :] < 8,
                                        (B, L)),
        "valid": jnp.ones((B, L), bool),
    }


@pytest.mark.parametrize("remat", [False, True])
def test_sft_loss_grad_parity_structured_vs_pallas(remat):
    """One SFT step computes the same loss and gradients whichever impl
    the config selects — pallas trains on the kernel fast path."""
    from repro.core.block_diffusion import sft_loss
    from repro.models.model import BlockDiffLM

    batch, rng = _sft_batch(), jax.random.PRNGKey(7)
    out = {}
    for impl in ("structured", "pallas"):
        model = BlockDiffLM(_tiny_cfg(impl, remat=remat))
        params = model.init(jax.random.PRNGKey(1))
        (loss, _), grads = jax.jit(jax.value_and_grad(
            lambda p: sft_loss(model, p, batch, rng), has_aux=True))(
                params)
        out[impl] = (loss, grads)
    ls, gs = out["structured"]
    lp, gp = out["pallas"]
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lp), atol=1e-4,
                               rtol=1e-4)
    flat_s = jax.tree.leaves(gs)
    flat_p = jax.tree.leaves(gp)
    for a, b in zip(flat_s, flat_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_dipo_logprob_grad_parity_structured_vs_pallas():
    """The DiPO packed-layout logprob forward+backward agrees between
    the structured and pallas impls (the RL training fast path)."""
    from repro.core.trajectory import RolloutBatch, trajectory_logprobs
    from repro.models.model import BlockDiffLM

    B, L, bsz, s_max = 4, 24, 8, 3
    key = jax.random.PRNGKey(3)
    roll = RolloutBatch(
        tokens=jax.random.randint(key, (B, L), 4, 60),
        steps=jax.random.randint(jax.random.fold_in(key, 1), (B, L),
                                 0, s_max),
        prompt_mask=jnp.broadcast_to(jnp.arange(L)[None, :] < bsz,
                                     (B, L)),
        valid=jnp.ones((B, L), bool),
        rewards=jnp.ones((B,), jnp.float32),
        group=jnp.zeros((B,), jnp.int32),
    )
    out = {}
    for impl in ("structured", "pallas"):
        model = BlockDiffLM(_tiny_cfg(impl))
        params = model.init(jax.random.PRNGKey(1))
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: jnp.sum(trajectory_logprobs(
                model, p, roll, s_max=s_max, scheme="packed")
                * roll.loss_mask)))(params)
        out[impl] = (loss, grads)
    ls, gs = out["structured"]
    lp, gp = out["pallas"]
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lp), atol=2e-3,
                               rtol=2e-3)
    for a, b in zip(jax.tree.leaves(gs), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3)


def test_sft_trainer_single_trace_pallas_remat():
    """step_traces == 1 across steps with attn_impl="pallas" under
    remat — the custom VJP neither retraces nor breaks checkpointing."""
    from repro.models.model import BlockDiffLM
    from repro.optim import adamw
    from repro.sft.trainer import SFTTrainer

    model = BlockDiffLM(_tiny_cfg("pallas", remat=True))
    params = model.init(jax.random.PRNGKey(1))
    tr = SFTTrainer(model, adamw.AdamWConfig(lr=1e-3), params)
    rng = jax.random.PRNGKey(2)
    for i in range(2):
        rng, k = jax.random.split(rng)
        m = tr.train_step(_sft_batch(seed=i), k)
        assert m["step_traces"] == 1
    assert 0.0 < m["attn_tile_visit_fraction"] <= 1.0


def test_dipo_step_single_trace_pallas():
    """The fused DiPO step stays at one compile with the pallas impl."""
    from repro.core.trajectory import RolloutBatch
    from repro.models.model import BlockDiffLM
    from repro.optim import adamw
    from repro.rl.trainer import DiPOConfig, make_dipo_step

    B, L, bsz, s_max = 4, 24, 8, 3
    model = BlockDiffLM(_tiny_cfg("pallas"))
    params = model.init(jax.random.PRNGKey(1))
    opt_cfg = adamw.AdamWConfig(lr=1e-4)
    opt_state = adamw.init_state(opt_cfg, params)
    step = make_dipo_step(model, opt_cfg,
                          DiPOConfig(group_size=2,
                                     logprob_scheme="packed"), s_max)
    for seed in range(2):
        key = jax.random.PRNGKey(seed)
        roll = RolloutBatch(
            tokens=jax.random.randint(key, (B, L), 4, 60),
            steps=jax.random.randint(jax.random.fold_in(key, 1), (B, L),
                                     0, s_max),
            prompt_mask=jnp.broadcast_to(jnp.arange(L)[None, :] < bsz,
                                         (B, L)),
            valid=jnp.ones((B, L), bool),
            rewards=jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32),
            group=jnp.asarray([0, 0, 1, 1], jnp.int32),
        )
        params, opt_state, _ = step(params, opt_state, roll, None, None,
                                    None, 2)
    assert step.n_traces == 1
