"""DiPO objective properties (paper Eq. 6-8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip file when absent
from hypothesis import given, settings, strategies as st

from repro.core.dipo import dipo_loss, group_advantages
from repro.core.trajectory import RolloutBatch


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), P=st.integers(1, 4), G=st.integers(2, 6))
def test_group_advantages_zero_mean(seed, P, G):
    key = jax.random.PRNGKey(seed)
    rewards = jax.random.normal(key, (P * G,))
    group = jnp.repeat(jnp.arange(P, dtype=jnp.int32), G)
    adv = group_advantages(rewards, group, P)
    for p in range(P):
        m = float(adv[group == p].mean())
        assert abs(m) < 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_group_advantages_std_normalised(seed):
    key = jax.random.PRNGKey(seed)
    rewards = jax.random.normal(key, (8,)) * 7.0 + 3.0
    group = jnp.zeros((8,), jnp.int32)
    adv = group_advantages(rewards, group, 1, normalize_std=True)
    assert abs(float(adv.std()) - 1.0) < 0.05


def _roll(B, L, rewards):
    return RolloutBatch(
        tokens=jnp.zeros((B, L), jnp.int32),
        steps=jnp.zeros((B, L), jnp.int32),
        prompt_mask=jnp.zeros((B, L), bool),
        valid=jnp.ones((B, L), bool),
        rewards=jnp.asarray(rewards), group=jnp.zeros((B,), jnp.int32))


def test_online_gradient_direction():
    """Online DiPO (pi_old = sg(pi)): gradient pushes up the logprob of
    positively-advantaged trajectories and down the negative ones."""
    B, L = 2, 8
    roll = _roll(B, L, [1.0, 0.0])  # adv = +0.5, -0.5
    logp0 = jnp.log(jnp.full((B, L), 0.5))

    def loss_fn(delta):
        loss, _ = dipo_loss(logp0 + delta, roll, n_groups=1)
        return loss

    g = jax.grad(loss_fn)(jnp.zeros((B, L)))
    assert bool((g[0] < 0).all())   # minimising => increase logp of winner
    assert bool((g[1] > 0).all())


def test_clipping_stops_gradient():
    """Ratios beyond 1+eps with positive advantage contribute no gradient."""
    B, L = 1, 4
    roll = _roll(B, L, [1.0])
    roll = RolloutBatch(roll.tokens, roll.steps, roll.prompt_mask,
                        roll.valid, roll.rewards, roll.group)
    old = jnp.log(jnp.full((B, L), 0.1))

    def loss_fn(lp):
        # force adv > 0 via two groups trick: single traj adv = 0 -> use
        # explicit old_logp and rewards pair
        r2 = _roll(2, L, [1.0, 0.0])
        lp2 = jnp.concatenate([lp, jnp.log(jnp.full((1, L), 0.1))])
        old2 = jnp.concatenate([old, jnp.log(jnp.full((1, L), 0.1))])
        loss, _ = dipo_loss(lp2, r2, old_logp=old2, n_groups=1, eps=0.2)
        return loss

    # ratio = exp(lp - old) = 3.0 >> 1.2 -> clipped, zero grad
    lp_hi = jnp.log(jnp.full((B, L), 0.3))
    g = jax.grad(loss_fn)(lp_hi)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-7)
    # ratio inside the clip window -> nonzero grad
    lp_in = jnp.log(jnp.full((B, L), 0.105))
    g2 = jax.grad(loss_fn)(lp_in)
    assert float(jnp.abs(g2).max()) > 1e-4


def test_kl_penalty_nonnegative_and_zero_at_ref():
    B, L = 2, 8
    roll = _roll(B, L, [1.0, 0.0])
    logp = jnp.log(jax.random.uniform(jax.random.PRNGKey(0), (B, L),
                                      minval=0.05, maxval=0.9))
    _, m_same = dipo_loss(logp, roll, ref_logp=logp, n_groups=1, beta=0.1)
    assert abs(float(m_same["kl_ref"])) < 1e-6
    _, m_diff = dipo_loss(logp, roll, ref_logp=logp - 0.5, n_groups=1,
                          beta=0.1)
    assert float(m_diff["kl_ref"]) > 0


def test_seq_vs_token_aggregation():
    """Eq.6 (per-seq mean) and Eq.8 (global token mean) differ exactly when
    sequence lengths differ."""
    B, L = 2, 8
    roll = _roll(B, L, [1.0, 0.0])
    valid = roll.valid.at[1, 4:].set(False)  # seq 1 half length
    roll = RolloutBatch(roll.tokens, roll.steps, roll.prompt_mask, valid,
                        roll.rewards, roll.group)
    old = jnp.log(jnp.full((B, L), 0.2))
    lp = old + jnp.array([[0.1] * L, [0.05] * L])
    l_tok, _ = dipo_loss(lp, roll, old_logp=old, n_groups=1,
                         aggregate="token")
    l_seq, _ = dipo_loss(lp, roll, old_logp=old, n_groups=1,
                         aggregate="seq")
    assert abs(float(l_tok) - float(l_seq)) > 1e-6
