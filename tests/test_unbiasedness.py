"""THE core correctness claim (paper §3.2/§4.1): the fused masked forward
produces exactly the logits the sequential inference engine would.

Oracle = literal decode replay (prefill + per-block decode_step with the
historical inputs).  Tested for dense, GQA+SWA, MoE (dropless capacity),
MLA, RWKV6, and hybrid Mamba+attention backbones.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import dirl_layout, plain_layout, sample_sft_noise
from repro.core import decoding
from repro.core.trajectory import (trajectory_logprobs_packed,
                                   trajectory_logprobs_replay)
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM

CFGS = {
    "dense": ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4,
                         n_kv_heads=2, d_ff=128, vocab_size=128,
                         block_size=8, attn_impl="structured"),
    "swa": ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab_size=128,
                       block_size=8, sliding_window=16, attn_impl="ref"),
    "moe": ModelConfig(name="t", arch_type="moe", n_experts=4, top_k=2,
                       n_shared_experts=1, moe_d_ff=64,
                       capacity_factor=8.0,  # dropless => exact
                       n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=128, block_size=8,
                       attn_impl="structured"),
    "mla": ModelConfig(name="t", attn_kind="mla", q_lora_rank=32,
                       kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                       v_head_dim=16, n_layers=3, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=128, vocab_size=128,
                       block_size=8, attn_impl="structured"),
    "rwkv6": ModelConfig(name="t", arch_type="ssm", ssm_kind="rwkv6",
                         n_layers=3, d_model=64, rwkv_head_dim=16,
                         d_ff=128, vocab_size=128, block_size=8),
    "hybrid": ModelConfig(name="t", arch_type="hybrid", ssm_kind="mamba",
                          attn_every=3, attn_offset=1, n_layers=3,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=128, block_size=8, d_state=8,
                          attn_impl="ref"),
}


def _sft_setup(cfg, seed=7):
    key = jax.random.PRNGKey(0)
    model = BlockDiffLM(cfg)
    params = model.init(key)
    B, L, bsz = 2, 32, cfg.block_size
    tokens = jax.random.randint(key, (B, L), 4, cfg.vocab_size - 2)
    valid = jnp.ones((B, L), bool)
    pm = jnp.arange(L)[None] < bsz
    steps, _, _ = sample_sft_noise(jax.random.PRNGKey(seed), tokens, pm,
                                   valid, block_size=bsz)
    return model, params, tokens, steps, valid


def _replay_logits(model, params, tokens, steps, valid, k):
    """Literal inference recomputation for block k."""
    cfg = model.cfg
    B, L = tokens.shape
    bsz = cfg.block_size
    MASK = cfg.resolved_mask_token
    meta_p = plain_layout(tokens, valid, block_size=bsz)
    caches = model.make_caches(B, L, ring=False)
    _, out = model.forward_masked(params, tokens, meta_p, caches=caches,
                                  want_boundaries=True)
    caches_full, bounds = out["caches"], out["boundaries"]
    blk = jnp.where(steps[:, k * bsz:(k + 1) * bsz] > 0, MASK,
                    tokens[:, k * bsz:(k + 1) * bsz])
    pos = jnp.broadcast_to(jnp.arange(k * bsz, (k + 1) * bsz,
                                      dtype=jnp.int32), (B, bsz))
    if cfg.ssm_kind:
        from repro.core.trajectory import _merge_boundary_states
        caches_full = _merge_boundary_states(caches_full, bounds, k)
    lg, _ = model.decode_step(params, blk, pos, caches_full,
                              cache_limit=jnp.full((B,), k * bsz))
    return lg


@pytest.mark.parametrize("family", list(CFGS))
def test_sft_dup_pass_equals_inference(family):
    cfg = CFGS[family]
    model, params, tokens, steps, valid = _sft_setup(cfg)
    B, L = tokens.shape
    bsz = cfg.block_size
    ids, meta, _ = dirl_layout(tokens, steps, valid, block_size=bsz,
                               mask_token=cfg.resolved_mask_token,
                               noised=True)
    logits_b, _ = model.forward_masked(params, ids, meta, dup_len=L,
                                       logits_from=L)
    errs = []
    for k in range(1, L // bsz):
        lg = _replay_logits(model, params, tokens, steps, valid, k)
        sel = steps[:, k * bsz:(k + 1) * bsz] > 0
        d = jnp.abs(jax.nn.log_softmax(lg) -
                    jax.nn.log_softmax(logits_b[:, k * bsz:(k + 1) * bsz]))
        errs.append(float(jnp.where(sel[..., None], d, 0).max()))
    assert max(errs) < 5e-5, f"{family}: dup pass biased vs inference"


@pytest.mark.parametrize("family", ["dense", "swa", "mla"])
def test_rl_packed_equals_replay(family):
    """The packed per-step layout is bit-equivalent to sequential replay."""
    cfg = CFGS[family]
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, Lp, Lmax, s_max = 2, 16, 40, 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, Lp), 4,
                                cfg.vocab_size - 2)
    pblocks = jnp.array([2, 1], jnp.int32)
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(3), max_len=Lmax,
                            s_max=s_max, mode="dynamic", tau=0.6,
                            eos_id=1, temperature=1.0)
    roll = decoding.rollout_to_batch(gen, jnp.zeros((B,)),
                                     jnp.zeros((B,), jnp.int32),
                                     cfg.block_size)
    lp_p = trajectory_logprobs_packed(model, params, roll, s_max=s_max)
    lp_r = trajectory_logprobs_replay(model, params, roll, s_max=s_max)
    err = jnp.abs(jnp.where(roll.loss_mask, lp_p - lp_r, 0)).max()
    assert float(err) < 5e-5, f"{family}: packed != replay"


def test_fused_approx_bias_is_bounded_documented():
    """The one-2L-pass approximation (committed-KV) is intentionally biased;
    document that the bias is nonzero but bounded at init."""
    from repro.core.trajectory import trajectory_logprobs_fused
    cfg = CFGS["dense"]
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, Lp, Lmax, s_max = 2, 16, 40, 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, Lp), 4, 100)
    pblocks = jnp.array([2, 2], jnp.int32)
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(3), max_len=Lmax,
                            s_max=s_max, mode="dynamic", tau=0.6, eos_id=1)
    roll = decoding.rollout_to_batch(gen, jnp.zeros((B,)),
                                     jnp.zeros((B,), jnp.int32),
                                     cfg.block_size)
    lp_f = trajectory_logprobs_fused(model, params, roll)
    lp_r = trajectory_logprobs_replay(model, params, roll, s_max=s_max)
    bias = jnp.abs(jnp.where(roll.loss_mask, lp_f - lp_r, 0)).max()
    assert 0 < float(bias) < 1.0
