"""Blockwise decoding loop: step maps, eos, static/dynamic policies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decoding
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, block_size=8,
                  attn_impl="structured")


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 4, 100)
    pblocks = jnp.array([2, 1], jnp.int32)
    return model, params, prompt, pblocks


def test_generation_shapes_and_prompt_preserved(setup):
    model, params, prompt, pblocks = setup
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(2), max_len=48, s_max=4,
                            mode="dynamic", tau=0.6, eos_id=1)
    assert gen["tokens"].shape == (2, 48)
    # each sequence's TRUE prompt region (pblocks * bsz) is preserved;
    # beyond it, shorter prompts legitimately start generating.
    np.testing.assert_array_equal(np.asarray(gen["tokens"][0, :16]),
                                  np.asarray(prompt[0]))
    np.testing.assert_array_equal(np.asarray(gen["tokens"][1, :8]),
                                  np.asarray(prompt[1, :8]))
    assert bool((gen["gen_blocks"] >= 1).all())


def test_static_mode_step_budget(setup):
    """Static n_steps=4 on block 8 reveals exactly 2 tokens/step."""
    model, params, prompt, pblocks = setup
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(2), max_len=48, s_max=4,
                            mode="static", n_steps=4, eos_id=1)
    steps = np.asarray(gen["steps"])
    pb = np.asarray(gen["prompt_blocks"])
    gb = np.asarray(gen["gen_blocks"])
    for b in range(2):
        for k in range(pb[b], pb[b] + gb[b]):
            blk = steps[b, k * 8:(k + 1) * 8]
            # 8 tokens over 4 steps -> each step reveals exactly 2
            counts = np.bincount(blk, minlength=4)
            assert (counts == 2).all(), blk


def test_all_tokens_revealed_no_mask_left(setup):
    model, params, prompt, pblocks = setup
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(2), max_len=48, s_max=3,
                            mode="dynamic", tau=0.99, eos_id=1)
    toks = np.asarray(gen["tokens"])
    pb, gb = np.asarray(gen["prompt_blocks"]), np.asarray(gen["gen_blocks"])
    for b in range(2):
        lo, hi = pb[b] * 8, (pb[b] + gb[b]) * 8
        assert (toks[b, lo:hi] != CFG.resolved_mask_token).all()


def test_dynamic_tau_monotone_steps(setup):
    """Higher tau (more conservative) never uses fewer denoise steps."""
    model, params, prompt, pblocks = setup

    def mean_step(tau):
        gen = decoding.generate(model, params, prompt, pblocks,
                                jax.random.PRNGKey(2), max_len=48, s_max=8,
                                mode="dynamic", tau=tau, eos_id=1)
        steps = np.asarray(gen["steps"])
        pb = np.asarray(gen["prompt_blocks"])
        gb = np.asarray(gen["gen_blocks"])
        vals = []
        for b in range(2):
            lo, hi = pb[b] * 8, (pb[b] + gb[b]) * 8
            vals.append(steps[b, lo:hi].max())
        return float(np.mean(vals))

    assert mean_step(0.99) >= mean_step(0.1)


def test_determinism(setup):
    model, params, prompt, pblocks = setup
    kw = dict(max_len=48, s_max=4, mode="dynamic", tau=0.7, eos_id=1)
    g1 = decoding.generate(model, params, prompt, pblocks,
                           jax.random.PRNGKey(5), **kw)
    g2 = decoding.generate(model, params, prompt, pblocks,
                           jax.random.PRNGKey(5), **kw)
    np.testing.assert_array_equal(np.asarray(g1["tokens"]),
                                  np.asarray(g2["tokens"]))


def test_ragged_prompts_run_to_their_own_budget(setup):
    """Regression: the loop count used to come from the *padded* prompt
    width, so in a ragged batch the short-prompt row ran out of trips
    before its own block limit and returned silently truncated, EOS-less
    output.  Every row must now decode until EOS or its own budget."""
    model, params, prompt, pblocks = setup
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(2), max_len=48, s_max=3,
                            mode="dynamic", tau=0.9, eos_id=1)
    toks = np.asarray(gen["tokens"])
    gb = np.asarray(gen["gen_blocks"])
    done = np.asarray(gen["done"])
    K = 48 // 8
    for b, pb in enumerate([2, 1]):
        hit_eos = bool(
            (toks[b, pb * 8:(pb + gb[b]) * 8] == 1).any())
        # full budget (down to the row's TRUE prompt) or EOS — never a
        # padded-width cutoff
        assert hit_eos or gb[b] == K - pb, (b, gb[b])
        assert done[b]
    # row 1's true prompt is one block shorter than the padding: it gets
    # one more block of budget than the padded width suggests
    assert gb[1] == K - 1 or (toks[1, 8:(1 + gb[1]) * 8] == 1).any()


def test_full_prompt_row_not_corrupted_in_mixed_batch(setup):
    """Regression: a row whose prompt fills the cache must stay frozen
    (done at init) while other rows decode — advance_block used to
    denoise-commit over its last prompt block."""
    model, params, prompt, pblocks = setup
    full = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (48,), 4, 100), np.int32)
    toks = np.zeros((2, 48), np.int32)
    toks[0, :16] = np.asarray(prompt[0, :16])
    toks[1] = full
    pb = jnp.asarray([2, 6], jnp.int32)
    gen = decoding.generate(model, params, jnp.asarray(toks), pb,
                            jax.random.PRNGKey(4), max_len=48, s_max=3,
                            mode="dynamic", tau=0.9, eos_id=1)
    np.testing.assert_array_equal(np.asarray(gen["tokens"][1]), full)
    assert int(gen["gen_blocks"][1]) == 0
    assert not bool(gen["done"][1])      # zero-budget rows report False
    assert int(gen["gen_blocks"][0]) > 0


def test_count_gen_tokens_cuts_at_first_eos():
    toks = np.full((3, 32), 7, np.int32)
    toks[0, 19] = 1          # EOS mid block 2
    toks[1, 8] = 1           # EOS at the very first generated token
    pb = np.array([1, 1, 1])
    gb = np.array([3, 3, 0])
    n = decoding.count_gen_tokens(toks, pb, gb, eos_id=1, block_size=8)
    assert n.tolist() == [12, 1, 0]


def test_rollout_batch_masks(setup):
    model, params, prompt, pblocks = setup
    gen = decoding.generate(model, params, prompt, pblocks,
                            jax.random.PRNGKey(2), max_len=48, s_max=4,
                            mode="dynamic", tau=0.6, eos_id=1)
    roll = decoding.rollout_to_batch(gen, jnp.zeros((2,)),
                                     jnp.zeros((2,), jnp.int32), 8)
    pm = np.asarray(roll.prompt_mask)
    lm = np.asarray(roll.loss_mask)
    assert pm[0, :16].all() and not pm[0, 16:].any()
    assert pm[1, :8].all() and not pm[1, 8:].any()
    assert not (pm & lm).any()
