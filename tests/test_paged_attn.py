"""Page-aware kernel family: parity grids across the KV layouts.

Three levels:

* kernel-level decode — ``kernels.paged_attn.paged_decode_attention``
  (run through the real ``resolve_kv_layout`` dispatch) against the
  gathered fallback on raw pools: GQA / MLA-MQA shapes, sliding window,
  softcap, ragged block tables with -1 holes, ``cache_limit`` edges,
  and the null-page no-leak guarantee (bitwise: pool garbage cannot
  change the output);
* kernel-level prefill — ``paged_prefill_attention`` (the in-place
  suffix-prefill kernel) *bitwise* against the gathered plain-paged
  path across GQA/MLA x window x softcap x prefix-hit widths, plus the
  (8, 128) tile-padding parity cases (block_size 4, head dim 96: the
  padded launch compiled mode would run on TPU matches the unpadded
  output bitwise) and the ``plan_exec`` execution-planning contract;
* scheduler-level — decode TOKENS byte-identical across
  dense / gathered-paged / in-place-pallas pools under admission and
  eviction churn (the acceptance criterion), including sliding-window
  and MLA stacks, prefix-shared pages, partial-hit suffix-prefill
  admissions (with ``admit_transient_kv_bytes`` dropping to 0 in
  place), and mixed SamplingParams with the zero-retrace invariant
  (``n_advance_traces == 1``).

Nature of the token-level contract: the online-softmax kernel and the
plain-softmax fallback are different f32 arithmetic, so *logits* agree
only to ~1e-5 (hence the kernel-level rtol) — token byte-equality holds
because argmax/threshold decisions have margins orders of magnitude
above that rounding, verified empirically for these seeds on the
interpret path (the same empirical-bitwise standard PR 3 used for
``prefill_suffix``).  A failure here after a jax/XLA upgrade or on real
TPU hardware means a *decision boundary* moved — investigate the
numerics before touching the assertion.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import SeqMeta
from repro.kernels.paged_attn import (paged_decode_attention,
                                      paged_prefill_attention, plan_exec)
from repro.models import attention as A
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.api import SamplingParams
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import ModelServer

BSZ = 8
MAX_LEN = 48
_BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             vocab_size=128, block_size=BSZ, attn_impl="structured")


# ---------------------------------------------------------------------------
# kernel-level parity (raw pools, no model)
# ---------------------------------------------------------------------------


def _pool(key, *, P=11, K=5, Hkv=2, Dk=32, Dv=32, B=3):
    """A random pool + ragged table (with -1 holes and an all-hole row)
    + self block + per-row positions/limits covering the edge cases."""
    ks = jax.random.split(key, 6)
    kp = jax.random.normal(ks[0], (P, BSZ, Hkv, Dk), jnp.float32)
    vp = jax.random.normal(ks[1], (P, BSZ, Hkv, Dv), jnp.float32)
    pos = np.arange(P * BSZ).reshape(P, BSZ).astype(np.int32) % (K * BSZ)
    pos[4, 3:] = -1                       # partially filled page
    table = np.full((B, K), -1, np.int32)
    table[0, :3] = [1, 2, 3]              # trailing holes
    table[1] = [5, 6, 7, 8, 9]            # full row
    # row 2: no pages at all — only the self block is visible
    k_self = jax.random.normal(ks[2], (B, BSZ, Hkv, Dk), jnp.float32)
    v_self = jax.random.normal(ks[3], (B, BSZ, Hkv, Dv), jnp.float32)
    # cache_limit edges: 0 (nothing committed), mid-sequence, full
    blk = np.array([0, 3, K], np.int32)
    positions = blk[:, None] * BSZ + np.arange(BSZ)[None, :]
    limit = blk * BSZ
    cache = A.PagedAttnCache(k=kp, v=vp, pos=jnp.asarray(pos))
    return (cache, jnp.asarray(table), k_self, v_self,
            jnp.asarray(positions), jnp.asarray(limit))


def _attend(cache, table, k_self, v_self, positions, limit, q, kernel,
            **kw):
    return A.resolve_kv_layout(cache, kernel).attend(
        q, k_self, v_self, positions, cache, block_table=table,
        cache_limit=limit, **kw)


@pytest.mark.parametrize("shape", ["gqa", "mla"])
@pytest.mark.parametrize("window,softcap", [(None, None), (12, None),
                                            (None, 5.0), (20, 5.0)])
def test_kernel_matches_gathered_reference(shape, window, softcap):
    """In-place kernel vs gathered fallback on the ragged-pool grid:
    GQA and the MLA latent-MQA form (Hkv=1, Dk != Dv), sliding window,
    softcap, -1 table holes, partially filled pages, limit edges."""
    H = 4
    dims = dict(Hkv=2, Dk=32, Dv=32) if shape == "gqa" \
        else dict(Hkv=1, Dk=40, Dv=32)
    cache, table, k_self, v_self, positions, limit = _pool(
        jax.random.PRNGKey(0), **dims)
    q = jax.random.normal(jax.random.PRNGKey(7),
                          (3, BSZ, H, dims["Dk"]), jnp.float32)
    kw = dict(scale=dims["Dk"] ** -0.5, softcap=softcap, window=window)
    o_ref = _attend(cache, table, k_self, v_self, positions, limit, q,
                    "ref", **kw)
    o_pal = _attend(cache, table, k_self, v_self, positions, limit, q,
                    "pallas", **kw)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-6)


def test_null_page_and_holes_never_leak():
    """Bitwise guarantee: garbage in the null page, in unmapped pages,
    and in pos=-1 slots cannot change the kernel output — the masking
    semantics (table -1, pos -1, cache_limit) hide them exactly."""
    cache, table, k_self, v_self, positions, limit = _pool(
        jax.random.PRNGKey(1))
    q = jax.random.normal(jax.random.PRNGKey(8), (3, BSZ, 4, 32),
                          jnp.float32)
    kw = dict(scale=32 ** -0.5, softcap=None, window=None)
    base = _attend(cache, table, k_self, v_self, positions, limit, q,
                   "pallas", **kw)
    # poison everything the mask must hide: the null page, pages no
    # table row maps (e.g. 4 has pos=-1 slots; 10 unmapped), and keys
    # past each row's cache_limit (handled by limit, not contents)
    mapped = {int(p) for p in np.asarray(table).ravel() if p >= 0}
    unmapped = [p for p in range(cache.k.shape[0]) if p not in mapped]
    poison = cache._replace(
        k=cache.k.at[jnp.asarray(unmapped)].set(1e9),
        v=cache.v.at[jnp.asarray(unmapped)].set(-1e9))
    got = _attend(poison, table, k_self, v_self, positions, limit, q,
                  "pallas", **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    # limit=0 row sees only its self block: pool contents irrelevant
    poison_all = cache._replace(k=cache.k.at[:].set(1e9))
    got0 = _attend(poison_all, table, k_self, v_self, positions, limit,
                   q, "pallas", **kw)
    np.testing.assert_array_equal(np.asarray(got0)[0],
                                  np.asarray(base)[0])


def test_cache_limit_edges_match_reference():
    """Per-row limits at 0 / one-block / exactly-full agree with the
    gathered fallback (which inherits them from _decode_key_mask)."""
    cache, table, k_self, v_self, positions, _ = _pool(
        jax.random.PRNGKey(2))
    q = jax.random.normal(jax.random.PRNGKey(9), (3, BSZ, 4, 32),
                          jnp.float32)
    kw = dict(scale=32 ** -0.5, softcap=None, window=None)
    for lim in ([0, 0, 0], [BSZ, BSZ, BSZ], [0, 17, 5 * BSZ]):
        lim = jnp.asarray(lim, jnp.int32)
        o_ref = _attend(cache, table, k_self, v_self, positions, lim, q,
                        "ref", **kw)
        o_pal = _attend(cache, table, k_self, v_self, positions, lim, q,
                        "pallas", **kw)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-6)


def test_transient_kv_bytes_accounting():
    """The layout abstraction's copy accounting: gather width for the
    ref fallback, dense concat width for dense rows, 0 in place —
    decode (per-tick) and prefill (per-admission) both."""
    cache, *_ = _pool(jax.random.PRNGKey(3))
    per_tok = 2 * (32 + 32) * 4 + 4          # Hkv*(Dk+Dv)*itemsize + pos
    assert A.transient_kv_bytes(cache, 3, 5, "ref") == 3 * 5 * BSZ * per_tok
    assert A.transient_kv_bytes(cache, 3, 5, "pallas") == 0
    dense = A.make_attn_cache(3, MAX_LEN, 2, 32, 32, jnp.float32)
    assert A.transient_kv_bytes(dense, 3, 5, "ref") \
        == 3 * MAX_LEN * per_tok
    # admission-time suffix-prefill gather: hit-prefix width, 0 in place
    assert A.prefill_transient_kv_bytes(cache, 1, 4, "ref") \
        == 4 * BSZ * per_tok
    assert A.prefill_transient_kv_bytes(cache, 1, 4, "pallas") == 0
    assert A.prefill_transient_kv_bytes(dense, 1, 4, "ref") == 0
    with pytest.raises(ValueError, match="kernel"):
        A.resolve_kv_layout(cache, "cuda")


# ---------------------------------------------------------------------------
# kernel-level suffix-prefill parity (bitwise) + tile padding + planning
# ---------------------------------------------------------------------------


def _prefill_pool(key, *, Kp, Ts, Hkv, Dk, Dv, B=2, bsz=BSZ):
    """A pool whose first B*Kp pages hold each row's committed prefix
    (sequential absolute positions) + a Ts-block suffix to prefill."""
    P = B * max(Kp, 1) + 2
    ks = jax.random.split(key, 5)
    pos = np.full((P, bsz), -1, np.int32)
    table = np.zeros((B, Kp), np.int32)
    pg = 1
    for b in range(B):
        for j in range(Kp):
            table[b, j] = pg
            pos[pg] = j * bsz + np.arange(bsz)
            pg += 1
    cache = A.PagedAttnCache(
        k=jax.random.normal(ks[0], (P, bsz, Hkv, Dk), jnp.float32),
        v=jax.random.normal(ks[1], (P, bsz, Hkv, Dv), jnp.float32),
        pos=jnp.asarray(pos))
    T = Ts * bsz
    positions = np.broadcast_to(Kp * bsz + np.arange(T), (B, T))
    q = jax.random.normal(ks[2], (B, T, 4 * Hkv, Dk), jnp.float32)
    k_self = jax.random.normal(ks[3], (B, T, Hkv, Dk), jnp.float32)
    v_self = jax.random.normal(ks[4], (B, T, Hkv, Dv), jnp.float32)
    meta = SeqMeta(copy=jnp.zeros((B, T), jnp.int32),
                   block=jnp.asarray(positions // bsz, jnp.int32),
                   step=jnp.zeros((B, T), jnp.int32),
                   pos=jnp.asarray(positions, jnp.int32),
                   valid=jnp.ones((B, T), bool))
    return cache, jnp.asarray(table), q, k_self, v_self, meta


def _prefill_attend(cache, table, q, k_self, v_self, meta, kernel, *,
                    bsz=BSZ, **kw):
    return A.resolve_kv_layout(cache, kernel).prefill_attend(
        q, k_self, v_self, meta, cache, context_table=table,
        block_size=bsz, impl="chunked", **kw)


@pytest.mark.parametrize("shape", ["gqa", "mla"])
@pytest.mark.parametrize("window,softcap", [(None, None), (12, None),
                                            (None, 5.0)])
@pytest.mark.parametrize("Kp", [0, 1, 3])
def test_prefill_kernel_bitwise_vs_gathered(shape, window, softcap, Kp):
    """The tentpole contract: the in-place suffix-prefill kernel is
    *bitwise* equal to the gathered plain-paged path (and hence to a
    full prefill — see core.decoding.prefill_suffix) across GQA and the
    MLA latent-MQA form (Hkv=1, Dk != Dv), sliding window, softcap, and
    prefix-hit widths from zero (pure-suffix) to several pages."""
    dims = dict(Hkv=2, Dk=32, Dv=32) if shape == "gqa" \
        else dict(Hkv=1, Dk=40, Dv=32)
    cache, table, q, k_self, v_self, meta = _prefill_pool(
        jax.random.PRNGKey(4), Kp=Kp, Ts=2, **dims)
    kw = dict(scale=dims["Dk"] ** -0.5, softcap=softcap, window=window)
    o_ref = _prefill_attend(cache, table, q, k_self, v_self, meta,
                            "ref", **kw)
    o_pal = _prefill_attend(cache, table, q, k_self, v_self, meta,
                            "pallas", **kw)
    np.testing.assert_array_equal(np.asarray(o_pal), np.asarray(o_ref))


def test_prefill_kernel_ignores_stale_pool_rows():
    """Bitwise guarantee: pool pages outside the context table (and the
    null page) cannot change the prefill output — the kernel streams
    only table-mapped pages and masks pos=-1 rows."""
    cache, table, q, k_self, v_self, meta = _prefill_pool(
        jax.random.PRNGKey(5), Kp=2, Ts=1, Hkv=2, Dk=32, Dv=32)
    kw = dict(scale=32 ** -0.5, softcap=None, window=None)
    base = _prefill_attend(cache, table, q, k_self, v_self, meta,
                           "pallas", **kw)
    mapped = {int(p) for p in np.asarray(table).ravel()}
    unmapped = [p for p in range(cache.k.shape[0]) if p not in mapped]
    poison = cache._replace(
        k=cache.k.at[jnp.asarray(unmapped)].set(1e9),
        v=cache.v.at[jnp.asarray(unmapped)].set(-1e9))
    got = _prefill_attend(poison, table, q, k_self, v_self, meta,
                          "pallas", **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def _subtile_decode_pool(key, *, bsz=4, Dk=96, Dv=96, Hkv=2, B=2, K=3):
    P = B * K + 1
    ks = jax.random.split(key, 5)
    kp = jax.random.normal(ks[0], (P, bsz, Hkv, Dk), jnp.float32)
    vp = jax.random.normal(ks[1], (P, bsz, Hkv, Dv), jnp.float32)
    pp = jnp.asarray(np.arange(P * bsz).reshape(P, bsz) % (K * bsz),
                     jnp.int32)
    table = jnp.asarray(np.arange(1, B * K + 1).reshape(B, K), jnp.int32)
    k_self = jax.random.normal(ks[2], (B, bsz, Hkv, Dk), jnp.float32)
    v_self = jax.random.normal(ks[3], (B, bsz, Hkv, Dv), jnp.float32)
    positions = jnp.asarray(
        np.broadcast_to(K * bsz + np.arange(bsz), (B, bsz)), jnp.int32)
    limit = jnp.full((B,), K * bsz, jnp.int32)
    q = jax.random.normal(ks[4], (B, bsz, 2 * Hkv, Dk), jnp.float32)
    return q, kp, vp, pp, table, k_self, v_self, positions, limit


@pytest.mark.parametrize("window", [None, 6])
def test_tile_padding_bitwise_decode(window):
    """block_size 4 / head dim 96 (both below the (8, 128) f32 tile):
    the zero-padded launch — the exact operands compiled mode runs on
    TPU — matches the unpadded output bitwise.  Padded self rows carry
    pos=-1 and padded head dims contribute +0.0 terms, so padding is
    arithmetic-exact, not approximate."""
    q, kp, vp, pp, table, ksf, vsf, pos, lim = _subtile_decode_pool(
        jax.random.PRNGKey(6))
    kw = dict(scale=96 ** -0.5, softcap=None, window=window)
    plain = paged_decode_attention(q, kp, vp, pp, table, ksf, vsf, pos,
                                   lim, interpret=True, pad=False, **kw)
    padded = paged_decode_attention(q, kp, vp, pp, table, ksf, vsf, pos,
                                    lim, interpret=True, pad=True, **kw)
    np.testing.assert_array_equal(np.asarray(padded), np.asarray(plain))


@pytest.mark.parametrize("softcap", [None, 5.0])
def test_tile_padding_bitwise_prefill(softcap):
    """Prefill counterpart of the padding parity: sub-tile pages
    (block_size 4) and head dim 96, padded vs unpadded bitwise."""
    cache, table, q, k_self, v_self, meta = _prefill_pool(
        jax.random.PRNGKey(7), Kp=3, Ts=2, Hkv=2, Dk=96, Dv=96, bsz=4)
    kw = dict(scale=96 ** -0.5, softcap=softcap, window=None)
    plain = paged_prefill_attention(
        q, cache.k, cache.v, cache.pos, table, k_self, v_self, meta.pos,
        interpret=True, pad=False, **kw)
    padded = paged_prefill_attention(
        q, cache.k, cache.v, cache.pos, table, k_self, v_self, meta.pos,
        interpret=True, pad=True, **kw)
    np.testing.assert_array_equal(np.asarray(padded), np.asarray(plain))


def test_plan_exec_contract():
    """Execution planning: tile-aligned shapes compile on TPU, sub-tile
    shapes compile via zero-padding (unless padding is disabled, which
    falls back to interpret), and non-TPU backends always interpret."""
    on_tpu = jax.default_backend() == "tpu"
    # tile-aligned page shape: compiled wherever a TPU exists
    plan = plan_exec(8, 128, 128, interpret=False)
    assert plan.mode == "compiled" and not plan.padded
    assert "tile-aligned" in plan.reason
    # sub-tile: compiled only by padding up to the (8, 128) tile
    plan = plan_exec(4, 96, 96, interpret=False)
    assert plan.mode == "compiled" and plan.padded
    assert "zero-padded" in plan.reason
    # padding disabled -> the old interpret fallback, with the reason
    plan = plan_exec(4, 96, 96, interpret=False, pad=False)
    assert plan.mode == "interpret" and not plan.padded
    assert "padding disabled" in plan.reason
    # backend-resolved default (this CI host: no TPU -> interpret)
    plan = plan_exec(4, 96, 96)
    assert plan.interpret == (not on_tpu)
    if not on_tpu:
        assert "backend=" in plan.reason and not plan.padded
    # forced interpret always wins
    assert plan_exec(8, 128, 128, interpret=True).mode == "interpret"


def test_kernel_exec_plan_surface():
    """The queryable mode surface: a KernelPlan for pallas on paged
    caches, None wherever no Pallas kernel is ever launched."""
    cache, *_ = _pool(jax.random.PRNGKey(3))
    plan = A.kernel_exec_plan(cache, "pallas")
    assert plan is not None and plan.mode in ("compiled", "interpret")
    assert A.kernel_exec_plan(cache, "ref") is None
    dense = A.make_attn_cache(3, MAX_LEN, 2, 32, 32, jnp.float32)
    assert A.kernel_exec_plan(dense, "pallas") is None


# ---------------------------------------------------------------------------
# scheduler-level: decode tokens byte-identical across the three layouts
# ---------------------------------------------------------------------------


def _drain(model, params, sched, prompt, pblocks, keys, budgets):
    for i in range(len(keys)):
        sched.submit(prompt[i % 4], pblocks[i % 4], keys[i],
                     max_new_blocks=budgets[i % len(budgets)])
    return {c.uid: c for c in sched.run(params)}


def _assert_same_tokens(ref, got):
    assert sorted(ref) == sorted(got)
    for uid, d in ref.items():
        p = got[uid]
        assert d.gen_blocks == p.gen_blocks
        assert d.denoise_steps == p.denoise_steps
        np.testing.assert_array_equal(d.tokens, p.tokens)
        np.testing.assert_array_equal(d.steps, p.steps)


def _three_way(cfg, *, n_pages=13, tau=0.6):
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    keys = jax.random.split(jax.random.PRNGKey(13), 6)
    outs = {}
    for cache, kernel in [("dense", "ref"), ("paged", "ref"),
                          ("paged", "pallas")]:
        kw = dict(n_pages=n_pages, prefix_cache=False) \
            if cache == "paged" else {}
        sched = SlotScheduler(model, n_slots=3, max_len=MAX_LEN, s_max=4,
                              mode="dynamic", tau=tau, temperature=1.0,
                              eos_id=1, cache=cache, kernel=kernel, **kw)
        outs[(cache, kernel)] = (
            _drain(model, params, sched, prompt, pblocks, keys,
                   [3, None, 2]),
            sched.stats.transient_kv_bytes)
    ref = outs[("dense", "ref")][0]
    _assert_same_tokens(ref, outs[("paged", "ref")][0])
    _assert_same_tokens(ref, outs[("paged", "pallas")][0])
    assert outs[("paged", "ref")][1] > 0
    assert outs[("paged", "pallas")][1] == 0   # no per-step K/V copy
    assert outs[("dense", "ref")][1] > 0       # dense concat transient


def test_pallas_tokens_match_dense_and_gathered():
    """The acceptance criterion: dense vs gathered-paged vs in-place
    pallas produce byte-identical tokens, step maps and denoise counts
    under mixed-length admission/eviction churn on a tight pool — with
    transient_kv_bytes == 0 only on the in-place path."""
    _three_way(ModelConfig(name="t", **_BASE))


@pytest.mark.parametrize("variant", ["swa", "mla"])
def test_pallas_parity_swa_and_mla(variant):
    """Sliding-window (dense rings vs paged window-masking) and the
    absorbed-MLA latent pool keep three-way byte parity."""
    if variant == "swa":
        cfg = ModelConfig(name="w", sliding_window=16, **_BASE)
    else:
        cfg = ModelConfig(name="m", attn_kind="mla", kv_lora_rank=32,
                          qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                          **_BASE)
    _three_way(cfg, tau=0.8)


def test_pallas_prefix_shared_pages_parity():
    """A DiPO G-group on prefix-shared pages decodes the same bytes
    through the in-place kernel as through the gathered fallback, with
    identical sharing stats (the kernel reads shared pages exactly
    like exclusive ones — refcounts are invisible to attention)."""
    model = BlockDiffLM(ModelConfig(name="t", **_BASE))
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 16), 4, 100))
    keys = jax.random.split(jax.random.PRNGKey(9), 8)
    outs = {}
    for kernel in ["ref", "pallas"]:
        sched = SlotScheduler(model, n_slots=4, max_len=MAX_LEN, s_max=3,
                              mode="dynamic", tau=0.8, temperature=1.0,
                              eos_id=1, cache="paged", n_pages=25,
                              prefix_cache=True, kernel=kernel)
        for i in range(8):      # 2 prompts x G=4, members adjacent
            sched.submit(prompt[i // 4], 2, keys[i], max_new_blocks=3)
        outs[kernel] = ({c.uid: c for c in sched.run(params)},
                        sched.stats)
    _assert_same_tokens(outs["ref"][0], outs["pallas"][0])
    assert outs["pallas"][1].prefix_hit_blocks \
        == outs["ref"][1].prefix_hit_blocks > 0
    assert outs["pallas"][1].transient_kv_bytes == 0


def test_partial_hit_suffix_prefill_parity_and_admit_stats():
    """Partial prefix hits take the suffix-prefill path: a prompt whose
    first blocks are registered but whose tail diverges pays a suffix
    prefill against the hit pages.  Tokens must be byte-identical
    between the gathered admission (kernel="ref") and the in-place
    prefill kernel (kernel="pallas") — and the admission-gather stat
    must be the hit width for ref, exactly 0 in place."""
    model = BlockDiffLM(ModelConfig(name="t", **_BASE))
    params = model.init(jax.random.PRNGKey(0))
    base = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (2, 16), 4, 100))
    ext = np.concatenate([base, (base[:, :BSZ] + 1) % 100], axis=1)
    keys = jax.random.split(jax.random.PRNGKey(23), 8)
    outs = {}
    for kernel in ["ref", "pallas"]:
        sched = SlotScheduler(model, n_slots=4, max_len=MAX_LEN, s_max=3,
                              mode="dynamic", tau=0.8, temperature=1.0,
                              eos_id=1, cache="paged", n_pages=41,
                              prefix_cache=True, kernel=kernel)
        for i in range(8):
            p = i // 4
            if i % 2:   # odd members: 2 hit blocks + 1 divergent block
                sched.submit(ext[p], 3, keys[i], max_new_blocks=2)
            else:       # even members register / fully hit the base
                sched.submit(base[p], 2, keys[i], max_new_blocks=2)
        outs[kernel] = ({c.uid: c for c in sched.run(params)},
                        sched.stats, sched.kernel_plan)
    _assert_same_tokens(outs["ref"][0], outs["pallas"][0])
    s_ref, s_pal = outs["ref"][1], outs["pallas"][1]
    assert s_ref.prefix_hit_blocks == s_pal.prefix_hit_blocks > 0
    # admission gather = 2 hit blocks x token bytes for one B=1 row
    per_tok = 2 * (16 + 16) * 4 + 4
    assert s_ref.admit_transient_kv_bytes == 2 * BSZ * per_tok
    assert s_pal.admit_transient_kv_bytes == 0
    # the queryable execution-mode surface
    assert s_ref.kernel_mode == "" and outs["ref"][2] is None
    plan = outs["pallas"][2]
    assert plan is not None and s_pal.kernel_mode == plan.mode
    if jax.default_backend() != "tpu":
        assert plan.mode == "interpret" and "backend=" in plan.reason


def test_pallas_zero_retrace_mixed_params():
    """Mixed SamplingParams on one pallas pool: a single advance trace
    (the kernel choice is a pool static, request params stay traced
    data) and per-row byte parity with the gathered fallback."""
    model = BlockDiffLM(ModelConfig(name="t", **_BASE))
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    keys = jax.random.split(jax.random.PRNGKey(21), 6)
    mix = [SamplingParams(tau=0.5, temperature=1.0, max_new_blocks=2),
           SamplingParams(tau=0.95, max_new_blocks=3),
           SamplingParams(mode="static", n_steps=3, temperature=1.0,
                          max_new_blocks=2)]
    outs = {}
    for kernel in ["ref", "pallas"]:
        sched = SlotScheduler(model, n_slots=3, max_len=MAX_LEN, s_max=4,
                              eos_id=1, cache="paged", kernel=kernel)
        for i in range(6):
            sched.submit(prompt[i % 4], int(pblocks[i % 4]), keys[i],
                         params=mix[i % 3])
        outs[kernel] = {c.uid: c for c in sched.run(params)}
        assert sched.n_advance_traces == 1, sched.n_advance_traces
    _assert_same_tokens(outs["ref"], outs["pallas"])


def test_engine_surfaces_transient_kv_bytes():
    """EngineStats mirrors the pool's transient-copy stat; the pallas
    engine keeps the generate_ids static-parity contract."""
    model = BlockDiffLM(ModelConfig(name="t", **_BASE))
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (3, 16), 4, 100))
    pblocks = np.array([2, 1, 2], np.int32)
    rng = jax.random.PRNGKey(17)
    outs, stats = {}, {}
    for mode, cache, kernel in [("static", "dense", "ref"),
                                ("continuous", "paged", "pallas")]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=4, mode="dynamic", tau=0.6,
            temperature=1.0, batching=mode, n_slots=3, cache=cache,
            kernel=kernel))
        outs[mode] = eng.generate_ids(prompt, pblocks, rng)
        stats[mode] = eng.stats
    a, b = outs["static"], outs["continuous"]
    for k in ["tokens", "steps", "gen_blocks", "denoise_steps"]:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert stats["continuous"].transient_kv_bytes == 0
    assert stats["static"].transient_kv_bytes == 0   # no pool built
    assert stats["continuous"].admit_transient_kv_bytes == 0
    assert stats["continuous"].kernel_mode in ("compiled", "interpret")
    assert stats["static"].kernel_mode == ""         # no pool built


def test_kernel_config_validation():
    model = BlockDiffLM(ModelConfig(name="t", **_BASE))
    with pytest.raises(ValueError, match="pallas"):
        SlotScheduler(model, n_slots=2, max_len=MAX_LEN,
                      cache="dense", kernel="pallas")
    with pytest.raises(ValueError, match="kernel"):
        SlotScheduler(model, n_slots=2, max_len=MAX_LEN,
                      cache="paged", kernel="triton")
