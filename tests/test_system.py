"""End-to-end system behaviour: the paper's two-stage pipeline on the
synthetic verifiable-math task (small scale, CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.optim.adamw import AdamWConfig
from repro.rl.trainer import DiPOConfig, DiPOTrainer
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.server import ModelServer
from repro.sft.trainer import SFTTrainer

# full two-stage pipeline: minutes on CPU -> slow tier (`pytest -m slow`)
pytestmark = pytest.mark.slow

CFG = ModelConfig(name="sys", n_layers=2, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab_size=384, block_size=16,
                  attn_impl="structured")


@pytest.fixture(scope="module")
def sft_result():
    tok = ByteTokenizer()
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    ds = MathTaskDataset(tok, CFG.block_size, seq_len=96, seed=0, level=0)
    tr = SFTTrainer(model, AdamWConfig(lr=3e-3, clip_norm=1.0), params)
    hist = tr.run(ds.sft_batches(16), 30, jax.random.PRNGKey(1),
                  verbose=False)
    return model, tr.params, tok, ds, hist


def test_sft_loss_decreases(sft_result):
    _, _, _, _, hist = sft_result
    start = np.mean([h["loss"] for h in hist[:5]])
    end = np.mean([h["loss"] for h in hist[-5:]])
    assert end < 0.7 * start, (start, end)


def test_dipo_step_runs_and_updates_server(sft_result):
    model, params, tok, ds, _ = sft_result
    server = ModelServer(jax.tree.map(jnp.copy, params))
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=96, s_max=4, mode="dynamic", tau=0.7, temperature=1.0))
    tr = DiPOTrainer(model, engine, AdamWConfig(lr=1e-4),
                     DiPOConfig(group_size=4, beta=0.02,
                                logprob_scheme="packed"), server.params)
    v0 = server.version
    hist = tr.run(ds.prompt_batches(4), 2, jax.random.PRNGKey(2),
                  verbose=False)
    assert server.version == v0 + 2          # in-place update per step
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert engine.stats.total_tokens > 0


def test_tracer_layout_loss_close_to_dirl(sft_result):
    """Fig. 4a vs 4b compute the same objective (they differ in attention
    work, not in the NELBO)."""
    from repro.core.block_diffusion import sft_loss
    model, params, tok, ds, _ = sft_result
    b = next(ds.sft_batches(4))
    batch = {k: jnp.asarray(v) for k, v in b.asdict().items()}
    plen = int(batch["prompt_mask"].sum(1).min())
    plen -= plen % CFG.block_size
    batch["prompt_len_static"] = plen
    rng = jax.random.PRNGKey(9)
    l_dirl, _ = sft_loss(model, params, batch, rng, layout="dirl")
    l_trace, _ = sft_loss(model, params, batch, rng, layout="tracer")
    np.testing.assert_allclose(float(l_dirl), float(l_trace), rtol=0.05)
