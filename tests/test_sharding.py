"""Partition-rule coverage and divisibility sanitisation (no devices —
uses AbstractMesh)."""

import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.models.model import BlockDiffLM
from repro.models.modules import tree_paths


def _abstract_mesh(sizes, names):
    # jax >= 0.5 takes (sizes, names); 0.4.x takes a shape tuple of
    # (name, size) pairs.
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _param_shapes(arch):
    cfg = configs.get_config(arch, dtype="bfloat16", param_dtype="bfloat16")
    model = BlockDiffLM(cfg)
    return cfg, jax.eval_shape(model.init,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_every_big_param_is_sharded(arch):
    """No >= 1M-element parameter may end up fully replicated."""
    cfg, shapes = _param_shapes(arch)
    specs = shd.sanitize_specs(
        shd.param_specs(shapes, cfg.n_experts), shapes, MESH)
    flat_shapes = dict(tree_paths(shapes))
    flat_specs = dict(tree_paths_specs(specs, shapes))
    for path, leaf in flat_shapes.items():
        if leaf.size < 1_000_000:
            continue
        spec = flat_specs[path]
        assert any(ax is not None for ax in spec), \
            f"{arch}: {path} {leaf.shape} replicated"


def tree_paths_specs(specs, shapes):
    """Pair spec leaves with param paths (specs are P leaves)."""
    flat_sh, _ = jax.tree_util.tree_flatten(shapes)
    flat_sp = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    paths = [p for p, _ in tree_paths(shapes)]
    assert len(paths) == len(flat_sp)
    return list(zip(paths, flat_sp))


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH, MESH3])
def test_specs_divide_mesh(arch, mesh):
    """After sanitisation every sharded dim divides its mesh axes — the
    exact condition jit in_shardings enforces."""
    cfg, shapes = _param_shapes(arch)
    specs = shd.sanitize_specs(
        shd.param_specs(shapes, cfg.n_experts), shapes, mesh)
    for (path, leaf), (_, spec) in zip(tree_paths(shapes),
                                       tree_paths_specs(specs, shapes)):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        for size, ax in zip(leaf.shape, dims):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            assert size % total == 0, (arch, path, leaf.shape, spec)


def test_cache_specs_head_fallback():
    """kv-heads smaller than the model axis shard the sequence instead."""
    cfg = configs.get_config("mixtral-8x22b", dtype="bfloat16",
                             param_dtype="bfloat16")
    model = BlockDiffLM(cfg)
    caches = jax.eval_shape(functools.partial(model.make_caches, 128, 32768))
    specs = shd.cache_specs(caches, MESH, shard_seq=False)
    flat = dict(tree_paths_specs(specs, caches))
    kspec = flat["groups/l0/k"]
    assert kspec[-2] is None and kspec[-3] == "model"  # seq over model


def test_cache_specs_long_context_seq_sharding():
    cfg = configs.get_config("gemma2-27b", dtype="bfloat16",
                             param_dtype="bfloat16")
    model = BlockDiffLM(cfg)
    caches = jax.eval_shape(functools.partial(model.make_caches, 1, 524288))
    specs = shd.cache_specs(caches, MESH, shard_seq=True)
    flat = dict(tree_paths_specs(specs, caches))
    kspec = flat["groups/l0/k"]
    assert kspec[-4] is None  # batch 1 unsharded
    assert "data" in str(kspec[-3])  # sequence over data


def test_sanitizer_drops_indivisible():
    shapes = {"w": jax.ShapeDtypeStruct((10, 32), jnp.float32)}
    specs = {"w": P("model", "data")}
    out = shd.sanitize_specs(specs, shapes, MESH)
    assert out["w"] == P(None, "data")
