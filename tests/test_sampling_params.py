"""Per-request SamplingParams: one slot pool serving mixed decode
configurations.

Pins the request-API redesign contracts:
  * byte-parity — every row of a mixed-params batch (different τ,
    temperature, mode, block budgets) is bit-identical to the same
    request in a homogeneous run, across dense / paged /
    paged+prefix-cache layouts (the acceptance criterion);
  * zero retraces — the pool's jitted advance compiles once and serves
    any parameter mix (params are traced per-row data, never statics);
  * params never touch prompt KV — mixed-τ requests on one prompt
    share prefix pages exactly like identical requests;
  * per-request stop token / seed / budget semantics, finish_reason,
    and admit→finish latency plumbing through Completion /
    RequestOutput / EngineStats.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decoding
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.serving.api import (GenerationConfig, RequestOutput,
                               SamplingParams)
from repro.serving.engine import EngineStats, RolloutEngine
from repro.serving.scheduler import SlotScheduler
from repro.serving.server import ModelServer

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, block_size=8,
                  attn_impl="structured")
BSZ = CFG.block_size
MAX_LEN = 48
K = MAX_LEN // BSZ

# >= 3 distinct configurations: τ, temperature, mode and block budgets
# all differ (the acceptance-criterion mix)
MIX = [
    SamplingParams(tau=0.5, temperature=1.0, max_new_blocks=2),
    SamplingParams(tau=0.9, temperature=0.0, max_new_blocks=None),
    SamplingParams(tau=0.99, temperature=1.0, max_new_blocks=3),
    SamplingParams(mode="static", n_steps=2, temperature=1.0,
                   max_new_blocks=2),
]


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    return model, params, prompt, pblocks


def _submit_mix(sched, prompt, pblocks, keys):
    """Round-robin the MIX configs over 8 requests; returns uid->cfg idx."""
    owner = {}
    for i in range(8):
        uid = sched.submit(prompt[i % 4], int(pblocks[i % 4]), keys[i],
                           params=MIX[i % len(MIX)])
        owner[uid] = i
    return owner


def _reference_rows(model, params, prompt, pblocks, keys):
    """Homogeneous ground truth: for each config, run the rows that use
    it as one one-shot generate with plain scalar parameters."""
    ref = {}
    for ci, sp in enumerate(MIX):
        rows = [i for i in range(8) if i % len(MIX) == ci]
        toks = np.stack([prompt[i % 4] for i in rows])
        pb = np.array([pblocks[i % 4] for i in rows], np.int32)
        limit = None
        if sp.max_new_blocks is not None:
            limit = np.minimum(K, pb + sp.max_new_blocks)
        gen = decoding.generate(
            model, params, jnp.asarray(toks), jnp.asarray(pb),
            jnp.stack([keys[i] for i in rows]), max_len=MAX_LEN, s_max=4,
            mode=sp.mode, tau=sp.tau, n_steps=sp.n_steps,
            temperature=sp.temperature, eos_id=sp.eos_id, limit=limit)
        for j, i in enumerate(rows):
            ref[i] = (np.asarray(gen["tokens"][j]),
                      np.asarray(gen["steps"][j]),
                      int(gen["gen_blocks"][j]),
                      int(gen["denoise_steps"][j]))
    return ref


def test_mixed_params_byte_parity_all_layouts(setup):
    """Acceptance criterion: a pool serving >= 3 distinct SamplingParams
    is byte-identical per row to homogeneous single-config runs, on
    dense, paged, and paged+prefix-cache layouts."""
    model, params, prompt, pblocks = setup
    keys = jax.random.split(jax.random.PRNGKey(3), 8)
    ref = _reference_rows(model, params, prompt, pblocks, keys)
    for kw in [dict(cache="dense"),
               dict(cache="paged", prefix_cache=False),
               dict(cache="paged", prefix_cache=True)]:
        sched = SlotScheduler(model, n_slots=3, max_len=MAX_LEN, s_max=4,
                              eos_id=1, **kw)
        owner = _submit_mix(sched, prompt, pblocks, keys)
        comps = {c.uid: c for c in sched.run(params)}
        assert sorted(comps) == sorted(owner)
        for uid, c in comps.items():
            toks, steps, gb, dn = ref[owner[uid]]
            assert c.gen_blocks == gb, kw
            assert c.denoise_steps == dn, kw
            hi = (c.prompt_blocks + c.gen_blocks) * BSZ
            np.testing.assert_array_equal(c.tokens[:hi], toks[:hi])
            np.testing.assert_array_equal(c.steps[:hi], steps[:hi])


def test_mixed_params_zero_retrace(setup):
    """Acceptance criterion: after warmup, arbitrary parameter mixes
    reuse the single compiled advance — the trace counter stays at 1
    (parameters are per-row traced data, not jit statics)."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=3, max_len=MAX_LEN, s_max=4,
                          cache="paged")
    keys = jax.random.split(jax.random.PRNGKey(5), 9)
    # warmup: one vanilla request pays the one and only trace
    sched.submit(prompt[0], int(pblocks[0]), keys[8])
    list(sched.run(params))
    assert sched.n_advance_traces == 1
    _submit_mix(sched, prompt, pblocks, keys)
    list(sched.run(params))
    assert sched.n_advance_traces == 1      # zero retraces for the mix


def test_params_never_invalidate_prefix_sharing(setup):
    """Requests with different SamplingParams share prompt pages exactly
    like identical ones: params shape decoding only, never prompt KV.
    Each mixed-τ group member still matches its homogeneous run."""
    model, params, prompt, pblocks = setup
    taus = [0.5, 0.8, 0.9, 0.99]
    keys = jax.random.split(jax.random.PRNGKey(11), len(taus))
    sched = SlotScheduler(model, n_slots=4, max_len=MAX_LEN, s_max=4,
                          cache="paged", prefix_cache=True)
    for i, tau in enumerate(taus):
        sched.submit(prompt[0], 2, keys[i],
                     params=SamplingParams(tau=tau, temperature=1.0,
                                           max_new_blocks=2))
    comps = {c.uid: c for c in sched.run(params)}
    s = sched.stats
    # first member prefills both prompt blocks, every other τ-variant
    # maps the same shared pages — zero extra prefill
    assert s.prefix_miss_blocks == 2
    assert s.prefix_hit_blocks == (len(taus) - 1) * 2
    assert s.prefill_blocks == 2
    for i, tau in enumerate(taus):
        gen = decoding.generate(
            model, params, jnp.asarray(prompt[:1]),
            jnp.asarray(pblocks[:1]), keys[i][None], max_len=MAX_LEN,
            s_max=4, mode="dynamic", tau=tau, temperature=1.0, eos_id=1,
            limit=np.array([2 + 2], np.int32))
        c = comps[i]
        hi = (c.prompt_blocks + c.gen_blocks) * BSZ
        np.testing.assert_array_equal(
            c.tokens[:hi], np.asarray(gen["tokens"][0, :hi]))


def test_engine_mixed_sampling_static_continuous_parity(setup):
    """generate_ids(sampling=[...]) is token-identical between the
    one-shot static path (per-row vectors in one jitted generate) and
    the slot pool, for a mixed-params batch."""
    model, params, prompt, pblocks = setup
    sampling = [MIX[i % len(MIX)] for i in range(4)]
    rng = jax.random.PRNGKey(19)
    outs = {}
    for mode in ["static", "continuous"]:
        eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
            max_len=MAX_LEN, s_max=4, batching=mode, n_slots=3,
            cache="paged" if mode == "continuous" else "dense"))
        outs[mode] = eng.generate_ids(prompt, pblocks, rng,
                                      sampling=sampling)
    a, b = outs["static"], outs["continuous"]
    for k in ["gen_blocks", "denoise_steps", "done", "prompt_blocks"]:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    for i in range(4):
        hi = int((pblocks[i] + a["gen_blocks"][i]) * BSZ)
        np.testing.assert_array_equal(np.asarray(a["tokens"][i, :hi]),
                                      np.asarray(b["tokens"][i, :hi]))
        np.testing.assert_array_equal(np.asarray(a["steps"][i, :hi]),
                                      np.asarray(b["steps"][i, :hi]))


def test_per_request_eos_and_finish_reason(setup):
    """eos_id=-1 disables EOS stopping (the row runs its full budget,
    finish_reason 'length'); a default row's finish_reason matches
    whether its generated region actually contains the stop token."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                          temperature=1.0, tau=0.6)
    keys = jax.random.split(jax.random.PRNGKey(23), 2)
    u_noeos = sched.submit(prompt[0], 2, keys[0],
                           params=SamplingParams(eos_id=-1,
                                                 temperature=1.0,
                                                 tau=0.6,
                                                 max_new_blocks=3))
    u_def = sched.submit(prompt[0], 2, keys[1])
    comps = {c.uid: c for c in sched.run(params)}
    c = comps[u_noeos]
    assert c.gen_blocks == 3                 # ran the whole budget
    assert c.finish_reason == "length" and not c.finished_eos
    assert c.gen_tokens == 3 * BSZ           # -1 never cuts the count
    d = comps[u_def]
    region = d.tokens[d.prompt_blocks * BSZ:
                      (d.prompt_blocks + d.gen_blocks) * BSZ]
    assert d.finish_reason == ("eos" if (region == 1).any() else "length")
    assert d.params.eos_id == 1              # pool default params applied
    for comp in comps.values():
        assert comp.latency_ticks == \
            comp.completed_tick - comp.admitted_tick >= 0


def test_per_request_seed_deterministic(setup):
    """params.seed pins the request's rng: no key argument needed, and
    identical (prompt, params) submissions produce identical bytes."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3,
                          temperature=1.0, tau=0.6)
    sp = SamplingParams(seed=42, temperature=1.0, tau=0.6,
                        max_new_blocks=2)
    u0 = sched.submit(prompt[0], 2, params=sp)
    u1 = sched.submit(prompt[0], 2, params=sp)
    # an explicit key always wins over the seed — batch drivers keep
    # their per-row streams (static/continuous parity) even when the
    # request params happen to carry a seed
    key = jax.random.PRNGKey(77)
    u2 = sched.submit(prompt[0], 2, key, params=sp)
    u3 = sched.submit(prompt[0], 2, key,
                      params=sp.replace(seed=None))
    comps = {c.uid: c for c in sched.run(params)}
    np.testing.assert_array_equal(comps[u0].tokens, comps[u1].tokens)
    np.testing.assert_array_equal(comps[u0].steps, comps[u1].steps)
    np.testing.assert_array_equal(comps[u2].tokens, comps[u3].tokens)
    with pytest.raises(ValueError, match="rng"):
        sched.submit(prompt[0], 2)           # no key, no seed


def test_submit_legacy_budget_override_and_zero_budget(setup):
    """The legacy max_new_blocks= keyword overrides the params' budget;
    an explicit 0 completes immediately with finish_reason 'length'."""
    model, params, prompt, pblocks = setup
    sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=3)
    keys = jax.random.split(jax.random.PRNGKey(29), 2)
    u0 = sched.submit(prompt[0], 2, keys[0],
                      params=SamplingParams(max_new_blocks=4, eos_id=-1),
                      max_new_blocks=1)
    u1 = sched.submit(prompt[0], 2, keys[1],
                      params=SamplingParams(tau=0.3), max_new_blocks=0)
    comps = {c.uid: c for c in sched.run(params)}
    assert comps[u0].gen_blocks == 1         # keyword won
    assert comps[u1].gen_blocks == 0
    assert comps[u1].finish_reason == "length"
    assert comps[u1].params.tau == 0.3       # rest of params preserved


def test_engine_stream_outputs_and_latency_stats(setup):
    """stream() yields structured RequestOutput records; EngineStats
    aggregates admit->finish latencies into p50/p95."""
    model, params, prompt, pblocks = setup
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, tau=0.6, temperature=1.0,
        batching="continuous", n_slots=2))
    uids = [eng.submit(f"q{i}",
                       params=SamplingParams(tau=0.5 + 0.1 * i,
                                             temperature=1.0,
                                             max_new_blocks=2))
            for i in range(3)]
    outs = {o.uid: o for o in eng.stream()}
    assert sorted(outs) == sorted(uids)
    for uid, o in outs.items():
        assert isinstance(o, RequestOutput)
        assert o.finish_reason in ("eos", "length")
        assert o.latency_ticks >= 0
        assert o.gen_tokens >= len(o.token_ids)  # ids trimmed at EOS
    s = eng.stats
    assert len(s.latencies) == 3
    assert 0 <= s.latency_p50 <= s.latency_p95
    # continuous generate_ids also feeds the latency percentiles
    eng.generate_ids(prompt, pblocks, jax.random.PRNGKey(2))
    assert len(eng.stats.latencies) == 7


def test_scheduler_config_collapse(setup):
    """One GenerationConfig object flows engine -> scheduler (no field
    mirror); keyword overrides still patch individual fields."""
    model, params, _, _ = setup
    cfg = GenerationConfig(max_len=MAX_LEN, n_slots=2, tau=0.7,
                           temperature=1.0, mode="static", n_steps=4,
                           eos_id=3)
    sched = SlotScheduler(model, cfg)
    assert sched.n_slots == 2 and sched.max_len == MAX_LEN
    assert sched.default_params == SamplingParams(
        tau=0.7, temperature=1.0, mode="static", n_steps=4, eos_id=3)
    over = SlotScheduler(model, cfg, n_slots=3, tau=0.9)
    assert over.n_slots == 3 and over.default_params.tau == 0.9
    assert cfg.n_slots == 2                  # original untouched
    eng = RolloutEngine(model, ModelServer(params), cfg)
    assert eng.scheduler.gen_cfg is cfg      # handed over whole


def test_group_rollouts_per_group_tau(setup):
    """generate_group_ids(sampling=[per-prompt params]) — the
    DiPOConfig.group_taus lever: each group's G members decode with
    their prompt's τ, byte-identical to a homogeneous run of that τ
    (same rng layout), while prompt pages still dedupe per group."""
    model, params, prompt, pblocks = setup
    P, G = 2, 2
    toks, pb = prompt[:P], pblocks[:P]
    rng = jax.random.PRNGKey(31)
    eng = RolloutEngine(model, ModelServer(params), GenerationConfig(
        max_len=MAX_LEN, s_max=3, temperature=1.0,
        batching="continuous", n_slots=4, cache="paged"))
    per_group = [eng.gen_cfg.sampling(tau=t) for t in (0.5, 0.95)]
    mixed = eng.generate_group_ids(toks, pb, rng, G, sampling=per_group)
    assert eng.stats.prefix_hit_blocks == (G - 1) * int(pb.sum())
    for gi, sp in enumerate(per_group):
        eng_h = RolloutEngine(model, ModelServer(params),
                              GenerationConfig(
            max_len=MAX_LEN, s_max=3, temperature=1.0,
            batching="continuous", n_slots=4, cache="paged"))
        homo = eng_h.generate_group_ids(toks, pb, rng, G, sampling=sp)
        for r in range(gi * G, gi * G + G):
            hi = int((mixed["prompt_blocks"][r]
                      + mixed["gen_blocks"][r]) * BSZ)
            np.testing.assert_array_equal(
                np.asarray(mixed["tokens"][r, :hi]),
                np.asarray(homo["tokens"][r, :hi]))


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="mode"):
        SamplingParams(mode="greedy")
    with pytest.raises(ValueError, match="n_steps"):
        SamplingParams(n_steps=0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="max_new_blocks"):
        SamplingParams(max_new_blocks=-1)
    sp = SamplingParams(tau=0.5)
    assert sp.replace(tau=0.7).tau == 0.7 and sp.tau == 0.5
    assert dataclasses.is_dataclass(sp) and sp.dynamic
