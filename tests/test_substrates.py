"""Substrate layers: optimizer, checkpoint IO, data pipeline, server."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip file when absent
from hypothesis import given, settings, strategies as st

from repro.checkpoint.io import load_pytree, save_pytree
from repro.data.math_tasks import check_answer, parse_answer, sample_problem
from repro.data.pipeline import MathTaskDataset, pad_to_block
from repro.data.tokenizer import ByteTokenizer
from repro.optim import adamw
from repro.optim.schedule import cosine_schedule
from repro.serving.server import ModelServer, OfflineWeightStore

import random


# ------------------------------ optimizer ---------------------------------


def test_adamw_quadratic_convergence():
    cfg = adamw.AdamWConfig(lr=0.1, clip_norm=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw.init_state(cfg, params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_clip_norm():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0)
    params = {"x": jnp.zeros((4,))}
    state = adamw.init_state(cfg, params)
    _, _, m = adamw.apply_updates(cfg, params, {"x": jnp.full((4,), 100.0)},
                                  state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_adamw_bf16_state_dtype():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"x": jnp.zeros((4,), jnp.bfloat16)}
    state = adamw.init_state(cfg, params)
    assert state["m"]["x"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    fn = cosine_schedule(1e-3, 100, warmup_steps=10)
    assert float(fn(jnp.array(5))) == pytest.approx(5e-4)
    assert float(fn(jnp.array(10))) == pytest.approx(1e-3)
    assert float(fn(jnp.array(100))) == pytest.approx(0.0, abs=1e-9)


# ------------------------------ checkpoint --------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "b": {"c": jnp.array([1, 2], jnp.int32)}}
    path = os.path.join(tmp_path, "ck.msgpack")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    for k, l in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(k, np.float32),
                                      np.asarray(l, np.float32))
        assert k.dtype == l.dtype


# ------------------------------ tokenizer / data --------------------------


@settings(max_examples=50, deadline=None)
@given(st.text(max_size=64))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


def test_math_problem_verifiable():
    rng = random.Random(0)
    for _ in range(100):
        p = sample_problem(rng)
        assert check_answer(p.full, p.answer)
        assert parse_answer("no answer here") is None
        assert not check_answer(p.full, p.answer + 1)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 200), st.sampled_from([4, 8, 16]))
def test_pad_to_block(n, bsz):
    ids = list(range(n))
    out = pad_to_block(ids, bsz, 0)
    assert len(out) % bsz == 0
    assert out[:n] == ids
    assert len(out) - n < bsz


def test_sft_batches_block_aligned():
    tok = ByteTokenizer()
    ds = MathTaskDataset(tok, block_size=16, seq_len=128, seed=0)
    b = next(ds.sft_batches(4))
    assert b.tokens.shape == (4, 128)
    # prompt region ends on a block boundary
    plens = b.prompt_mask.sum(axis=1)
    assert (plens % 16 == 0).all() and (plens > 0).all()
    vlens = b.valid.sum(axis=1)
    assert (vlens % 16 == 0).all()
    # valid covers the prompt + body
    assert ((b.tokens != 0).sum(axis=1) <= vlens).all()


# ------------------------------ server ------------------------------------


def test_server_inplace_update_no_io():
    params = {"w": jnp.ones((8, 8))}
    srv = ModelServer(params)
    assert srv.version == 0
    v = srv.update_weights({"w": jnp.zeros((8, 8))})
    assert v == 1
    assert float(srv.params["w"].sum()) == 0.0


def test_offline_store_roundtrips_through_fs(tmp_path):
    params = {"w": jnp.full((8, 8), 3.0)}
    store = OfflineWeightStore(params, root=str(tmp_path))
    p1 = store.params
    np.testing.assert_array_equal(np.asarray(p1["w"]),
                                  np.asarray(params["w"]))
    store.update_weights({"w": jnp.full((8, 8), 4.0)})
    assert float(store.params["w"][0, 0]) == 4.0
    # files actually exist on disk (the Fig 5a IO cost is real)
    assert len(os.listdir(tmp_path)) >= 2
    assert store.load_seconds > 0
