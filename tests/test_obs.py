"""Observability layer: metrics registry semantics, span tracer
invariants, request-lifecycle completeness through the scheduler, and
exporter schema validation — plus the two properties the layer must not
break: token byte-parity and the zero-retrace contract with tracing on.
"""

import json

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.obs import export
from repro.obs.metrics import Counter, Histogram, MetricsRegistry
from repro.obs.trace import Span, Tracer
from repro.serving.engine import EngineStats
from repro.serving.scheduler import SlotScheduler

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, block_size=8,
                  attn_impl="structured")
BSZ = CFG.block_size
MAX_LEN = 48


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (4, 16), 4, 100))
    pblocks = np.array([2, 1, 2, 1], np.int32)
    return model, params, prompt, pblocks


# ========================================================== registry


def test_counter_monotonic():
    reg = MetricsRegistry("t")
    c = reg.counter("ticks", "tick count")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_reset_spares_counters():
    reg = MetricsRegistry("t")
    c = reg.counter("done")
    g = reg.gauge("active")
    h = reg.histogram("lat", reservoir=8)
    c.inc(5)
    g.set(3)
    h.observe(1.0)
    reg.reset()
    assert c.value == 5          # monotonic: survives registry reset
    assert g.value == 0
    assert len(h) == 0 and h.count == 0


def test_labeled_family():
    reg = MetricsRegistry("t")
    fam = reg.histogram("phase_seconds", labelnames=("phase",))
    fam.labels(phase="rollout").observe(1.0)
    fam.labels(phase="train").observe(2.0)
    assert fam.labels(phase="rollout").count == 1
    by_labels = {s.labels: s.value for s in reg.collect()}
    assert by_labels[(("phase", "rollout"),)]["count"] == 1
    assert by_labels[(("phase", "train"),)]["sum"] == 2.0


def test_bind_storage_views_dataclass_field():
    """The bind=(obj, attr) design: plain attribute mutation and the
    registry see ONE value — the scheduler keeps writing
    ``stats.ticks += 1`` and collect() reports it."""

    class Box:
        ticks = 0

    box = Box()
    reg = MetricsRegistry("t")
    c = reg.counter("ticks", bind=(box, "ticks"))
    box.ticks += 7
    assert c.value == 7
    c.inc(2)
    assert box.ticks == 9
    (s,) = reg.collect()
    assert s.name == "t_ticks" and s.value == 9


def test_histogram_bounded_reservoir_and_percentiles():
    h = Histogram("lat", reservoir=100)
    for v in range(1000):
        h.observe(float(v))
    assert len(h) == 100                      # bounded window
    assert h.count == 1000 and h.sum == sum(range(1000))
    assert h.maxlen == 100
    # recent-window percentiles: values 900..999
    assert h.percentile(50) == pytest.approx(949.5)
    assert 990 <= h.percentile(99) <= 999
    # deque-compatible legacy surface
    h2 = Histogram("lat2", reservoir=4)
    h2.append(1)
    assert list(h2) == [1] and bool(h2)


def test_engine_stats_latency_p99():
    s = EngineStats()
    for v in range(1, 101):
        s.latencies.append(v)
    assert s.latency_p50 == pytest.approx(50.5)
    assert s.latency_p99 == pytest.approx(np.percentile(range(1, 101), 99))
    names = {smp.name for smp in s.registry.collect()}
    assert "dirl_engine_latency_ticks" in names


# ============================================================ tracer


def test_span_nesting_and_tracks():
    tr = Tracer()
    with tr.span("outer", cat="scheduler", track="scheduler"):
        with tr.span("inner", cat="scheduler", track="scheduler"):
            pass
    inner, outer = tr.snapshot()              # inner closes first
    assert inner.name == "inner" and outer.name == "outer"
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1


def test_ring_eviction_counts_dropped():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4 and tr.dropped == 6
    assert [sp.name for sp in tr.snapshot()] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_disabled_tracer_still_times():
    """Engine wall-time comes from span durations, so a disabled
    tracer must still measure — it just records nothing."""
    tr = Tracer(enabled=False)
    with tr.span("work") as sp:
        sum(range(1000))
    assert sp.dur > 0
    assert len(tr) == 0
    tr.begin("k", "lifecycle")
    assert tr.end("k") is None and tr.n_open == 0


def test_begin_end_lifecycle_merges_args():
    tr = Tracer()
    tr.begin(("req", 0), "req 0", cat="request", track="slot 0", uid=0)
    tr.amend(("req", 0), slot=0)
    sp = tr.end(("req", 0), finish_reason="eos")
    assert sp.args == {"uid": 0, "slot": 0, "finish_reason": "eos"}
    assert tr.end(("req", 0)) is None         # idempotent close


# ==================================== scheduler lifecycle + parity


def _drain(sched, prompt, pblocks, params, budget=3):
    keys = jax.random.split(jax.random.PRNGKey(7), prompt.shape[0])
    for i in range(prompt.shape[0]):
        sched.submit(prompt[i], int(pblocks[i]), keys[i],
                     max_new_blocks=budget)
    return {c.uid % prompt.shape[0]: c for c in sched.run(params)}


def test_lifecycle_completeness_under_deferral(setup):
    """A page pool too small for concurrent admission defers requests;
    every request must still end with a closed decode span carrying the
    finish_reason / slot / prefix-hit / kernel-mode labels, and defer
    markers must land on the scheduler track."""
    model, params, prompt, pblocks = setup
    K = MAX_LEN // BSZ
    sched = SlotScheduler(model, n_slots=4, max_len=MAX_LEN, s_max=4,
                          mode="dynamic", tau=0.6, temperature=1.0,
                          eos_id=1, cache="paged", n_pages=2 * K + 1,
                          kernel="pallas", trace=True)
    comps = _drain(sched, prompt, pblocks, params)
    assert len(comps) == 4
    assert sched.stats.deferred > 0           # the pool did defer
    assert sched.tracer.n_open == 0           # every lifecycle closed
    spans = sched.tracer.snapshot()
    names = {sp.name for sp in spans}
    assert {"tick", "admit", "advance", "harvest", "defer"} <= names
    decode = {sp.args["uid"]: sp for sp in spans
              if sp.cat == "request" and sp.track.startswith("slot")}
    assert sorted(decode) == [0, 1, 2, 3]
    for sp in decode.values():
        for label in ("finish_reason", "slot", "hit_blocks",
                      "kernel_mode"):
            assert label in sp.args, (sp.name, label)
        assert sp.dur > 0
    queued = [sp for sp in spans if sp.track == "queue"]
    assert len(queued) == 4
    defers = [sp for sp in spans if sp.name == "defer"]
    assert all(sp.track == "scheduler" for sp in defers)


def test_tracing_preserves_bytes_and_single_trace(setup):
    """Tracing on vs off: token-identical completions and the advance
    still traces exactly once — observability is free of semantic and
    retrace cost."""
    model, params, prompt, pblocks = setup
    comps = {}
    for traced in (False, True):
        sched = SlotScheduler(model, n_slots=2, max_len=MAX_LEN, s_max=4,
                              mode="dynamic", tau=0.6, temperature=1.0,
                              eos_id=1, cache="paged", trace=traced)
        _drain(sched, prompt, pblocks, params)       # warm
        sched.stats = type(sched.stats)()
        comps[traced] = _drain(sched, prompt, pblocks, params)
        assert sched.n_advance_traces == 1, sched.n_advance_traces
    for uid, c in comps[False].items():
        t = comps[True][uid]
        hi = (c.prompt_blocks + c.gen_blocks) * BSZ
        assert c.gen_blocks == t.gen_blocks
        np.testing.assert_array_equal(c.tokens[:hi], t.tokens[:hi])


def test_stats_reset_gives_fresh_registry():
    """The warmup idiom ``sched.stats = type(sched.stats)()`` must
    produce a working registry bound to the NEW object."""
    s1 = EngineStats()
    s1.rollouts += 3
    s2 = type(s1)()
    assert s2.rollouts == 0
    s2.rollouts += 1
    by_name = {smp.name: smp.value for smp in s2.registry.collect()}
    assert by_name["dirl_engine_rollouts"] == 1


# ========================================================== exporters


def _spans():
    tr = Tracer(clock=iter(np.arange(1.0, 9.0, 0.5).tolist()).__next__)
    with tr.span("tick", cat="scheduler", track="scheduler"):
        with tr.span("advance", cat="scheduler", track="scheduler"):
            pass
    tr.begin(("d", 0), "req 0", cat="request", track="slot 0", uid=0)
    tr.begin(("d", 1), "req 1", cat="request", track="slot 1", uid=1)
    tr.end(("d", 0), finish_reason="eos")
    tr.end(("d", 1), finish_reason="budget")
    tr.instant("defer", cat="scheduler", track="scheduler")
    return tr.snapshot()


def test_chrome_trace_schema_and_slot_tracks(tmp_path):
    path = tmp_path / "run.trace.json"
    export.write_chrome_trace(path, _spans(), metadata={"tool": "test"})
    payload = export.validate_chrome_trace(path)
    events = payload["traceEvents"]
    threads = {e["args"]["name"] for e in events
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"scheduler", "slot 0", "slot 1"} <= threads
    complete = [e for e in events if e["ph"] == "X"]
    assert all(isinstance(e["ts"], int) and e["dur"] >= 1
               for e in complete)
    reqs = {e["name"]: e for e in complete if e["cat"] == "request"}
    assert reqs["req 0"]["args"]["finish_reason"] == "eos"
    assert payload["otherData"]["schema_version"] == \
        export.TRACE_SCHEMA_VERSION


def test_chrome_trace_validation_rejects_corruption(tmp_path):
    path = tmp_path / "bad.trace.json"
    export.write_chrome_trace(path, _spans())
    payload = json.loads(path.read_text())
    payload["traceEvents"][1]["ph"] = "Q"
    path.write_text(json.dumps(payload))
    with pytest.raises(ValueError):
        export.validate_chrome_trace(path)


def test_metrics_json_roundtrip(tmp_path):
    s = EngineStats()
    s.rollouts += 2
    s.latencies.append(4)
    path = tmp_path / "m.json"
    export.write_metrics_json(path, s.registry)
    payload = export.validate_metrics_json(path)
    by_name = {m["name"]: m for m in payload["metrics"]}
    assert by_name["dirl_engine_rollouts"]["value"] == 2
    assert by_name["dirl_engine_latency_ticks"]["value"]["count"] == 1


def test_prometheus_text(tmp_path):
    reg = MetricsRegistry("dirl_test")
    reg.counter("ticks", "tick count").inc(3)
    reg.histogram("lat", reservoir=8).observe(2.0)
    reg.info("kernel_mode", "exec mode").set("interpret")
    text = export.prometheus_text(reg)
    assert "# TYPE dirl_test_ticks counter" in text
    assert "dirl_test_ticks 3" in text
    assert "dirl_test_lat_count 1" in text
    assert 'quantile="0.99"' in text
    assert 'dirl_test_kernel_mode_info{value="interpret"} 1' in text


def test_jsonl_dump(tmp_path):
    path = tmp_path / "spans.jsonl"
    n = export.write_jsonl(path, _spans())
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == n == 5
    assert all({"name", "track", "t0", "t1", "dur", "args"} <= set(ln)
               for ln in lines)
