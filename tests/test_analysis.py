"""HLO analysis utilities (roofline substrate)."""

import pytest

from repro.launch import hlo_analysis as hlo

SAMPLE_HLO = """
  %ag = bf16[8,128,256]{2,1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}
  %ar = f32[1024,1024]{1,0} all-reduce(%y), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %ars = f32[64,64]{1,0} all-reduce-start(%z), replica_groups={{0,1}}
  %ard = f32[64,64]{1,0} all-reduce-done(%ars)
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %tup = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b), replica_groups=[4,2]
"""


def test_collective_stats_parsing():
    st = hlo.collective_stats(SAMPLE_HLO)
    per = st["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-reduce"]["count"] == 2        # -start counted, -done not
    assert per["collective-permute"]["count"] == 1
    assert per["all-to-all"]["count"] == 1
    # all-gather: 8*128*256*2 bytes * (4-1)/4
    assert per["all-gather"]["bytes"] == int(8 * 128 * 256 * 2 * 3 / 4)
    # all-reduce big: 1024^2*4 * 2 * 7/8
    expect_ar = int(1024 * 1024 * 4 * 2 * 7 / 8) + int(64 * 64 * 4 * 2 / 2)
    assert per["all-reduce"]["bytes"] == expect_ar
    # tuple all-to-all sums both members, n=2 groups of size 2
    assert per["all-to-all"]["bytes"] == int(2 * 16 * 16 * 4 * 1 / 2)
    assert st["total_bytes"] == sum(v["bytes"] for v in per.values())


def test_roofline_terms_and_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"total_bytes": 50e9 * 3}
    t = hlo.roofline_terms(cost, coll, 256)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(2.0)
    assert t["t_collective_s"] == pytest.approx(3.0)
    assert hlo.dominant_term(t) == "collective"


def test_active_params_moe():
    from repro import configs
    cfg = configs.get_config("mixtral-8x22b")
    total = 140_630_000_000
    act = hlo.active_params(cfg, total)
    # 8 experts top-2 -> roughly (2+overhead)/8 of expert params active
    assert act < 0.45 * total
    dense = configs.get_config("deepseek-7b")
    assert hlo.active_params(dense, 123) == 123
