"""HLO analysis utilities (roofline substrate)."""

import pytest

from repro.launch import hlo_analysis as hlo

SAMPLE_HLO = """
  %ag = bf16[8,128,256]{2,1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}
  %ar = f32[1024,1024]{1,0} all-reduce(%y), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %ars = f32[64,64]{1,0} all-reduce-start(%z), replica_groups={{0,1}}
  %ard = f32[64,64]{1,0} all-reduce-done(%ars)
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %tup = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b), replica_groups=[4,2]
"""


def test_collective_stats_parsing():
    st = hlo.collective_stats(SAMPLE_HLO)
    per = st["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-reduce"]["count"] == 2        # -start counted, -done not
    assert per["collective-permute"]["count"] == 1
    assert per["all-to-all"]["count"] == 1
    # all-gather: 8*128*256*2 bytes * (4-1)/4
    assert per["all-gather"]["bytes"] == int(8 * 128 * 256 * 2 * 3 / 4)
    # all-reduce big: 1024^2*4 * 2 * 7/8
    expect_ar = int(1024 * 1024 * 4 * 2 * 7 / 8) + int(64 * 64 * 4 * 2 / 2)
    assert per["all-reduce"]["bytes"] == expect_ar
    # tuple all-to-all sums both members, n=2 groups of size 2
    assert per["all-to-all"]["bytes"] == int(2 * 16 * 16 * 4 * 1 / 2)
    assert st["total_bytes"] == sum(v["bytes"] for v in per.values())


def test_roofline_terms_and_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"total_bytes": 50e9 * 3}
    t = hlo.roofline_terms(cost, coll, 256)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(2.0)
    assert t["t_collective_s"] == pytest.approx(3.0)
    assert hlo.dominant_term(t) == "collective"


def test_active_params_moe():
    from repro import configs
    cfg = configs.get_config("mixtral-8x22b")
    total = 140_630_000_000
    act = hlo.active_params(cfg, total)
    # 8 experts top-2 -> roughly (2+overhead)/8 of expert params active
    assert act < 0.45 * total
    dense = configs.get_config("deepseek-7b")
    assert hlo.active_params(dense, 123) == 123


# ===========================================================================
# dirlint: the contract-checking static-analysis pass
# ===========================================================================

import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.analysis import run_all
from repro.analysis.astutils import Project
from repro.analysis import donation, trace_lint
from repro.analysis.guards import TraceGuard
from repro.analysis.kernel_contracts import (Launch, capture_launches,
                                             check_kernels, check_launch,
                                             check_parity_coverage)
from repro.analysis.rules import (Finding, RULES, apply_pragmas,
                                  scan_pragmas)


def _project(tmp_path, files: dict) -> Project:
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return Project(tmp_path)


def _rules(findings):
    return {f.rule for f in findings}


# ------------------------------------------------------- rule registry


def test_rule_registry_complete():
    assert set(RULES) == {
        "trace-branch", "trace-host-pull", "hot-sync", "obs-in-trace",
        "post-donation-read", "kernel-oob-index", "kernel-scratch-tile",
        "kernel-plan-matrix", "kernel-parity-coverage"}
    for rule in RULES.values():
        assert rule.doc


# ------------------------------------------------------- trace hygiene


def test_trace_branch_and_host_pull_fire(tmp_path):
    project = _project(tmp_path, {"mod.py": """
        import jax

        def step(x):
            if x > 0:
                x = x + 1
            y = x.item()
            return x * y

        fast_step = jax.jit(step)
    """})
    findings = trace_lint.run(project)
    assert "trace-branch" in _rules(findings)
    assert "trace-host-pull" in _rules(findings)


def test_static_guards_do_not_fire(tmp_path):
    project = _project(tmp_path, {"mod.py": """
        import jax

        def sized(x, n, p):
            if n > 2:                    # static_argnames
                x = x + n
            if x.ndim == 3:              # shape metadata
                x = x[0]
            if "bias" in p:              # pytree structure
                x = x + p["bias"]
            return x

        jitted = jax.jit(sized, static_argnames=("n",))
    """})
    assert trace_lint.run(project) == []


def test_hot_sync_fires_in_hot_path(tmp_path):
    project = _project(tmp_path, {"serving/engine.py": """
        import jax

        class RolloutEngine:
            def stream(self, x):
                jax.block_until_ready(x)
                return x
    """})
    findings = trace_lint.run(project)
    assert _rules(findings) == {"hot-sync"}


# ------------------------------------------------------- obs-in-trace

_OBS_FIXTURE = {"obs/__init__.py": "", "obs/trace.py": """
    class Tracer:
        def span(self, name):
            pass

        def begin(self, key, name):
            pass
"""}


def test_obs_in_trace_fires(tmp_path):
    """Every detection route: `.tracer.<span-API>` chains, obs
    constructors, and method calls on a locally bound obs handle."""
    project = _project(tmp_path, {**_OBS_FIXTURE, "eng.py": """
        import jax
        from repro.obs.trace import Tracer

        class Eng:
            def hot(self, x):
                with self.tracer.span("step"):
                    return x * 2

            def hot2(self, x):
                t = Tracer()
                t.begin("k", "n")
                return x

            def drive(self, x):
                return jax.jit(self.hot)(x) + jax.jit(self.hot2)(x)
    """})
    findings = [f for f in trace_lint.run(project)
                if f.rule == "obs-in-trace"]
    assert len(findings) == 3
    msgs = " ".join(f.message for f in findings)
    assert "self.tracer.span" in msgs          # chain on conventional name
    assert "repro.obs.trace.Tracer" in msgs    # constructor via from-import
    assert "t.begin" in msgs                   # local obs handle


def test_obs_host_side_is_clean(tmp_path):
    """Obs calls *around* the dispatch — the scheduler pattern — stay
    unflagged: only jit-reachable bodies are walked."""
    project = _project(tmp_path, {**_OBS_FIXTURE, "sched.py": """
        import jax
        from repro.obs.trace import Tracer

        class Sched:
            def _kernel(self, x):
                return x + 1

            def step(self, x):
                with self.tracer.span("tick"):
                    return jax.jit(self._kernel)(x)
    """})
    assert "obs-in-trace" not in _rules(trace_lint.run(project))


def test_obs_in_trace_pragma_suppresses(tmp_path):
    src = textwrap.dedent("""
        import jax

        def hot(self, x):
            self.tracer.begin("k", "n")  # dirlint: ok(obs-in-trace)
            return x

        step = jax.jit(hot)
    """)
    project = _project(tmp_path, {"mod.py": src})
    findings = apply_pragmas(
        trace_lint.run(project),
        {str(tmp_path / "mod.py"): scan_pragmas(src)})
    obs = [f for f in findings if f.rule == "obs-in-trace"]
    assert len(obs) == 1 and obs[0].suppressed


# ------------------------------------------------------- donation safety


def test_post_donation_read_fires(tmp_path):
    project = _project(tmp_path, {"mod.py": """
        import jax

        def _adv(state, x):
            return state

        advance = jax.jit(_adv, donate_argnums=(0,))

        def drive(state, x):
            out = advance(state, x)
            return state.tokens
    """})
    findings = donation.run(project)
    assert _rules(findings) == {"post-donation-read"}
    (f,) = findings
    assert "state" in f.message and "advance" in f.message


def test_post_donation_rebind_is_safe(tmp_path):
    project = _project(tmp_path, {"mod.py": """
        import jax

        def _adv(state, x):
            return state

        advance = jax.jit(_adv, donate_argnums=(0,))

        def drive(state, x):
            state = advance(state, x)
            return state.tokens
    """})
    assert donation.run(project) == []


def test_post_donation_consumer_loop_wraparound_fires(tmp_path):
    """The async RL consumer hazard (rl/pipeline/loop.py): the fused
    DiPO step donates the param buffers the weight server still shares,
    so a loop body that pushes the step *output* but forgets to rebind
    its own ``params`` re-reads a dead buffer on the next iteration.
    This is the static face of the runtime guard
    ``ModelServer.params_at`` (StaleParamsError)."""
    project = _project(tmp_path, {"loop.py": """
        import jax

        def _step(params, opt_state, batch):
            return params, opt_state, {}

        step = jax.jit(_step, donate_argnums=(0, 1))

        def consume(server, params, opt_state, batches):
            for batch in batches:
                new_params, opt_state, m = step(params, opt_state, batch)
                server.update_weights(new_params)
            return new_params
    """})
    findings = donation.run(project)
    assert _rules(findings) == {"post-donation-read"}
    (f,) = findings
    assert "params" in f.message and "step" in f.message


def test_post_donation_consumer_rebind_and_push_is_safe(tmp_path):
    """The canonical consumer shape: rebind params from the step output
    in the call statement, push, and re-read live weights through the
    server's versioned surface — no dead-buffer read anywhere."""
    project = _project(tmp_path, {"loop.py": """
        import jax

        def _step(params, opt_state, batch):
            return params, opt_state, {}

        step = jax.jit(_step, donate_argnums=(0, 1))

        def consume(server, params, opt_state, batches):
            for batch in batches:
                params, opt_state, m = step(params, opt_state, batch)
                server.update_weights(params)
                version, live = server.params_versioned()
            return params
    """})
    assert donation.run(project) == []


# ------------------------------------------------------- kernel contracts


def _launch(**kw):
    base = dict(name="k", grid=(3,), num_scalar_prefetch=0,
                in_specs=[], out_specs=[], scratch=[], operands=[],
                out_shapes=[], interpret=True)
    base.update(kw)
    return Launch(**base)


def test_oob_index_map_fires():
    # grid point i=2 maps to rows [16, 24) of a 16-row operand
    bad = _launch(
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        operands=[np.zeros((16, 128), np.float32)])
    findings = check_launch(bad, require_tile=False, path="fix.py",
                            line=1, where="decode")
    assert _rules(findings) == {"kernel-oob-index"}

    ok = _launch(
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        operands=[np.zeros((24, 128), np.float32)])
    assert check_launch(ok, require_tile=False, path="fix.py",
                        line=1, where="decode") == []


def test_misaligned_scratch_fires_only_when_tiled():
    bad = _launch(scratch=[((16, 1), jnp.int32)])
    findings = check_launch(bad, require_tile=True, path="fix.py",
                            line=1, where="prefill")
    assert _rules(findings) == {"kernel-scratch-tile"}
    assert check_launch(bad, require_tile=False, path="fix.py",
                        line=1, where="prefill") == []


def test_capture_launches_records_and_short_circuits():
    def body(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    with capture_launches() as launches:
        out = pl.pallas_call(
            body, grid=(2,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
        )(jnp.ones((16, 128), jnp.float32))
    assert out.shape == (16, 128)
    assert not out.any()                  # body never ran
    (launch,) = launches
    assert launch.grid == (2,) and launch.name == "body"
    # the patch is scoped: outside the context the real pallas_call is back
    assert "pallas_call" in repr(pl.pallas_call)


def test_kernel_plan_matrix_clean_on_cpu():
    """All four plan_exec combos of both paged kernels (plus
    block-diff) pass bounds/tiling/abstract-eval on a CPU host."""
    assert check_kernels() == []


def test_parity_coverage_clean_and_fires(tmp_path):
    assert check_parity_coverage() == []

    bad = tmp_path / "t.py"
    bad.write_text(textwrap.dedent("""
        def test_decode_only():
            out = paged_decode_attention(q, k, v, block_table=bt)
    """))
    findings = check_parity_coverage(tests_path=bad)
    rules = _rules(findings)
    assert rules == {"kernel-parity-coverage"}
    msgs = " ".join(f.message for f in findings)
    assert "paged_prefill_attention" in msgs     # prefill never exercised
    assert "window" in msgs or "softcap" in msgs  # decode features missing


# ------------------------------------------------------- pragmas


def test_pragma_suppression_same_line_and_above():
    src = ("x = compute()\n"
           "jax.block_until_ready(x)  # dirlint: ok(hot-sync)\n"
           "# dirlint: ok(trace-branch, trace-host-pull)\n"
           "y = float(x)\n")
    pragmas = {"f.py": scan_pragmas(src)}
    out = apply_pragmas(
        [Finding("hot-sync", "f.py", 2, "m"),
         Finding("trace-host-pull", "f.py", 4, "m"),
         Finding("hot-sync", "f.py", 4, "m")], pragmas)
    assert [f.suppressed for f in out] == [True, True, False]


# ------------------------------------------------------- whole repo


def test_repo_has_zero_unsuppressed_findings():
    findings = run_all()
    loud = [f for f in findings if not f.suppressed]
    assert loud == [], "\n".join(f.format() for f in loud)
    # the deliberate, pragma'd syncs are still visible to --verbose
    assert any(f.suppressed and f.rule == "hot-sync" for f in findings)


# ------------------------------------------------------- TraceGuard


def test_traceguard_counts_compiles_not_calls():
    def f(x, y):
        return x + y

    g = TraceGuard(f, name="g")
    a = jnp.ones((4,))
    g(a, a)
    g(a, a)                               # cache hit
    assert g.n_traces == 1
    g(jnp.ones((8,)), jnp.ones((8,)))     # new shape -> retrace
    assert g.n_traces == 2
    assert g.stats() == {"name": "g", "n_traces": 2}
    g.reset()
    assert g.n_traces == 0
    g(a, a)                               # cache survives reset()
    assert g.n_traces == 0


def test_traceguard_static_argnames_bind_positionally():
    def f(x, n):
        return x * n

    g = TraceGuard(f, static_argnames=("n",))
    out = g(jnp.ones((2,)), 3)            # n passed positionally
    assert float(out[0]) == 3.0
    assert g.n_traces == 1
    g(jnp.ones((2,)), 3)
    assert g.n_traces == 1
    g(jnp.ones((2,)), 4)                  # new static value -> retrace
    assert g.n_traces == 2


def test_guard_stats_surface_through_stats_dataclasses():
    from repro.serving.engine import EngineStats
    from repro.serving.scheduler import SchedulerStats
    assert "advance_traces" in {f.name
                                for f in dataclasses.fields(SchedulerStats)}
    assert "advance_traces" in {f.name
                                for f in dataclasses.fields(EngineStats)}
