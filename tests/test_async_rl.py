"""Async RL pipeline: staleness accounting, K=0 bitwise equivalence
with the synchronous trainer, importance-weighted off-policy updates,
and the zero-retrace contract across mixed-version batches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decoding
from repro.core.dipo import dipo_loss
from repro.core.trajectory import RolloutBatch
from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.optim.adamw import AdamWConfig
from repro.rl.pipeline import AsyncDiPOTrainer, ReplayQueue, RolloutGroup
from repro.rl.trainer import DiPOConfig, DiPOTrainer
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.server import ModelServer, StaleParamsError

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=384, block_size=8,
                  attn_impl="structured")
BSZ = CFG.block_size
MAX_LEN = 48


@pytest.fixture(scope="module")
def setup():
    model = BlockDiffLM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    return model, params, tok


def _stack(model, params, tok):
    server = ModelServer(jax.tree.map(jnp.copy, params))
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=MAX_LEN, s_max=4, n_slots=4, cache="paged",
        temperature=1.0, tau=0.7), tokenizer=tok)
    return server, engine


def _ds(tok):
    return MathTaskDataset(tok, BSZ, seq_len=MAX_LEN, seed=0, level=0)


# ------------------------------------------------------- replay queue


def _mk_group(pid, version, G=2, L=2 * BSZ):
    gen = {"tokens": np.full((G, L), pid, np.int32),
           "steps": np.zeros((G, L), np.int32),
           "gen_blocks": np.ones((G,), np.int32),
           "prompt_blocks": np.ones((G,), np.int32),
           "done": np.ones((G,), bool),
           "denoise_steps": np.ones((G,), np.int32)}
    return RolloutGroup(prompt_id=pid, gen=gen,
                        rewards=np.zeros((G,), np.float32),
                        version=version, version_min=version,
                        version_max=version)


def test_discard_policy_evicts_beyond_window():
    """Groups older than K versions are evicted (and counted) at pop
    time under the discard policy; fresh ones flow through FIFO."""
    q = ReplayQueue(capacity=8, staleness_k=1, policy="discard")
    for pid, v in enumerate([0, 0, 1, 2]):
        q.push(_mk_group(pid, v))
    assert q.depth == 4
    assert q.n_ready(current_version=2) == 2   # staleness 2,2,1,0
    got = q.pop_batch(2, current_version=2)
    assert [g.prompt_id for g in got] == [2, 3]
    assert q.registry.get("groups_discarded").value == 2
    assert q.registry.get("groups_consumed").value == 2
    assert q.depth == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        q.pop_batch(1, current_version=2)


def test_importance_policy_keeps_stale_groups():
    """The importance policy never evicts — stale groups are consumed
    (their stored behaviour log-probs correct the update) and their
    staleness lands in the histogram."""
    q = ReplayQueue(capacity=8, staleness_k=1, policy="importance")
    for pid, v in enumerate([0, 0, 1, 2]):
        q.push(_mk_group(pid, v))
    assert q.n_ready(current_version=2) == 4
    got = q.pop_batch(4, current_version=2)
    assert [g.prompt_id for g in got] == [0, 1, 2, 3]
    assert [g.staleness(2) for g in got] == [2, 2, 1, 0]
    hist = q.registry.get("staleness")
    assert hist.count == 4 and max(hist) == 2
    assert q.registry.get("groups_discarded").value == 0


def test_future_version_tag_is_an_error():
    q = ReplayQueue(capacity=4, staleness_k=0)
    q.push(_mk_group(0, version=3))
    with pytest.raises(RuntimeError, match="corrupted"):
        q.pop_batch(1, current_version=2)


# ------------------------------------------- versioned server surface


def test_params_at_raises_on_stale_version(setup):
    """`params_at` is the post-donation read guard: after an update the
    old version's buffers were donated, so asking for them must fail
    loudly instead of returning garbage."""
    _, params, _ = setup
    server = ModelServer(jax.tree.map(jnp.copy, params))
    v0, p0 = server.params_versioned()
    assert server.params_at(v0) is p0
    new = jax.tree.map(jnp.copy, p0)
    v1 = server.update_weights(new)
    assert v1 == v0 + 1
    assert server.params_at(v1) is not None
    with pytest.raises(StaleParamsError, match="donated"):
        server.params_at(v0)


# --------------------------------------------- K=0 bitwise equivalence


def test_k0_bitwise_matches_sync_trainer(setup, monkeypatch):
    """staleness_k=0 reproduces DiPOTrainer parameter updates bitwise
    over 3 steps — same rollout tokens, same params, same opt state —
    even though the async path runs through submit/stream/queue."""
    model, params, tok = setup
    captured = []
    orig = decoding.rollout_to_batch

    def spy(gen, rewards, group, block_size):
        captured.append(np.asarray(gen["tokens"]))
        return orig(gen, rewards, group, block_size)

    monkeypatch.setattr(decoding, "rollout_to_batch", spy)

    opt = AdamWConfig(lr=1e-3)
    rl = DiPOConfig(group_size=2, logprob_scheme="packed")

    s1, e1 = _stack(model, params, tok)
    tr = DiPOTrainer(model, e1, opt, rl, jax.tree.map(jnp.copy, params))
    h1 = tr.run(_ds(tok).prompt_batches(2), 3, jax.random.PRNGKey(7),
                verbose=False)
    sync_rolls, captured = captured[:], []

    s2, e2 = _stack(model, params, tok)
    atr = AsyncDiPOTrainer(model, e2, opt, rl,
                           jax.tree.map(jnp.copy, params), staleness_k=0)
    h2 = atr.run(_ds(tok).prompt_batches(2), 3, jax.random.PRNGKey(7),
                 verbose=False)
    async_rolls = captured

    # rollouts bitwise identical, step by step
    assert len(sync_rolls) == len(async_rolls) == 3
    for a, b in zip(sync_rolls, async_rolls):
        np.testing.assert_array_equal(a, b)
    # parameter and optimizer trajectories bitwise identical
    for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                    jax.tree_util.tree_leaves(atr.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(tr.opt_state),
                    jax.tree_util.tree_leaves(atr.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in h1] == [h["loss"] for h in h2]
    assert s1.version == s2.version == 3
    # K=0 consumption is exactly on-policy: zero recorded staleness
    assert all(h["staleness_max"] == 0 for h in h2)


# --------------------------------------- importance weights, two versions


def test_two_version_importance_weights_hand_computed():
    """One group, two members rolled out under different param versions:
    the stored behaviour log-probs produce the exact Eq. 6 ratios.

    Row 0 (fresh, version v):   old_logp == logp      -> ratio 1
    Row 1 (stale, version v-1): old_logp = log(0.1),
                                logp = log(0.2)       -> ratio 2

    rewards [1, 0] -> adv [+0.5, -0.5]; eps = 0.2, token aggregation,
    all L=4 positions generated and valid:
      surr row0 = min(1*0.5, 1*0.5)        = +0.5 per token
      surr row1 = min(2*-0.5, 1.2*-0.5)    = -1.0 per token (pessimistic)
      obj  = (4*0.5 - 4*1.0) / 8 = -0.25 -> loss = +0.25
      ratio_mean = (4*1 + 4*2) / 8 = 1.5; clip_frac = 4/8 = 0.5
    """
    B, L = 2, 4
    roll = RolloutBatch(
        tokens=jnp.zeros((B, L), jnp.int32),
        steps=jnp.zeros((B, L), jnp.int32),
        prompt_mask=jnp.zeros((B, L), bool),
        valid=jnp.ones((B, L), bool),
        rewards=jnp.asarray([1.0, 0.0]), group=jnp.zeros((B,), jnp.int32))
    logp = jnp.log(jnp.full((B, L), 0.2))
    old_logp = jnp.stack([jnp.log(jnp.full((L,), 0.2)),
                          jnp.log(jnp.full((L,), 0.1))])
    loss, m = dipo_loss(logp, roll, old_logp=old_logp, n_groups=1,
                        eps=0.2, aggregate="token")
    np.testing.assert_allclose(float(loss), 0.25, rtol=1e-5)
    np.testing.assert_allclose(float(m["ratio_mean"]), 1.5, rtol=1e-5)
    np.testing.assert_allclose(float(m["clip_frac"]), 0.5, rtol=1e-6)


# ----------------------------------------------- lazy boundary sealing


def test_seal_backlog_at_version_boundary(setup):
    """Behaviour log-probs are computed only for groups that cross a
    version boundary while queued: None at harvest, sealed (once) by
    ``seal_queued`` under the still-live harvest-window params, and a
    loud error if a group ever survives a boundary unsealed."""
    from repro.rl.pipeline import RolloutProducer

    model, params, tok = setup
    server, engine = _stack(model, params, tok)
    q = ReplayQueue(capacity=8, staleness_k=1, policy="importance")
    rl = DiPOConfig(group_size=2, logprob_scheme="packed")
    prod = RolloutProducer(engine, q, rl, _ds(tok).prompt_batches(1),
                          jax.random.PRNGKey(0))
    prod.submit_next()
    while q.depth < 1:
        assert prod.pump() == 1
    (g,) = q.groups()
    assert g.old_logp is None          # lazy: nothing paid at harvest
    assert prod.seal_queued() == 1
    assert g.old_logp is not None and g.old_logp.shape == (2, MAX_LEN)
    assert np.all(np.isfinite(g.old_logp))
    assert q.registry.get("groups_sealed").value == 1
    assert prod.seal_queued() == 0     # idempotent: already sealed
    # an unsealed group whose harvest version is gone is an error, not
    # a silently-wrong ratio
    q.push(_mk_group(99, version=server.version))
    server.update_weights(jax.tree.map(jnp.copy, server.params))
    with pytest.raises(RuntimeError, match="never sealed"):
        prod.seal_queued()


# --------------------------------------------- zero-retrace contract


def test_zero_retrace_across_mixed_version_batches(setup):
    """K=1 consumption spans param versions (admission tags move every
    update, old_logp rides as data) yet the fused step compiles exactly
    once — versions never enter the traced computation."""
    model, params, tok = setup
    opt = AdamWConfig(lr=1e-3)
    rl = DiPOConfig(group_size=2, logprob_scheme="packed")
    server, engine = _stack(model, params, tok)
    atr = AsyncDiPOTrainer(model, engine, opt, rl,
                           jax.tree.map(jnp.copy, params), staleness_k=1)
    h = atr.run(_ds(tok).prompt_batches(2), 4, jax.random.PRNGKey(3),
                verbose=False)
    assert server.version == 4
    # consumption crossed versions 0..4 with stored behaviour logps…
    assert sorted(hh["param_version"] for hh in h) == [1, 2, 3, 4]
    assert all(np.isfinite(hh["loss"]) for hh in h)
    # …and the fused step traced exactly once (its per-call gauge too)
    assert atr._step.n_traces == 1
    assert all(hh["step_traces"] == 1 for hh in h)
    # the pool's advance never retraced either (drain-free weight
    # pushes swap buffers between ticks, not shapes)
    assert engine.scheduler.n_advance_traces == 1
