"""Quickstart: build a block-diffusion LM, run the fused SFT pass, decode.

PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import decoding
from repro.core.block_diffusion import sft_loss
from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import BlockDiffLM


def main():
    cfg = configs.get_config("tiny")
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name}, params = {model.param_count(params):,}")

    tok = ByteTokenizer()
    ds = MathTaskDataset(tok, cfg.block_size, seq_len=96, seed=0, level=0)
    batch = next(ds.sft_batches(4)).asdict()
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    # one fused duplicated-sequence SFT loss (paper §4.1)
    loss, metrics = sft_loss(model, params, batch, jax.random.PRNGKey(1))
    print(f"SFT NELBO = {float(loss):.3f} "
          f"(masked CE {float(metrics['masked_ce']):.3f})")

    # blockwise generation with dynamic-threshold decoding (paper §4.4)
    pb = next(ds.prompt_batches(2))
    gen = decoding.generate(model, params, jnp.asarray(pb.prompt_tokens),
                            jnp.asarray(pb.prompt_blocks),
                            jax.random.PRNGKey(2), max_len=96, s_max=4,
                            mode="dynamic", tau=0.9, eos_id=tok.eos_id)
    for i, prompt in enumerate(pb.texts):
        lo = int(pb.prompt_blocks[i]) * cfg.block_size
        hi = lo + int(gen["gen_blocks"][i]) * cfg.block_size
        out = tok.decode(jax.device_get(gen["tokens"][i, lo:hi]))
        print(f"prompt: {prompt!r}\n  -> (untrained) {out!r}")
    print("step map of first generated block:",
          gen["steps"][0, lo:lo + cfg.block_size])


if __name__ == "__main__":
    main()
