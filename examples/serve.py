"""Serving demo: the continuous-batching RolloutEngine answering a
request batch, streaming completions in finish order, plus a live
in-place weight update (the paper's Fig. 5b server loop, §4.2).

PYTHONPATH=src python examples/serve.py [--ckpt path.msgpack]
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.io import load_pytree
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import BlockDiffLM
from repro.serving.engine import (GenerationConfig, RolloutEngine,
                                  SamplingParams)
from repro.serving.server import ModelServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tau", type=float, default=0.9)
    args = ap.parse_args()

    cfg = configs.get_config("tiny")
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params = load_pytree(args.ckpt, params)

    server = ModelServer(params)
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=96, s_max=4, mode="dynamic", tau=args.tau,
        batching="continuous", n_slots=2))

    # streaming path: submit onto the live slot pool, harvest in finish
    # order (a 2-slot pool serving 4 requests exercises admission).
    # Each request carries its OWN SamplingParams — mixed τ and budgets
    # share the pool with zero retraces
    requests = ["Q: 12+7=?\nA:", "Q: 30-4=?\nA:", "Q: 5*6=?\nA:",
                "Q: 9+9=?\nA:"]
    keys = jax.random.split(jax.random.PRNGKey(1), len(requests))
    sampling = [SamplingParams(tau=t, max_new_blocks=b)
                for t, b in [(args.tau, None), (0.7, 3),
                             (0.95, None), (args.tau, 2)]]
    uids = {engine.submit(r, k, params=sp): r
            for r, k, sp in zip(requests, keys, sampling)}
    for out in engine.stream():
        print(f"[done uid={out.uid} tau={out.params.tau:g} "
              f"finish={out.finish_reason} "
              f"latency={out.latency_ticks} ticks] "
              f"{uids[out.uid]!r} -> {out.text!r}")
    s = engine.stats
    print(f"[engine] {s.rollouts} rollouts, {s.total_tokens} tokens, "
          f"{s.tokens_per_step:.2f} tokens/denoise-step, "
          f"slot-util {s.utilization:.0%}, latency p50/p95 "
          f"{s.latency_p50:.0f}/{s.latency_p95:.0f} ticks, "
          f"{s.wall_seconds:.2f}s")

    # live in-place weight update, then serve again (server stays up)
    new_params = jax.tree.map(lambda x: x, engine.store.params)
    v = server.update_weights(new_params)
    print(f"[server] in-place weight push -> version {v} "
          f"({server.update_seconds * 1e3:.2f} ms, no file IO)")
    outs = engine.generate_texts(requests[:2], jax.random.PRNGKey(2))
    print(f"post-update serve ok: {len(outs)} responses")


if __name__ == "__main__":
    main()
