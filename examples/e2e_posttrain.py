"""End-to-end two-stage post-training driver (the paper's §3 pipeline):

    SFT (fused blockwise NELBO)  ->  DiPO RL (online, in-place updates)

on the synthetic verifiable-math task, with eval before/after each stage.

PYTHONPATH=src python examples/e2e_posttrain.py            # CPU preset
PYTHONPATH=src python examples/e2e_posttrain.py --preset small
PYTHONPATH=src python examples/e2e_posttrain.py --preset 100m --sft-steps 300

The 100m preset is the paper-shaped run (use on real accelerators); the
default preset finishes on a single CPU core in a few minutes.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save_pytree
from repro.data.pipeline import MathTaskDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.models.model import BlockDiffLM
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import cosine_schedule
from repro.rl.trainer import DiPOConfig, DiPOTrainer
from repro.serving.engine import GenerationConfig, RolloutEngine
from repro.serving.server import ModelServer
from repro.sft.trainer import SFTTrainer

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=256, sft_steps=250, rl_steps=6, batch=16, seq=96),
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  d_ff=1024, sft_steps=300, rl_steps=10, batch=16, seq=128),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, sft_steps=400, rl_steps=40, batch=32, seq=256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--sft-steps", type=int, default=None)
    ap.add_argument("--rl-steps", type=int, default=None)
    ap.add_argument("--level", type=int, default=0)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]
    sft_steps = args.sft_steps or p["sft_steps"]
    rl_steps = args.rl_steps or p["rl_steps"]

    cfg = ModelConfig(
        name=f"e2e-{args.preset}", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab_size=384,
        block_size=16, attn_impl="structured")
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[e2e] {cfg.name}: {model.param_count(params):,} params")

    tok = ByteTokenizer()
    ds = MathTaskDataset(tok, cfg.block_size, seq_len=p["seq"], seed=0,
                         level=args.level)

    from benchmarks.table1_eval import evaluate
    def ev(prm, tag):
        m = evaluate(model, prm, tok, n_problems=32, mode="dynamic",
                     tau=0.9, level=args.level, max_len=p["seq"])
        print(f"[eval:{tag}] acc={m['acc']:.3f} "
              f"tokens/step={m['tokens_per_step']:.2f} "
              f"len={m['out_len']:.0f}")
        return m

    ev(params, "base")

    # ---- stage 1: SFT -------------------------------------------------
    sft = SFTTrainer(model, AdamWConfig(
        lr=3e-3, clip_norm=1.0,
        schedule=cosine_schedule(3e-3, sft_steps, warmup_steps=10)), params)
    sft.run(ds.sft_batches(p["batch"]), sft_steps, jax.random.PRNGKey(1),
            log_every=max(sft_steps // 8, 1))
    m_sft = ev(sft.params, "sft")

    # ---- stage 2: DiPO RL (online loop, Fig. 5b) ----------------------
    server = ModelServer(jax.tree.map(jnp.copy, sft.params))
    engine = RolloutEngine(model, server, GenerationConfig(
        max_len=p["seq"], s_max=4, mode="dynamic", tau=0.7,
        temperature=1.0, eos_id=tok.eos_id))
    rl = DiPOTrainer(model, engine, AdamWConfig(lr=5e-5),
                     DiPOConfig(group_size=8, beta=0.02,
                                logprob_scheme="packed"),
                     server.params)
    rl.run(ds.prompt_batches(8), rl_steps, jax.random.PRNGKey(2))
    m_rl = ev(rl.params, "sft+dipo")

    print(f"[e2e] acc: base->sft {m_sft['acc']:.3f}, "
          f"sft->dipo {m_rl['acc']:.3f}")
    if args.save:
        save_pytree(args.save, rl.params)
        print(f"[e2e] saved params to {args.save}")


if __name__ == "__main__":
    main()
