"""Every assigned architecture through the same block-diffusion API.

Instantiates the reduced variant of each --arch, runs one fused SFT pass
and one serve_step, and prints the layer pattern — demonstrating that the
paper's technique wraps dense/MoE/SSM/hybrid/enc-dec/VLM backbones behind
one interface.

PYTHONPATH=src python examples/arch_zoo.py [--arch all|<id>]
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.block_diffusion import sft_loss
from repro.core.masks import plain_layout
from repro.models.config import layer_pattern
from repro.models.model import BlockDiffLM


def demo(arch: str):
    cfg = configs.get_smoke_config(arch)
    model = BlockDiffLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pre, grp, ng = layer_pattern(cfg)
    pat = "/".join(s.mixer + ("+moe" if s.ffn == "moe" else "")
                   for s in grp)
    print(f"{arch:24s} {model.param_count(params):>12,} params  "
          f"pattern=[{pat}]x{ng}" + (f" (+{len(pre)} dense)" if pre else ""))

    B, L = 2, cfg.block_size * 4
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (B, L), 4, cfg.vocab_size - 2),
        "prompt_mask": jnp.arange(L)[None] < cfg.block_size,
        "valid": jnp.ones((B, L), bool),
    }
    if cfg.n_extra_tokens:
        emb = jax.random.normal(key, (B, cfg.n_extra_tokens,
                                      cfg.extra_embed_dim))
        batch["memory"] = model.compute_memory(params, emb)
    loss, _ = sft_loss(model, params, batch, jax.random.PRNGKey(2))

    meta = plain_layout(batch["tokens"], batch["valid"],
                        block_size=cfg.block_size)
    caches = model.make_caches(B, L)
    _, out = model.forward_masked(params, batch["tokens"], meta,
                                  caches=caches,
                                  memory=batch.get("memory"))
    blk = jnp.full((B, cfg.block_size), cfg.resolved_mask_token, jnp.int32)
    pos = jnp.broadcast_to(
        jnp.arange(L - cfg.block_size, L, dtype=jnp.int32), blk.shape)
    lg, _ = model.decode_step(params, blk, pos, out["caches"],
                              cache_limit=jnp.full((B,), L - cfg.block_size),
                              memory=batch.get("memory"))
    print(f"{'':24s} sft_loss={float(loss):.3f}  "
          f"serve_step logits {tuple(lg.shape)} finite="
          f"{bool(jnp.isfinite(lg).all())}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    args = ap.parse_args()
    archs = configs.ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    for a in archs:
        demo(a)


if __name__ == "__main__":
    main()
